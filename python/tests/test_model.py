"""L2 model graph tests: structure recovery, masking, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

KMAX = 8


def planted_block(phi, psi, k, seed=0, noise=0.05):
    """Block with k diagonal co-clusters + ground-truth labels."""
    rng = np.random.default_rng(seed)
    rl = np.sort(rng.integers(0, k, phi))
    cl = np.sort(rng.integers(0, k, psi))
    a = np.full((phi, psi), 0.05, np.float32)
    for t in range(k):
        a[np.ix_(rl == t, cl == t)] = 1.0
    a += noise * np.abs(rng.standard_normal((phi, psi)).astype(np.float32))
    return jnp.asarray(a), rl, cl


def args_for(phi, psi, k, seed=3):
    return (
        jnp.array([seed], jnp.int32),
        jnp.array([k], jnp.int32),
        jnp.arange(KMAX, dtype=jnp.int32) * max((phi + psi) // KMAX, 1),
        jnp.array([phi, psi], jnp.int32),
    )


def agreement(pred, truth):
    """Best-case label agreement via pairwise co-membership accuracy."""
    pred = np.asarray(pred)
    same_p = pred[:, None] == pred[None, :]
    same_t = truth[:, None] == truth[None, :]
    return float((same_p == same_t).mean())


class TestSccBlock:
    def test_recovers_planted_structure(self):
        a, rl, cl = planted_block(96, 80, 3, seed=1)
        seed, k, idx, dims = args_for(96, 80, 3)
        row_lab, col_lab, inertia = model.scc_block(a, seed, k, idx, dims, rank=4, kmax=KMAX, kmeans_iters=12)
        assert agreement(row_lab, rl) > 0.9
        assert agreement(col_lab, cl) > 0.9
        assert float(inertia[0]) >= 0.0

    def test_labels_bounded_by_k(self):
        a, _, _ = planted_block(64, 64, 2, seed=2)
        seed, k, idx, dims = args_for(64, 64, 2)
        row_lab, col_lab, _ = model.scc_block(a, seed, k, idx, dims, rank=4, kmax=KMAX)
        assert int(jnp.max(row_lab)) < 2
        assert int(jnp.max(col_lab)) < 2

    def test_padding_is_inert(self):
        # Same data, once exact and once zero-padded: labels on the
        # real region must have identical co-membership structure.
        a, rl, _ = planted_block(48, 40, 2, seed=3)
        seed, k, idx, dims = args_for(48, 40, 2)
        row_a, col_a, _ = model.scc_block(a, seed, k, idx, dims, rank=4, kmax=KMAX)
        pad = jnp.zeros((64, 64), jnp.float32).at[:48, :40].set(a)
        dims_p = jnp.array([48, 40], jnp.int32)
        row_b, col_b, _ = model.scc_block(pad, seed, k, idx, dims_p, rank=4, kmax=KMAX)
        assert agreement(np.asarray(row_b)[:48], np.asarray(row_a)) > 0.95
        assert agreement(np.asarray(col_b)[:40], np.asarray(col_a)) > 0.95

    def test_deterministic(self):
        a, _, _ = planted_block(64, 64, 3, seed=4)
        seed, k, idx, dims = args_for(64, 64, 3)
        out1 = model.scc_block(a, seed, k, idx, dims, rank=4, kmax=KMAX)
        out2 = model.scc_block(a, seed, k, idx, dims, rank=4, kmax=KMAX)
        np.testing.assert_array_equal(out1[0], out2[0])
        np.testing.assert_array_equal(out1[1], out2[1])

    def test_outputs_finite_on_degenerate_input(self):
        a = jnp.zeros((32, 32), jnp.float32)
        seed, k, idx, dims = args_for(32, 32, 2)
        row_lab, col_lab, inertia = model.scc_block(a, seed, k, idx, dims, rank=4, kmax=KMAX)
        assert np.all(np.asarray(row_lab) >= 0)
        assert np.isfinite(float(inertia[0]))


class TestPnmtfBlock:
    def test_recovers_planted_structure(self):
        a, rl, cl = planted_block(80, 70, 3, seed=5)
        seed, k, idx, dims = args_for(80, 70, 3)
        row_lab, col_lab, obj = model.pnmtf_block(a, seed, k, idx, dims, kmax=KMAX, iters=200)
        assert agreement(row_lab, rl) > 0.8
        assert agreement(col_lab, cl) > 0.8
        assert float(obj[0]) >= 0.0

    def test_objective_decreases_with_iterations(self):
        a, _, _ = planted_block(48, 48, 2, seed=6)
        seed, k, idx, dims = args_for(48, 48, 2)
        _, _, o_short = model.pnmtf_block(a, seed, k, idx, dims, kmax=KMAX, iters=2)
        _, _, o_long = model.pnmtf_block(a, seed, k, idx, dims, kmax=KMAX, iters=200)
        assert float(o_long[0]) <= float(o_short[0]) * 1.01

    def test_labels_bounded_by_k(self):
        a, _, _ = planted_block(40, 40, 2, seed=7)
        seed, k, idx, dims = args_for(40, 40, 2)
        row_lab, col_lab, _ = model.pnmtf_block(a, seed, k, idx, dims, kmax=KMAX, iters=15)
        assert int(jnp.max(row_lab)) < 2
        assert int(jnp.max(col_lab)) < 2


class TestNewtonSchulz:
    @pytest.mark.parametrize("shape", [(50, 3), (128, 8), (30, 1)])
    def test_orthonormalizes(self, shape):
        rng = np.random.default_rng(8)
        y = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
        q = model.newton_schulz_orthonormalize(y, iters=16)
        g = np.asarray(jnp.dot(q.T, q))
        np.testing.assert_allclose(g, np.eye(shape[1]), atol=5e-2)

    def test_preserves_column_space(self):
        rng = np.random.default_rng(9)
        y = jnp.asarray(rng.standard_normal((40, 4)).astype(np.float32))
        q = np.asarray(model.newton_schulz_orthonormalize(y, iters=16))
        # q columns must lie in span(y): residual of projection ~ 0.
        yn = np.asarray(y)
        proj = yn @ np.linalg.lstsq(yn, q, rcond=None)[0]
        np.testing.assert_allclose(proj, q, atol=1e-3)
