"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/values; explicit cases pin the edge geometry
(non-divisible tiles, single rows, masked clusters).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0, nonneg=False):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32) * scale
    if nonneg:
        x = np.abs(x)
    return jnp.asarray(x)


# ---------------------------------------------------------------- normalize
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_normalize_matches_ref(m, n, seed):
    a = rand((m, n), seed, nonneg=True)
    r = rand((m,), seed + 1, nonneg=True)
    c = rand((n,), seed + 2, nonneg=True)
    got = kernels.bipartite_normalize(a, r, c)
    want = ref.bipartite_normalize_ref(a, r, c)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(1, 1), (127, 129), (128, 128), (256, 64), (3, 500)])
def test_normalize_edge_shapes(shape):
    a = rand(shape, 7, nonneg=True)
    r = rand((shape[0],), 8, nonneg=True)
    c = rand((shape[1],), 9, nonneg=True)
    np.testing.assert_allclose(
        kernels.bipartite_normalize(a, r, c),
        ref.bipartite_normalize_ref(a, r, c),
        rtol=1e-6,
    )


def test_normalize_zero_rows_stay_zero():
    a = jnp.ones((4, 4), jnp.float32)
    r = jnp.array([1.0, 0.0, 1.0, 0.0], jnp.float32)
    c = jnp.ones((4,), jnp.float32)
    out = kernels.bipartite_normalize(a, r, c)
    assert float(jnp.abs(out[1]).sum()) == 0.0
    assert float(jnp.abs(out[3]).sum()) == 0.0


def test_normalize_custom_block_sizes():
    a = rand((200, 170), 11, nonneg=True)
    r = rand((200,), 12, nonneg=True)
    c = rand((170,), 13, nonneg=True)
    for bm, bn in [(32, 32), (64, 128), (256, 256)]:
        np.testing.assert_allclose(
            kernels.bipartite_normalize(a, r, c, block_m=bm, block_n=bn),
            ref.bipartite_normalize_ref(a, r, c),
            rtol=1e-6,
        )


# ------------------------------------------------------------------ matmul
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rand((m, k), seed)
    b = rand((k, n), seed + 1)
    got = kernels.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 1), (129, 257, 6), (128, 128, 16), (500, 3, 2)])
def test_matmul_edge_shapes(shape):
    m, k, n = shape
    a = rand((m, k), 21)
    b = rand((k, n), 22)
    np.testing.assert_allclose(kernels.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    a = rand((64, 64), 23)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(kernels.matmul(a, eye), a, rtol=1e-6)


def test_matmul_block_size_invariance():
    a = rand((300, 90), 24)
    b = rand((90, 8), 25)
    want = ref.matmul_ref(a, b)
    for bm in [16, 64, 128, 512]:
        np.testing.assert_allclose(kernels.matmul(a, b, block_m=bm), want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- kmeans assign
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    l=st.integers(1, 16),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmeans_assign_matches_ref(n, l, k, seed):
    kmax = 8
    z = rand((n, l), seed)
    cent = rand((kmax, l), seed + 1)
    kmask = (jnp.arange(kmax) < k).astype(jnp.float32)
    got_lab, got_d = kernels.kmeans_assign(z, cent, kmask)
    want_lab, want_d = ref.kmeans_assign_ref(z, cent, kmask)
    # Distances must agree; labels may differ only on exact ties.
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-4)
    ties = np.isclose(got_d, want_d, rtol=1e-4)
    assert np.all((np.asarray(got_lab) == np.asarray(want_lab)) | ties)
    assert int(jnp.max(got_lab)) < k


def test_kmeans_assign_respects_mask():
    z = jnp.zeros((5, 3), jnp.float32)
    cent = jnp.stack([jnp.full((3,), 9.0), jnp.zeros(3), jnp.full((3,), 0.1)]).astype(jnp.float32)
    cent = jnp.concatenate([cent, jnp.zeros((5, 3), jnp.float32)], axis=0)
    # Only cluster 0 valid: everything must go there despite cluster 1
    # being closer.
    kmask = jnp.array([1, 0, 0, 0, 0, 0, 0, 0], jnp.float32)
    lab, d = kernels.kmeans_assign(z, cent, kmask)
    assert np.all(np.asarray(lab) == 0)
    np.testing.assert_allclose(d, 9.0 * 9.0 * 3, rtol=1e-5)


def test_kmeans_assign_exact_points():
    z = jnp.array([[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]], jnp.float32)
    cent = jnp.concatenate([z, jnp.full((5, 2), 1e6, jnp.float32)], axis=0)
    kmask = (jnp.arange(8) < 3).astype(jnp.float32)
    lab, d = kernels.kmeans_assign(z, cent, kmask)
    assert list(np.asarray(lab)) == [0, 1, 2]
    np.testing.assert_allclose(d, 0.0, atol=1e-4)
