"""AOT emission tests: HLO text validity + manifest integrity.

The heavyweight check (rust loads + executes the HLO) lives in the rust
integration suite; here we validate the python side of the contract.
"""

import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def quick_dir():
    with tempfile.TemporaryDirectory() as d:
        aot.emit(d, aot.QUICK_VARIANTS)
        yield d


def test_emit_writes_all_variants(quick_dir):
    names = {v[0] for v in aot.QUICK_VARIANTS}
    for name in names:
        path = os.path.join(quick_dir, f"{name}.hlo.txt")
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 1000


def test_manifest_schema(quick_dir):
    manifest = os.path.join(quick_dir, "manifest.tsv")
    with open(manifest) as f:
        lines = f.read().strip().split("\n")
    assert lines[0] == "name\tkind\tphi\tpsi\trank\tkmax\tkmeans_iters\tpath"
    assert len(lines) == 1 + len(aot.QUICK_VARIANTS)
    for line in lines[1:]:
        cols = line.split("\t")
        assert len(cols) == 8
        assert cols[1] in ("scc_block", "pnmtf_block")
        int(cols[2]), int(cols[3]), int(cols[4]), int(cols[5]), int(cols[6])
        assert cols[7].endswith(".hlo.txt")


def test_hlo_text_is_plain_hlo(quick_dir):
    path = os.path.join(quick_dir, "scc_64.hlo.txt")
    with open(path) as f:
        text = f.read()
    assert text.startswith("HloModule"), "must be HLO text, not proto bytes"
    # The PJRT 0.5.1 loader rejects typed-FFI custom calls; the graphs
    # must not contain any custom-call at all.
    assert "custom-call" not in text, "graph leaked a custom-call (LAPACK?)"


def test_lowering_is_deterministic():
    fn, specs = model.block_fn("scc_block", 32, 32, rank=4, kmax=8, iters=4)
    a = aot.lower_to_hlo_text(fn, specs)
    b = aot.lower_to_hlo_text(fn, specs)
    assert a == b


def test_block_fn_rejects_unknown_kind():
    with pytest.raises(ValueError):
        model.block_fn("nope", 8, 8, rank=2, kmax=4, iters=2)
