"""Bipartite spectral normalization kernel: ``A_n = diag(r) · A · diag(c)``.

``r``/``c`` are the precomputed ``D^{-1/2}`` degree vectors. One fused
elementwise pass, tiled so each grid step holds a ``(bm, bn)`` tile of A
plus the matching vector slices in VMEM.

TPU mapping: a 128×128 f32 tile is 64 KiB; with input + output + both
vectors a grid step stays under 200 KiB of VMEM — comfortably
double-bufferable against the ~16 MiB budget while the VPU does the two
broadcast multiplies.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _normalize_kernel(a_ref, r_ref, c_ref, o_ref):
    a = a_ref[...]
    r = r_ref[...]
    c = c_ref[...]
    o_ref[...] = a * r[:, None] * c[None, :]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def bipartite_normalize(a, r, c, *, block_m: int = 128, block_n: int = 128):
    """``a * r[:, None] * c[None, :]`` as a tiled Pallas kernel.

    Args:
      a: ``(m, n)`` block matrix.
      r: ``(m,)`` row scaling (``D1^{-1/2}``).
      c: ``(n,)`` column scaling (``D2^{-1/2}``).
    """
    m, n = a.shape
    bm = min(block_m, m)
    bn = min(block_n, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _normalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, r, c)
