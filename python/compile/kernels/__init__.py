"""Layer-1 Pallas kernels for the LAMC block co-clustering graph.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target
on this image; real-TPU performance is estimated analytically from the
BlockSpec tiling (DESIGN.md section Hardware-Adaptation / Perf).
"""

from .kmeans import kmeans_assign
from .matmul import matmul
from .normalize import bipartite_normalize

__all__ = ["bipartite_normalize", "matmul", "kmeans_assign"]
