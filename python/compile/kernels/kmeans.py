"""k-means assignment kernel: pairwise squared distances + masked argmin.

Computes, for each embedding row ``z_i``, the nearest of ``kmax``
centroids with clusters ``j ≥ k`` masked to +inf (the artifact supports
any runtime ``k ≤ kmax`` from one compiled module).

Uses the ``‖z−c‖² = ‖z‖² − 2 z·c + ‖c‖²`` expansion so the inner product
is a single MXU-shaped ``(bm×l)·(l×kmax)`` dot per tile; the ``‖z‖²``
term is dropped (constant per row — does not change the argmin) and
added back by the caller only where the true distance is needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(z_ref, cent_ref, kmask_ref, lab_ref, dist_ref):
    z = z_ref[...]                      # (bm, l)
    cent = cent_ref[...]                # (kmax, l)
    kmask = kmask_ref[...]              # (kmax,) 0/1 validity
    dots = jnp.dot(z, cent.T, preferred_element_type=jnp.float32)  # (bm, kmax)
    c2 = jnp.sum(cent * cent, axis=-1)  # (kmax,)
    partial = c2[None, :] - 2.0 * dots  # ‖z‖² omitted: constant per row
    masked = jnp.where(kmask[None, :] > 0, partial, jnp.inf)
    lab_ref[...] = jnp.argmin(masked, axis=-1).astype(jnp.int32)
    z2 = jnp.sum(z * z, axis=-1)
    dist_ref[...] = jnp.min(masked, axis=-1) + z2


@functools.partial(jax.jit, static_argnames=("block_m",))
def kmeans_assign(z, centroids, kmask, *, block_m: int = 256):
    """Assign each row of ``z`` to its nearest valid centroid.

    Args:
      z: ``(n, l)`` embedding rows.
      centroids: ``(kmax, l)``.
      kmask: ``(kmax,)`` float mask, 1 for clusters ``< k`` else 0.

    Returns:
      ``(labels, sq_distances)`` with shapes ``(n,)``/``(n,)``.
    """
    n, l = z.shape
    kmax = centroids.shape[0]
    bm = min(block_m, n)
    grid = (pl.cdiv(n, bm),)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, l), lambda i: (i, 0)),
            pl.BlockSpec((kmax, l), lambda i: (0, 0)),
            pl.BlockSpec((kmax,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(z, centroids, kmask)
