"""Pure-jnp oracles for every Pallas kernel.

The pytest/hypothesis suites assert ``kernel(x) == ref(x)`` (allclose)
across shape, dtype and value sweeps; these references are deliberately
written in the most obvious jnp form (no tiling, no tricks) so a
disagreement always indicts the kernel.
"""

import jax.numpy as jnp


def bipartite_normalize_ref(a, r, c):
    """``diag(r) . A . diag(c)`` — elementwise broadcast form."""
    return a * r[:, None] * c[None, :]


def matmul_ref(a, b):
    """Plain dense matmul with f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def kmeans_assign_ref(z, centroids, kmask):
    """Nearest valid centroid per row, full-distance form.

    Returns ``(labels, squared distances)`` like the kernel, computing
    the complete ``|z - c|^2`` matrix directly.
    """
    d = jnp.sum((z[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    d = jnp.where(kmask[None, :] > 0, d, jnp.inf)
    labels = jnp.argmin(d, axis=-1).astype(jnp.int32)
    dists = jnp.min(d, axis=-1)
    return labels, dists


def inv_sqrt_degrees_ref(degrees, eps=1e-12):
    """``d^{-1/2}`` with zero-degree rows dropped to 0 (matches rust)."""
    return jnp.where(degrees > eps, 1.0 / jnp.sqrt(jnp.maximum(degrees, eps)), 0.0)
