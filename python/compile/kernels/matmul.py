"""Tiled matmul kernel — the subspace-iteration hot spot.

The spectral block graph spends its FLOPs in ``A_n @ G`` /
``A_n.T @ Y`` products where the right operand is a skinny sketch
(``rank+1 ≤ 16`` columns). The kernel tiles the tall operand over rows
and streams the full contraction dimension per grid step.

TPU mapping: with ``bm = 128`` and ``K = 512`` the A-tile is 256 KiB and
the skinny operand 32 KiB — both VMEM-resident; the ``dot`` lands on the
MXU as a (128×512)·(512×16) systolic pass per step. Accumulation is in
f32 (``preferred_element_type``) regardless of input dtype.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m",))
def matmul(a, b, *, block_m: int = 128):
    """``a @ b`` with row-tiling over ``a`` (``(m, k) @ (k, n) → (m, n)``)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm = min(block_m, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
