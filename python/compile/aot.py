"""AOT lowering: JAX block graphs -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the rust runtime then loads
``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file`` and
serves them forever. HLO *text* (never ``.serialize()``) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the image's xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Usage:
  python -m compile.aot --out-dir ../artifacts          # full variant set
  python -m compile.aot --out-dir ../artifacts --quick  # small test set
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

KMAX = 8

#: (name, kind, phi, psi, rank, iters). Shapes chosen to cover the
#: partition planner's candidate grid: squares for dense workloads,
#: tall rectangles for document-term blocks (phi >> psi).
VARIANTS = [
    ("scc_128", "scc_block", 128, 128, 6, 16),
    ("scc_256", "scc_block", 256, 256, 6, 16),
    ("scc_512", "scc_block", 512, 512, 6, 16),
    ("scc_512x128", "scc_block", 512, 128, 6, 16),
    ("scc_256x128", "scc_block", 256, 128, 6, 16),
    ("pnmtf_128", "pnmtf_block", 128, 128, 8, 100),
    ("pnmtf_256", "pnmtf_block", 256, 256, 8, 100),
]

QUICK_VARIANTS = [
    ("scc_64", "scc_block", 64, 64, 4, 8),
    ("pnmtf_64", "pnmtf_block", 64, 64, 8, 10),
]


def lower_to_hlo_text(fn, arg_specs) -> str:
    """jit -> stablehlo -> XlaComputation -> HLO text (return_tuple)."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, variants) -> None:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for name, kind, phi, psi, rank, iters in variants:
        fn, arg_specs = model.block_fn(kind, phi, psi, rank=rank, kmax=KMAX, iters=iters)
        text = lower_to_hlo_text(fn, arg_specs)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {name:<14} {kind:<12} {phi:>4}x{psi:<4} -> {fname} ({len(text) / 1e6:.2f} MB)", flush=True)
        rows.append((name, kind, phi, psi, rank, KMAX, iters, fname))

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("name\tkind\tphi\tpsi\trank\tkmax\tkmeans_iters\tpath\n")
        for r in rows:
            f.write("\t".join(str(x) for x in r) + "\n")
    print(f"wrote {manifest} ({len(rows)} artifacts)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)  # legacy alias
    parser.add_argument("--quick", action="store_true", help="emit the small test variants only")
    args = parser.parse_args()
    out_dir = args.out_dir
    if args.out:  # legacy: --out path/model.hlo.txt
        out_dir = os.path.dirname(args.out) or "."
    emit(out_dir, QUICK_VARIANTS if args.quick else VARIANTS)


if __name__ == "__main__":
    main()
