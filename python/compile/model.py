"""Layer-2 JAX compute graphs: per-block co-clustering.

Two graph families, one per atom method:

* :func:`scc_block` — Dhillon-2001 spectral co-clustering of one
  partition block: bipartite normalization (L1 kernel), randomized
  subspace iteration with Newton-Schulz orthogonalization (L1 matmul
  kernel), stacked embedding, masked k-means (L1 assignment kernel).
* :func:`pnmtf_block` — non-negative matrix tri-factorization by
  multiplicative updates, labels from factor argmax.

Both are lowered AOT (``aot.py``) to HLO text executed by the rust
runtime. Hard constraint discovered on this image (see DESIGN.md):
the PJRT 0.5.1 loader rejects typed-FFI custom calls, so **nothing here
may touch jnp.linalg.{qr,svd,cholesky} or triangular_solve** — all
factorizations are expressed as matmuls (Newton-Schulz), which is also
the natural MXU-friendly formulation on TPU.

Artifact signature (shared by both graphs):
  inputs : a f32[phi,psi], seed i32[1], k i32[1], init_idx i32[kmax],
           dims i32[2]  (actual rows/cols before zero-padding)
  outputs: (row_labels i32[phi], col_labels i32[psi], objective f32[1])
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels

_EPS = 1e-9


def _inv_sqrt(d, eps=1e-12):
    """d^{-1/2} with zero-degree entries (padding) mapped to 0."""
    return jnp.where(d > eps, lax.rsqrt(jnp.maximum(d, eps)), 0.0)


def newton_schulz_orthonormalize(y, iters: int = 12, ridge: float = 1e-6):
    """Orthonormalize the columns of ``y`` using only matmuls.

    Computes ``y @ (yᵀy)^{-1/2}`` via the Newton-Schulz iteration for the
    inverse matrix square root. Replaces LAPACK QR, which cannot be
    lowered for the PJRT 0.5.1 runtime, and maps onto the MXU as a chain
    of small (l×l) matmuls.
    """
    l = y.shape[1]
    g = jnp.dot(y.T, y, preferred_element_type=jnp.float32)
    tr = jnp.trace(g) + ridge
    gn = g / tr + ridge * jnp.eye(l, dtype=y.dtype)

    def body(_, x):
        t = x @ gn @ x
        return 1.5 * x - 0.5 * (x @ t)

    x = lax.fori_loop(0, iters, body, jnp.eye(l, dtype=y.dtype))
    return y @ (x * lax.rsqrt(tr))


def _validity_masks(phi, psi, dims):
    rows_valid = (lax.iota(jnp.int32, phi) < dims[0]).astype(jnp.float32)
    cols_valid = (lax.iota(jnp.int32, psi) < dims[1]).astype(jnp.float32)
    return rows_valid, cols_valid


def _masked_kmeans(z, valid, k, init_idx, kmax, iters):
    """Lloyd iterations over ``z`` rows with padding + k masking.

    Padded rows (``valid == 0``) participate in assignment (their labels
    are cropped by the caller) but contribute nothing to centroid
    updates or the inertia.
    """
    kmaskf = (lax.iota(jnp.int32, kmax) < k[0]).astype(jnp.float32)
    cent0 = z[init_idx]  # (kmax, l) gather

    def body(_, cent):
        labels, _ = kernels.kmeans_assign(z, cent, kmaskf)
        oh = jax.nn.one_hot(labels, kmax, dtype=jnp.float32) * valid[:, None]
        counts = jnp.sum(oh, axis=0)
        sums = jnp.dot(oh.T, z, preferred_element_type=jnp.float32)
        return jnp.where(counts[:, None] > 0.5, sums / (counts[:, None] + _EPS), cent)

    cent = lax.fori_loop(0, iters, body, cent0)
    labels, dists = kernels.kmeans_assign(z, cent, kmaskf)
    inertia = jnp.sum(dists * valid)
    return labels, inertia


def scc_block(a, seed, k, init_idx, dims, *, rank: int = 6, kmax: int = 8,
              kmeans_iters: int = 16, power_iters: int = 4, ns_iters: int = 12):
    """Spectral co-clustering of one zero-padded partition block."""
    phi, psi = a.shape
    rows_valid, cols_valid = _validity_masks(phi, psi, dims)
    # Defensive: force padding to exact zero even if the host sent junk.
    a = a * rows_valid[:, None] * cols_valid[None, :]

    d1 = jnp.sum(a, axis=1)
    d2 = jnp.sum(a, axis=0)
    r = _inv_sqrt(d1)
    c = _inv_sqrt(d2)
    an = kernels.bipartite_normalize(a, r, c)

    # Deflate the trivial leading singular pair (sigma_1 = 1,
    # u1 = sqrt(d1)/||.||, v1 = sqrt(d2)/||.||): the remaining top
    # subspace is exactly Dhillon's u_2..u_{l+1} / v_2..v_{l+1}.
    s1 = jnp.sqrt(jnp.maximum(d1, 0.0))
    s2 = jnp.sqrt(jnp.maximum(d2, 0.0))
    u1 = s1 * lax.rsqrt(jnp.sum(s1 * s1) + _EPS)
    v1 = s2 * lax.rsqrt(jnp.sum(s2 * s2) + _EPS)
    ad = an - u1[:, None] * v1[None, :]

    # Randomized subspace iteration for the top-`rank` left subspace.
    key = jax.random.PRNGKey(seed[0])
    g = jax.random.normal(key, (psi, rank), dtype=jnp.float32)
    y = newton_schulz_orthonormalize(kernels.matmul(ad, g), iters=ns_iters)
    adt = ad.T
    for _ in range(power_iters):
        w = newton_schulz_orthonormalize(kernels.matmul(adt, y), iters=ns_iters)
        y = newton_schulz_orthonormalize(kernels.matmul(ad, w), iters=ns_iters)

    # Right-side embedding ~ V Sigma; normalize columns to approximate V.
    w = kernels.matmul(adt, y)
    wnorm = lax.rsqrt(jnp.sum(w * w, axis=0) + _EPS)
    w = w * wnorm[None, :]

    # Dhillon's stacked embedding Z = [D1^{-1/2} U-hat ; D2^{-1/2} V-hat].
    zu = y * r[:, None]
    zv = w * c[:, None]
    z = jnp.concatenate([zu, zv], axis=0)
    valid = jnp.concatenate([rows_valid, cols_valid], axis=0)

    labels, inertia = _masked_kmeans(z, valid, k, init_idx, kmax, kmeans_iters)
    return (
        labels[:phi].astype(jnp.int32),
        labels[phi:].astype(jnp.int32),
        inertia.reshape(1),
    )


def pnmtf_block(a, seed, k, init_idx, dims, *, rank: int = 8, kmax: int = 8,
                iters: int = 30):
    """Tri-factorization A ~ R S Cᵀ of one block by multiplicative updates.

    ``rank`` is kept for signature parity with :func:`scc_block`; the
    factor width is ``kmax`` with clusters >= k zero-masked (a zero
    column stays zero under multiplicative updates).
    """
    phi, psi = a.shape
    rows_valid, cols_valid = _validity_masks(phi, psi, dims)
    a = a * rows_valid[:, None] * cols_valid[None, :]
    kmaskf = (lax.iota(jnp.int32, kmax) < k[0]).astype(jnp.float32)

    # PNMTF has no point-based init; fold init_idx into the PRNG stream
    # so the input stays live (jit would otherwise prune the parameter,
    # breaking the uniform 5-buffer artifact ABI the rust server uses).
    key = jax.random.fold_in(jax.random.PRNGKey(seed[0]), init_idx[0])
    k1, k2, k3 = jax.random.split(key, 3)
    scale = jnp.sqrt(jnp.sum(a * a) / (phi * psi) + _EPS)
    r0 = jax.random.uniform(k1, (phi, kmax), minval=0.5, maxval=1.5) * scale
    c0 = jax.random.uniform(k2, (psi, kmax), minval=0.5, maxval=1.5) * scale
    s0 = jax.random.uniform(k3, (kmax, kmax), minval=0.5, maxval=1.5)
    r0 = r0 * kmaskf[None, :] * rows_valid[:, None]
    c0 = c0 * kmaskf[None, :] * cols_valid[:, None]
    s0 = s0 * kmaskf[None, :] * kmaskf[:, None]

    def body(_, rcs):
        r, c, s = rcs
        # R update
        cst = jnp.dot(c, s.T, preferred_element_type=jnp.float32)
        num_r = kernels.matmul(a, cst)
        ctc = jnp.dot(c.T, c, preferred_element_type=jnp.float32)
        den_r = r @ (s @ ctc @ s.T)
        r = r * num_r / (den_r + _EPS)
        # C update
        rs = jnp.dot(r, s, preferred_element_type=jnp.float32)
        num_c = kernels.matmul(a.T, rs)
        rtr = jnp.dot(r.T, r, preferred_element_type=jnp.float32)
        den_c = c @ (s.T @ rtr @ s)
        c = c * num_c / (den_c + _EPS)
        # S update
        ac = kernels.matmul(a, c)
        num_s = jnp.dot(r.T, ac, preferred_element_type=jnp.float32)
        den_s = rtr @ s @ jnp.dot(c.T, c, preferred_element_type=jnp.float32)
        s = s * num_s / (den_s + _EPS)
        return (r, c, s)

    r, c, s = lax.fori_loop(0, iters, body, (r0, c0, s0))

    neg = jnp.float32(-1e30)
    row_labels = jnp.argmax(jnp.where(kmaskf[None, :] > 0, r, neg), axis=1)
    col_labels = jnp.argmax(jnp.where(kmaskf[None, :] > 0, c, neg), axis=1)

    # ||A - R S Ct||^2 via the trace expansion (no phi x psi temp).
    rs = jnp.dot(r, s, preferred_element_type=jnp.float32)
    at_rs = kernels.matmul(a.T, rs)
    cross = jnp.sum(at_rs * c)
    ctc = jnp.dot(c.T, c, preferred_element_type=jnp.float32)
    rst_rs = jnp.dot(rs.T, rs, preferred_element_type=jnp.float32)
    recon2 = jnp.sum(rst_rs * ctc)
    obj = jnp.maximum(jnp.sum(a * a) - 2.0 * cross + recon2, 0.0)

    return (
        row_labels.astype(jnp.int32),
        col_labels.astype(jnp.int32),
        obj.reshape(1),
    )


def block_fn(kind: str, phi: int, psi: int, *, rank: int, kmax: int, iters: int):
    """Bind a block graph to static shapes for AOT lowering."""
    if kind == "scc_block":
        fn = functools.partial(scc_block, rank=rank, kmax=kmax, kmeans_iters=iters)
    elif kind == "pnmtf_block":
        fn = functools.partial(pnmtf_block, rank=rank, kmax=kmax, iters=iters)
    else:
        raise ValueError(f"unknown block kind {kind!r}")

    def wrapped(a, seed, k, init_idx, dims):
        return fn(a, seed, k, init_idx, dims)

    arg_specs = (
        jax.ShapeDtypeStruct((phi, psi), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((kmax,), jnp.int32),
        jax.ShapeDtypeStruct((2,), jnp.int32),
    )
    return wrapped, arg_specs
