//! Microbenchmarks of the linalg substrate (the native-route hot path).
//!
//! Used by the §Perf iteration loop: changes to the GEMM/SVD/QR kernels
//! are accepted only when these medians improve.

use lamc::bench_util::{bench, Table};
use lamc::linalg::{jacobi_svd, matmul, matmul_at_b, qr_thin, randomized_svd};
use lamc::matrix::{CsrMatrix, DenseMatrix, Matrix};
use lamc::rng::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::seed_from(0xBE7C);
    println!("== linalg microbenches (LAMC_THREADS={}) ==\n", lamc::linalg::matmul_threads());
    let mut table = Table::new(&["op", "shape", "median", "GFLOP/s"]);

    // GEMM square.
    for n in [128usize, 256, 512, 1024] {
        let a = DenseMatrix::randn(n, n, &mut rng);
        let b = DenseMatrix::randn(n, n, &mut rng);
        let t = bench(1, 5, || {
            std::hint::black_box(matmul(&a, &b));
        });
        let gflops = 2.0 * (n as f64).powi(3) / t.median_s / 1e9;
        table.row(&["gemm".into(), format!("{n}x{n}x{n}"), t.format(), format!("{gflops:.2}")]);
    }

    // Skinny AtB (sketch contraction).
    let a = DenseMatrix::randn(4096, 512, &mut rng);
    let b = DenseMatrix::randn(4096, 8, &mut rng);
    let t = bench(1, 5, || {
        std::hint::black_box(matmul_at_b(&a, &b));
    });
    let gflops = 2.0 * 4096.0 * 512.0 * 8.0 / t.median_s / 1e9;
    table.row(&["gemm AᵀB".into(), "4096x512x8".into(), t.format(), format!("{gflops:.2}")]);

    // QR.
    let a = DenseMatrix::randn(2048, 12, &mut rng);
    let t = bench(1, 5, || {
        std::hint::black_box(qr_thin(&a));
    });
    table.row(&["qr_thin".into(), "2048x12".into(), t.format(), "-".into()]);

    // Randomized SVD dense + sparse.
    let dense = Matrix::Dense(DenseMatrix::randn(1024, 512, &mut rng));
    let t = bench(1, 3, || {
        let mut r = Xoshiro256::seed_from(1);
        std::hint::black_box(randomized_svd(&dense, 6, 6, 3, &mut r));
    });
    table.row(&["rsvd k=6".into(), "1024x512 dense".into(), t.format(), "-".into()]);

    let mut trips = Vec::new();
    let mut r2 = Xoshiro256::seed_from(2);
    for _ in 0..(4096 * 80) {
        trips.push((r2.next_below(4096), r2.next_below(1024), r2.next_f32()));
    }
    let sparse = Matrix::Sparse(CsrMatrix::from_triplets(4096, 1024, trips));
    let t = bench(1, 3, || {
        let mut r = Xoshiro256::seed_from(1);
        std::hint::black_box(randomized_svd(&sparse, 6, 6, 3, &mut r));
    });
    table.row(&["rsvd k=6".into(), "4096x1024 2% nnz".into(), t.format(), "-".into()]);

    // Exact Jacobi (the baseline's wall).
    let a = DenseMatrix::randn(256, 256, &mut rng);
    let t = bench(0, 3, || {
        std::hint::black_box(jacobi_svd(&a, 30, 1e-10));
    });
    table.row(&["jacobi_svd".into(), "256x256".into(), t.format(), "-".into()]);

    println!("{}", table.render());
}
