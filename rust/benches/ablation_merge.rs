//! Ablation A1: merge threshold τ sweep (DESIGN.md §5 design choice).
//!
//! Shows the robustness window: too-low τ over-merges (k collapses),
//! too-high τ under-merges (k explodes, NMI drops from fragmentation).

use lamc::bench_util::Table;
use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::merge::MergeConfig;
use lamc::metrics::score_coclustering;
use lamc::pipeline::{Lamc, LamcConfig};

fn main() {
    let ds = planted_dense(&PlantedConfig {
        rows: 800,
        cols: 700,
        row_clusters: 4,
        col_clusters: 4,
        noise: 0.2,
        signal: 1.3,
        seed: 5001,
        ..Default::default()
    });

    println!("== Ablation: hierarchical-merge threshold τ ==\n");
    let mut table = Table::new(&["tau", "k found", "NMI", "ARI", "time (s)"]);
    for tau in [0.05, 0.15, 0.25, 0.35, 0.5, 0.65, 0.8, 0.95] {
        let cfg = LamcConfig {
            k: 4,
            merge: MergeConfig { tau, ..Default::default() },
            ..Default::default()
        };
        let out = Lamc::new(cfg).run(&ds.matrix).unwrap();
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        table.row(&[
            format!("{tau:.2}"),
            out.k.to_string(),
            format!("{:.4}", s.nmi()),
            format!("{:.4}", s.ari()),
            format!("{:.3}", out.elapsed_s),
        ]);
    }
    println!("{}", table.render());
    println!("Expected shape: a plateau of high NMI around the default τ=0.35,");
    println!("degradation at both extremes (over-/under-merging).");
}
