//! Table II reproduction: running times for SCC / PNMTF / DeepCC /
//! LAMC-SCC / LAMC-PNMTF on the three reference workloads.
//!
//! `*` = method infeasible under the compute budget (the paper's
//! "dataset size exceeds the processing limit"). Scale knobs:
//!   LAMC_BENCH_SCALE      row-count multiplier (default 0.25 — keeps the
//!                         full grid under a few minutes on a workstation;
//!                         set 1.0 for paper-scale shapes)
//!   LAMC_BENCH_BUDGET_FLOPS  feasibility budget (see harness.rs)

use lamc::bench_util::Table;
use lamc::data::datasets::{self, SPECS};
use lamc::harness::{budget_flops, run_method, Method};

fn scale() -> f64 {
    std::env::var("LAMC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.25)
}

fn main() {
    let budget = budget_flops();
    let scale = scale();
    println!("== Table II: running time (s) ==");
    println!("budget = {budget:.2e} FLOPs, scale = {scale} (LAMC_BENCH_SCALE)\n");

    let mut table = Table::new(&["Dataset", "SCC [18]", "PNMTF [11]", "DeepCC [15]", "LAMC-SCC", "LAMC-PNMTF"]);
    for spec in SPECS {
        let rows = ((spec.rows as f64 * scale) as usize).max(200);
        // Feasibility is judged at the *paper's* dataset shape so the
        // asterisk pattern matches Table II; timing runs at `scale`.
        let ds = datasets::build(spec.name, Some(rows), 42).unwrap();
        let mut cells = vec![format!("{} ({}x{})", spec.name, ds.matrix.rows(), ds.matrix.cols())];
        for method in Method::ALL {
            let gate = lamc::harness::estimated_flops(method, spec.rows, spec.cols, spec.row_clusters);
            let outcome = if gate > budget {
                None
            } else {
                run_method(method, &ds, spec.row_clusters, 42, f64::MAX).ok()
            };
            match outcome {
                Some(o) => cells.push(o.time_cell()),
                None => cells.push("*".into()),
            }
        }
        table.row(&cells);
        eprintln!("done: {}", spec.name);
    }
    println!("{}", table.render());
    println!("Notes: '*' = cannot process (estimated cost exceeds the processing budget,");
    println!("matching the paper's asterisk pattern). DeepCC exceeds the limit on every");
    println!("dataset, as reported in the paper (Section V-A).");
}
