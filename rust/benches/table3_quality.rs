//! Table III reproduction: NMI and ARI for every method × dataset.
//!
//! Ground truth comes from the planted generators (DESIGN.md §4); the
//! asterisk pattern mirrors Table II's feasibility envelope.

use lamc::bench_util::Table;
use lamc::data::datasets::{self, SPECS};
use lamc::harness::{budget_flops, run_method, Method};

fn scale() -> f64 {
    std::env::var("LAMC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.25)
}

fn main() {
    let budget = budget_flops();
    let scale = scale();
    println!("== Table III: NMI / ARI ==");
    println!("budget = {budget:.2e} FLOPs, scale = {scale}\n");

    let mut table = Table::new(&["Dataset", "Metric", "SCC [18]", "PNMTF [11]", "DeepCC [15]", "LAMC-SCC", "LAMC-PNMTF"]);
    for spec in SPECS {
        let rows = ((spec.rows as f64 * scale) as usize).max(200);
        let ds = datasets::build(spec.name, Some(rows), 42).unwrap();
        let mut nmi_cells = vec![spec.name.to_string(), "NMI".to_string()];
        let mut ari_cells = vec![String::new(), "ARI".to_string()];
        for method in Method::ALL {
            let gate = lamc::harness::estimated_flops(method, spec.rows, spec.cols, spec.row_clusters);
            let outcome = if gate > budget {
                None
            } else {
                run_method(method, &ds, spec.row_clusters, 42, f64::MAX).ok()
            };
            match outcome {
                Some(o) => {
                    nmi_cells.push(o.nmi_cell());
                    ari_cells.push(o.ari_cell());
                }
                None => {
                    nmi_cells.push("*".into());
                    ari_cells.push("*".into());
                }
            }
        }
        table.row(&nmi_cells);
        table.row(&ari_cells);
        eprintln!("done: {}", spec.name);
    }
    println!("{}", table.render());
    println!("Notes: ground truth = planted co-cluster labels; '*' as in Table II.");
}
