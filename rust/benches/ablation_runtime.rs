//! Ablation A3: PJRT artifact route vs native Rust route, per-block.
//!
//! Measures the per-block latency of both execution routes on
//! artifact-shaped blocks, plus the padding overhead of routing an
//! odd-shaped block through the nearest larger artifact.

use std::sync::Arc;

use lamc::bench_util::{bench, Table};
use lamc::cocluster::{AtomCocluster, SpectralCocluster};
use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::matrix::Matrix;
use lamc::rng::Xoshiro256;
use lamc::runtime::{Manifest, RuntimePool, RuntimePoolConfig};

fn main() {
    let Some(path) = lamc::runtime::find_manifest() else {
        println!("SKIP: run `make artifacts` first");
        return;
    };
    let manifest = Manifest::load(&path).unwrap();
    let pool = RuntimePool::start(manifest, RuntimePoolConfig { servers: 1 }).unwrap();
    let native = SpectralCocluster::default();

    println!("== Ablation: execution route latency per block ==\n");
    let mut table = Table::new(&["block", "route", "median", "notes"]);
    for (r, c) in [(128usize, 128usize), (256, 256), (200, 190), (512, 512)] {
        let ds = planted_dense(&PlantedConfig {
            rows: r,
            cols: c,
            row_clusters: 4,
            col_clusters: 4,
            noise: 0.1,
            signal: 1.5,
            seed: 7001,
            ..Default::default()
        });
        let block = ds.matrix.to_dense();

        if let Some(spec) = pool.spec_for("scc_block", r, c, 4) {
            let pool2 = Arc::clone(&pool);
            let spec2 = Arc::clone(&spec);
            let block2 = block.clone();
            let t = bench(1, 5, move || {
                pool2.execute(Arc::clone(&spec2), block2.clone(), 4, 7).unwrap();
            });
            let pad = (spec.phi * spec.psi) as f64 / (r * c) as f64;
            table.row(&[
                format!("{r}x{c}"),
                format!("pjrt ({})", spec.name),
                t.format(),
                format!("pad factor {pad:.2}"),
            ]);
        }

        let m = Matrix::Dense(block.clone());
        let t = bench(1, 5, || {
            let mut rng = Xoshiro256::seed_from(7);
            native.cocluster(&m, 4, &mut rng);
        });
        table.row(&[format!("{r}x{c}"), "native".into(), t.format(), String::new()]);
    }
    println!("{}", table.render());
    println!("Note: the pjrt route runs the AOT-compiled JAX/Pallas graph (interpret-mode");
    println!("Pallas on CPU); on a real TPU the same artifact lowers to MXU kernels —");
    println!("see DESIGN.md §Hardware-Adaptation for the roofline estimate.");
}
