//! Row-band (LAMC2) vs tiled (LAMC3) store layouts under the three
//! access shapes the pipeline generates: row-heavy blocks, column-heavy
//! blocks, and square planner tiles — with the tiled store additionally
//! packed under `--codec shuffle-lz` to measure what compression does
//! to bytes off disk and decode time. Reports wall time per gather and
//! the two byte counters the layout/codec actually control: stored
//! bytes read and uncompressed bytes decoded.
//!
//! Run: `cargo bench --bench store_layouts [-- --json OUT.json]`
//! (plain `main()`, prints a table; `--json` additionally writes the
//! machine-readable form CI's perf-smoke job folds into `BENCH_10.json`
//! — schema in docs/BENCHMARKS.md).

use lamc::bench_util::{bench, json_arg_path, Table};
use lamc::matrix::{DenseMatrix, Matrix};
use lamc::rng::Xoshiro256;
use lamc::store::{pack_matrix, pack_matrix_tiled, pack_matrix_tiled_with_codec, Codec, StoreReader};

fn main() {
    let rows = 2048usize;
    let cols = 1024usize;
    let mut rng = Xoshiro256::seed_from(0x57031);
    println!("== store layouts: {rows} x {cols} dense, 256-row bands vs 256x128 tiles ==\n");
    let matrix = Matrix::Dense(DenseMatrix::randn(rows, cols, &mut rng));

    let dir = std::env::temp_dir().join("lamc_bench_store_layouts");
    std::fs::create_dir_all(&dir).unwrap();
    let band_path = dir.join("m.lamc2");
    let tiled_path = dir.join("m.lamc3");
    let tiled_lz_path = dir.join("m_lz.lamc3");
    let band_summary = pack_matrix(&matrix, &band_path, 256).unwrap();
    let tiled_summary = pack_matrix_tiled(&matrix, &tiled_path, 256, 128).unwrap();
    let lz_summary =
        pack_matrix_tiled_with_codec(&matrix, &tiled_lz_path, 256, 128, Codec::ShuffleLz).unwrap();

    // On-disk compression: randn f32 payloads compress on the exponent
    // byte plane alone, so the shuffle-lz store must be strictly smaller.
    let mut store_records: Vec<String> = Vec::new();
    println!("on-disk payload bytes (raw -> stored):");
    for (name, s) in
        [("lamc2", &band_summary), ("lamc3", &tiled_summary), ("lamc3+lz", &lz_summary)]
    {
        let ratio = s.stored_payload_bytes as f64 / s.raw_payload_bytes.max(1) as f64;
        println!(
            "  {name:9} codec={:10} {} -> {} bytes ({:.1}%)",
            s.codec.as_str(),
            s.raw_payload_bytes,
            s.stored_payload_bytes,
            ratio * 100.0
        );
        store_records.push(format!(
            "    {{\"store\": \"{name}\", \"codec\": \"{}\", \"raw_payload_bytes\": {}, \"stored_payload_bytes\": {}, \"on_disk_ratio\": {ratio:.4}}}",
            s.codec.as_str(),
            s.raw_payload_bytes,
            s.stored_payload_bytes
        ));
    }
    assert!(
        lz_summary.stored_payload_bytes < tiled_summary.stored_payload_bytes,
        "shuffle-lz stores fewer payload bytes than raw tiles"
    );
    assert_eq!(
        lz_summary.fingerprint, tiled_summary.fingerprint,
        "content fingerprint is codec-invariant"
    );
    println!();

    // Caches off: the point is bytes touched, not cache residency.
    let shapes: [(&str, usize, usize); 3] = [
        ("row-heavy (16 x 512)", 16, 512),
        ("square (128 x 128)", 128, 128),
        ("col-heavy (1024 x 32)", 1024, 32),
    ];

    let mut table = Table::new(&[
        "access shape",
        "layout",
        "median",
        "stored bytes/gather",
        "decoded bytes/gather",
    ]);
    let mut records: Vec<String> = Vec::new();
    // (stored bytes/gather, gathered bytes) per layout on the col-heavy
    // shape, for the compression acceptance check below.
    let mut col_heavy: Vec<(&str, u64, u64)> = Vec::new();
    for (name, nr, nc) in shapes {
        for (layout, path) in
            [("lamc2", &band_path), ("lamc3", &tiled_path), ("lamc3+lz", &tiled_lz_path)]
        {
            let reader = StoreReader::open_with_cache(path, 0).unwrap();
            let mut qrng = Xoshiro256::seed_from(7);
            let mut gathered = 0u64;
            let t = bench(1, 5, || {
                let r = qrng.sample_indices(rows, nr);
                let c = qrng.sample_indices(cols, nc);
                let tile = reader.tile(&r, &c).unwrap();
                gathered = gathered.wrapping_add(tile.data().len() as u64 * 4);
                std::hint::black_box(tile);
            });
            let gathers = reader.tiles_served().max(1);
            let per_gather = reader.bytes_read() / gathers;
            let decoded_per_gather = reader.bytes_decoded() / gathers;
            if name.starts_with("col-heavy") {
                col_heavy.push((layout, per_gather, gathered));
            }
            table.row(&[
                name.to_string(),
                layout.to_string(),
                t.format(),
                format!("{per_gather}"),
                format!("{decoded_per_gather}"),
            ]);
            records.push(format!(
                "    {{\"shape\": \"{name}\", \"layout\": \"{layout}\", \"median_s\": {:.6}, \"payload_bytes_per_gather\": {per_gather}, \"decoded_bytes_per_gather\": {decoded_per_gather}}}",
                t.median_s
            ));
        }
    }
    println!("{}", table.render());
    println!("(lamc3 wins where the access is narrower than the matrix; lamc2 wins\n row-heavy shapes by avoiding per-tile seek/decode overhead; shuffle-lz\n trades decode CPU for strictly fewer stored bytes off disk)");

    // Acceptance: on the col-heavy shape the compressed tiled store
    // reads strictly fewer stored bytes than its codec=none twin while
    // gathering the exact same bytes (same seeded query stream).
    let none = col_heavy.iter().find(|(l, _, _)| *l == "lamc3").unwrap();
    let lz = col_heavy.iter().find(|(l, _, _)| *l == "lamc3+lz").unwrap();
    assert!(
        lz.1 < none.1,
        "col-heavy: shuffle-lz reads {} B/gather, codec=none {} B/gather",
        lz.1,
        none.1
    );
    assert_eq!(lz.2, none.2, "col-heavy: identical bytes gathered across codecs");

    if let Some(json_out) = json_arg_path() {
        let json = format!(
            "{{\n  \"bench\": \"store_layouts\",\n  \"rows\": {rows},\n  \"cols\": {cols},\n  \
             \"band_store\": \"256-row bands\",\n  \"tiled_store\": \"256x128 tiles\",\n  \
             \"stores\": [\n{}\n  ],\n  \"gathers\": [\n{}\n  ]\n}}\n",
            store_records.join(",\n"),
            records.join(",\n")
        );
        std::fs::write(&json_out, json).unwrap();
        println!("wrote {json_out:?}");
    }
}
