//! Row-band (LAMC2) vs tiled (LAMC3) store layouts under the three
//! access shapes the pipeline generates: row-heavy blocks, column-heavy
//! blocks, and square planner tiles. Reports wall time per gather and —
//! the number the layout actually controls — payload bytes off disk.
//!
//! Run: `cargo bench --bench store_layouts [-- --json OUT.json]`
//! (plain `main()`, prints a table; `--json` additionally writes the
//! machine-readable form CI's perf-smoke job folds into `BENCH_5.json`
//! — schema in docs/BENCHMARKS.md).

use lamc::bench_util::{bench, json_arg_path, Table};
use lamc::matrix::{DenseMatrix, Matrix};
use lamc::rng::Xoshiro256;
use lamc::store::{pack_matrix, pack_matrix_tiled, StoreReader};

fn main() {
    let rows = 2048usize;
    let cols = 1024usize;
    let mut rng = Xoshiro256::seed_from(0x57031);
    println!("== store layouts: {rows} x {cols} dense, 256-row bands vs 256x128 tiles ==\n");
    let matrix = Matrix::Dense(DenseMatrix::randn(rows, cols, &mut rng));

    let dir = std::env::temp_dir().join("lamc_bench_store_layouts");
    std::fs::create_dir_all(&dir).unwrap();
    let band_path = dir.join("m.lamc2");
    let tiled_path = dir.join("m.lamc3");
    pack_matrix(&matrix, &band_path, 256).unwrap();
    pack_matrix_tiled(&matrix, &tiled_path, 256, 128).unwrap();

    // Caches off: the point is bytes touched, not cache residency.
    let shapes: [(&str, usize, usize); 3] = [
        ("row-heavy (16 x 512)", 16, 512),
        ("square (128 x 128)", 128, 128),
        ("col-heavy (1024 x 32)", 1024, 32),
    ];

    let mut table = Table::new(&["access shape", "layout", "median", "payload bytes/gather"]);
    let mut records: Vec<String> = Vec::new();
    for (name, nr, nc) in shapes {
        for (layout, path) in [("lamc2", &band_path), ("lamc3", &tiled_path)] {
            let reader = StoreReader::open_with_cache(path, 0).unwrap();
            let mut qrng = Xoshiro256::seed_from(7);
            let t = bench(1, 5, || {
                let r = qrng.sample_indices(rows, nr);
                let c = qrng.sample_indices(cols, nc);
                std::hint::black_box(reader.tile(&r, &c).unwrap());
            });
            let per_gather = reader.bytes_read() / reader.tiles_served().max(1);
            table.row(&[
                name.to_string(),
                layout.to_string(),
                t.format(),
                format!("{per_gather}"),
            ]);
            records.push(format!(
                "    {{\"shape\": \"{name}\", \"layout\": \"{layout}\", \"median_s\": {:.6}, \"payload_bytes_per_gather\": {per_gather}}}",
                t.median_s
            ));
        }
    }
    println!("{}", table.render());
    println!("(lamc3 wins where the access is narrower than the matrix; lamc2 wins\n row-heavy shapes by avoiding per-tile seek/decode overhead)");

    if let Some(json_out) = json_arg_path() {
        let json = format!(
            "{{\n  \"bench\": \"store_layouts\",\n  \"rows\": {rows},\n  \"cols\": {cols},\n  \
             \"band_store\": \"256-row bands\",\n  \"tiled_store\": \"256x128 tiles\",\n  \"gathers\": [\n{}\n  ]\n}}\n",
            records.join(",\n")
        );
        std::fs::write(&json_out, json).unwrap();
        println!("wrote {json_out:?}");
    }
}
