//! Ablation A2: Theorem 1 in practice — P_thresh vs chosen T_p vs
//! empirically measured co-cluster detection rate.
//!
//! For each threshold the planner solves Eq. 4 for T_p; we then measure
//! the detection rate over Monte-Carlo shuffles and over the real
//! pipeline's recovered NMI. The empirical rate must dominate the
//! certified probability (the bound is conservative).

use lamc::bench_util::Table;
use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::metrics::score_coclustering;
use lamc::partition::prob_model::{detection_probability, CoclusterPrior};
use lamc::partition::{plan, PlannerConfig};
use lamc::pipeline::{Lamc, LamcConfig};
use lamc::rng::Xoshiro256;

fn monte_carlo_detection(rows: usize, frac: f64, phi: usize, m: usize, t_m: usize, t_p: usize, trials: usize) -> f64 {
    let mut rng = Xoshiro256::seed_from(0xAB1A);
    let members = (rows as f64 * frac) as usize;
    let mut hits = 0;
    for _ in 0..trials {
        let mut detected_any = false;
        for _ in 0..t_p {
            let perm = rng.permutation(rows);
            let mut counts = vec![0usize; m];
            for (pos, &id) in perm.iter().enumerate() {
                if id < members {
                    counts[(pos / phi).min(m - 1)] += 1;
                }
            }
            if counts.iter().any(|&c| c >= t_m) {
                detected_any = true;
                break;
            }
        }
        if detected_any {
            hits += 1;
        }
    }
    hits as f64 / trials as f64
}

fn main() {
    println!("== Ablation: P_thresh → T_p → measured detection ==\n");
    let (rows, cols) = (1200usize, 1000usize);
    let prior = CoclusterPrior { row_fraction: 0.08, col_fraction: 0.08, t_m: 12, t_n: 12 };

    let mut table = Table::new(&["P_thresh", "phi x psi", "T_p", "certified P", "MC detect", "pipeline NMI"]);
    for p_thresh in [0.5, 0.8, 0.95, 0.99, 0.999] {
        let cfg = PlannerConfig { p_thresh, prior, candidate_sizes: vec![192, 256, 384], ..Default::default() };
        let pl = plan(rows, cols, &cfg);
        let certified = detection_probability(&prior, pl.phi, pl.psi, pl.m, pl.n, pl.t_p);
        let mc = monte_carlo_detection(rows, prior.row_fraction, pl.phi, pl.m, prior.t_m, pl.t_p, 400);

        let ds = planted_dense(&PlantedConfig {
            rows,
            cols,
            row_clusters: 4,
            col_clusters: 4,
            noise: 0.2,
            signal: 1.3,
            seed: 6001,
            ..Default::default()
        });
        let out = Lamc::new(LamcConfig {
            k: 4,
            planner: cfg,
            ..Default::default()
        })
        .run(&ds.matrix)
        .unwrap();
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);

        table.row(&[
            format!("{p_thresh}"),
            format!("{}x{}", pl.phi, pl.psi),
            pl.t_p.to_string(),
            format!("{certified:.4}"),
            format!("{mc:.4}"),
            format!("{:.4}", s.nmi()),
        ]);
    }
    println!("{}", table.render());
    println!("Invariant: MC detect ≥ certified P (Theorem 1 is a lower bound).");
}
