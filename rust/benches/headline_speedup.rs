//! Headline claim: "approximately 83% decrease [in running time] for
//! dense matrices and up to 30% for sparse matrices".
//!
//! Dense: LAMC-SCC vs classical SCC (exact SVD) on the Amazon-1000
//! shape. Sparse: LAMC-PNMTF vs PNMTF on the CLASSIC4 shape.
//! Reports the measured reduction next to the paper's number.
//!
//! Run: `cargo bench --bench headline_speedup [-- --json OUT.json]` —
//! the JSON mode is what CI's perf-smoke job folds into `BENCH_10.json`
//! and feeds to `scripts/bench_compare.py` for the perf-trajectory
//! regression gate (tolerance policy in docs/BENCHMARKS.md).

use lamc::bench_util::json_arg_path;
use lamc::data::datasets;
use lamc::harness::{run_method, Method};

fn scale() -> f64 {
    std::env::var("LAMC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

fn reduction(base: f64, ours: f64) -> f64 {
    100.0 * (1.0 - ours / base)
}

fn main() {
    let scale = scale();
    println!("== Headline speedups (scale {scale}) ==\n");

    // Dense: SCC vs LAMC-SCC.
    let rows = ((1000.0 * scale) as usize).max(300);
    let ds = datasets::build("amazon1000", Some(rows), 7).unwrap();
    eprintln!("dense workload {}x{}", ds.matrix.rows(), ds.matrix.cols());
    let scc = run_method(Method::Scc, &ds, 5, 7, f64::MAX).unwrap();
    let lamc_scc = run_method(Method::LamcScc, &ds, 5, 7, f64::MAX).unwrap();
    let (t_scc, t_lamc) = (scc.time_s.unwrap(), lamc_scc.time_s.unwrap());
    println!("dense  ({}x{}):", ds.matrix.rows(), ds.matrix.cols());
    println!("  SCC       : {t_scc:>9.3} s  (NMI {})", scc.nmi_cell());
    println!("  LAMC-SCC  : {t_lamc:>9.3} s  (NMI {})", lamc_scc.nmi_cell());
    println!("  reduction : {:.1}%   (paper: ~83%)", reduction(t_scc, t_lamc));

    // Sparse: PNMTF vs LAMC-PNMTF.
    let rows = ((18_000.0 * scale * 0.5) as usize).max(2000);
    let ds = datasets::build("classic4", Some(rows), 7).unwrap();
    eprintln!("sparse workload {}x{}", ds.matrix.rows(), ds.matrix.cols());
    let pnmtf = run_method(Method::Pnmtf, &ds, 4, 7, f64::MAX).unwrap();
    let lamc_pnmtf = run_method(Method::LamcPnmtf, &ds, 4, 7, f64::MAX).unwrap();
    let (t_p, t_lp) = (pnmtf.time_s.unwrap(), lamc_pnmtf.time_s.unwrap());
    println!("\nsparse ({}x{}, {:.2}% nnz):", ds.matrix.rows(), ds.matrix.cols(),
             100.0 * ds.matrix.nnz() as f64 / (ds.matrix.rows() * ds.matrix.cols()) as f64);
    println!("  PNMTF      : {t_p:>9.3} s  (NMI {})", pnmtf.nmi_cell());
    println!("  LAMC-PNMTF : {t_lp:>9.3} s  (NMI {})", lamc_pnmtf.nmi_cell());
    println!("  reduction  : {:.1}%   (paper: up to 30%)", reduction(t_p, t_lp));

    if let Some(json_out) = json_arg_path() {
        let json = format!(
            "{{\n  \"bench\": \"headline_speedup\",\n  \"scale\": {scale},\n  \
             \"t_scc_dense_s\": {t_scc:.6},\n  \"t_lamc_scc_dense_s\": {t_lamc:.6},\n  \
             \"reduction_dense_pct\": {:.4},\n  \
             \"t_pnmtf_sparse_s\": {t_p:.6},\n  \"t_lamc_pnmtf_sparse_s\": {t_lp:.6},\n  \
             \"reduction_sparse_pct\": {:.4}\n}}\n",
            reduction(t_scc, t_lamc),
            reduction(t_p, t_lp),
        );
        std::fs::write(&json_out, json).unwrap();
        println!("wrote {json_out:?}");
    }
}
