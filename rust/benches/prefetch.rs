//! Prefetch on/off: does overlapping chunk I/O with compute make a
//! store-backed `Lamc::run` measurably faster?
//!
//! The shape is the store's worst case (and the paper's target access
//! pattern): a **col-heavy** grid — ψ-wide blocks much narrower than
//! the matrix — over a row-band (LAMC2) store, so every gather decodes
//! full-width bands. With prefetch off, each band's first touch blocks
//! a worker: decode serializes in front of co-clustering. With prefetch
//! on, the scheduler hands the reader each round's plan up front and a
//! background thread decodes bands while blocks compute.
//!
//! The atom is a fixed-cost probe (a few arithmetic passes per block,
//! deterministic labels) sized so compute and decode are the same order
//! of magnitude — the regime where overlap pays. SCC-dominated runs see
//! a smaller *relative* win (compute dwarfs I/O); the absolute
//! I/O-hiding is the same. One worker thread is used so the comparison
//! is overlap vs no-overlap, not core-count noise.
//!
//! Run: `cargo bench --bench prefetch [-- --json OUT.json]` — the JSON
//! mode is what CI's perf-smoke job records as `BENCH_10.json` (schema
//! in docs/BENCHMARKS.md).

use std::sync::Arc;

use lamc::bench_util::{bench, json_arg_path, Table};
use lamc::cocluster::{AtomCocluster, CoclusterResult};
use lamc::matrix::{DenseMatrix, Matrix};
use lamc::partition::{CoclusterPrior, PlannerConfig};
use lamc::rng::Xoshiro256;
use lamc::store::{pack_matrix, StoreReader};
use lamc::{Lamc, LamcConfig};

const ROWS: usize = 2048;
const COLS: usize = 4096;
const CHUNK_ROWS: usize = 256;
const HOT_BUDGET: usize = 256 << 20;
const PREFETCH_BUDGET: usize = 64 << 20;

/// Fixed-cost probe atom: `passes` fused multiply-add sweeps over the
/// block, deterministic labels. Calibrates compute against decode so
/// the bench isolates the I/O pipeline, not SCC's linear algebra.
struct ProbeAtom {
    passes: usize,
}

impl AtomCocluster for ProbeAtom {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn cocluster(&self, a: &Matrix, k: usize, _rng: &mut Xoshiro256) -> CoclusterResult {
        let mut acc = 0f32;
        if let Matrix::Dense(d) = a {
            for _ in 0..self.passes {
                for &v in d.data() {
                    acc = acc.mul_add(0.999_9, v);
                }
            }
        }
        let k = k.max(1);
        CoclusterResult {
            row_labels: (0..a.rows()).map(|i| i % k).collect(),
            col_labels: (0..a.cols()).map(|j| j % k).collect(),
            k,
            // Keeps the passes observable so they cannot be elided.
            objective: std::hint::black_box(acc) as f64,
        }
    }
}

fn config() -> LamcConfig {
    LamcConfig {
        k: 4,
        atom_override: Some(Arc::new(ProbeAtom { passes: 6 })),
        planner: PlannerConfig {
            // ψ = 256 of 4096 columns: every block is col-heavy
            // relative to the full-width row bands it decodes.
            candidate_sizes: vec![256],
            // Generous prior: certifies with few samplings, so the
            // bench measures the I/O pipeline, not T_p.
            prior: CoclusterPrior { row_fraction: 0.5, col_fraction: 0.5, t_m: 2, t_n: 2 },
            max_samplings: 4,
            ..Default::default()
        },
        workers: 1,
        seed: 0xBE7C,
        ..Default::default()
    }
}

fn main() {
    println!(
        "== prefetch on/off: {ROWS} x {COLS} dense, lamc2 {CHUNK_ROWS}-row bands, col-heavy psi=256 grid ==\n"
    );
    let mut rng = Xoshiro256::seed_from(0x9E7F);
    let matrix = Matrix::Dense(DenseMatrix::randn(ROWS, COLS, &mut rng));
    let dir = std::env::temp_dir().join("lamc_bench_prefetch");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.lamc2");
    pack_matrix(&matrix, &path, CHUNK_ROWS).unwrap();

    let lamc = Lamc::new(config());
    let mut table = Table::new(&["prefetch", "median", "speedup"]);
    let mut medians = Vec::new();
    let mut plan_line = String::new();
    for (label, prefetch_budget) in [("off", 0usize), ("on", PREFETCH_BUDGET)] {
        // A fresh reader per run: timing covers cold caches every time
        // (a warm hot-cache run would measure nothing but compute).
        let t = bench(1, 3, || {
            let reader = StoreReader::open_with_budgets(&path, HOT_BUDGET, prefetch_budget).unwrap();
            let out = lamc.run(&reader).unwrap();
            std::hint::black_box(out.k);
        });
        medians.push((label, t));
        let speedup = medians[0].1.median_s / t.median_s;
        table.row(&[label.to_string(), t.format(), format!("{speedup:.2}x")]);
    }
    // One instrumented run for the counters the JSON records.
    let reader = StoreReader::open_with_budgets(&path, HOT_BUDGET, PREFETCH_BUDGET).unwrap();
    let out = lamc.run(&reader).unwrap();
    plan_line.push_str(&format!(
        "{}x{} blocks of {}x{}, T_p={}",
        out.plan.m, out.plan.n, out.plan.phi, out.plan.psi, out.plan.t_p
    ));
    let io = reader.io_counters();

    println!("{}", table.render());
    println!("plan: {plan_line}");
    println!(
        "instrumented run: prefetch_issued={} prefetch_hits={} prefetch_wasted_bytes={} chunks_read={}",
        io.prefetch_issued, io.prefetch_hits, io.prefetch_wasted_bytes, io.chunks_read
    );

    if let Some(json_out) = json_arg_path() {
        let (off, on) = (medians[0].1, medians[1].1);
        let json = format!(
            "{{\n  \"bench\": \"prefetch\",\n  \"rows\": {ROWS},\n  \"cols\": {COLS},\n  \
             \"store\": \"lamc2 row-band, {CHUNK_ROWS}-row bands\",\n  \
             \"shape\": \"col-heavy (psi=256 of {COLS} cols)\",\n  \"plan\": \"{plan_line}\",\n  \
             \"prefetch_off\": {{\"median_s\": {:.6}, \"min_s\": {:.6}, \"runs\": {}}},\n  \
             \"prefetch_on\": {{\"median_s\": {:.6}, \"min_s\": {:.6}, \"runs\": {}}},\n  \
             \"speedup\": {:.4},\n  \
             \"prefetch_issued\": {},\n  \"prefetch_hits\": {},\n  \"prefetch_wasted_bytes\": {}\n}}\n",
            off.median_s,
            off.min_s,
            off.runs,
            on.median_s,
            on.min_s,
            on.runs,
            off.median_s / on.median_s,
            io.prefetch_issued,
            io.prefetch_hits,
            io.prefetch_wasted_bytes,
        );
        std::fs::write(&json_out, json).unwrap();
        println!("wrote {json_out:?}");
    }
}
