//! Integration: the long-lived service end to end over real TCP.
//!
//! Drives a `ServiceServer` on an ephemeral port with blocking
//! `ServiceClient`s: submission, polling, result retrieval, the result
//! cache (an identical second submission must be a hit with identical
//! labels and no extra pipeline work), concurrent clients with
//! independent seeds, protocol-level error handling, and the streaming
//! append path (`APPEND` → incremental job → `SUBSCRIBE` feed).

use std::time::Duration;

use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::pipeline::Lamc;
use lamc::service::{JobSpec, ServiceClient, ServiceConfig, ServiceManager, ServiceServer};
use lamc::store::MatrixRef;

fn planted(seed: u64) -> lamc::matrix::Matrix {
    planted_dense(&PlantedConfig {
        rows: 96,
        cols: 80,
        row_clusters: 3,
        col_clusters: 3,
        noise: 0.1,
        signal: 1.5,
        seed,
        ..Default::default()
    })
    .matrix
}

fn spawn_service(runners: usize) -> (ServiceServer, ServiceManager) {
    let manager = ServiceManager::new(ServiceConfig {
        runners,
        queue_capacity: 16,
        cache_capacity_bytes: 16 << 20,
        ..Default::default()
    });
    manager.register("planted", planted(11));
    let server = ServiceServer::spawn("127.0.0.1:0", manager.clone()).expect("bind ephemeral port");
    (server, manager)
}

const WAIT: Duration = Duration::from_secs(180);

#[test]
fn tcp_round_trip_second_submission_hits_cache() {
    let (server, manager) = spawn_service(1);
    let mut client = ServiceClient::connect(server.addr()).unwrap();
    let spec = JobSpec { matrix: "planted".into(), k: 3, seed: 7, ..Default::default() };

    let id1 = client.submit(&spec).unwrap();
    let out1 = client.wait(id1, WAIT).unwrap();
    assert_eq!(out1.row_labels.len(), 96);
    assert_eq!(out1.col_labels.len(), 80);
    assert!(!out1.cached, "first run computes");

    let stats1 = client.stats().unwrap();
    assert_eq!(stats1["cache_hits"], "0");
    assert_eq!(stats1["cache_misses"], "1");
    let blocks_after_first: u64 = stats1["blocks_total"].parse().unwrap();
    assert!(blocks_after_first > 0, "pipeline ran blocks");

    // Identical resubmission: a distinct job id, served from cache.
    let id2 = client.submit(&spec).unwrap();
    assert_ne!(id1, id2);
    let out2 = client.wait(id2, WAIT).unwrap();
    assert!(out2.cached, "second identical submission must hit the cache");
    assert_eq!(out1.row_labels, out2.row_labels, "cached labels identical");
    assert_eq!(out1.col_labels, out2.col_labels);
    assert_eq!(out1.k, out2.k);

    let stats2 = client.stats().unwrap();
    assert_eq!(stats2["cache_hits"], "1", "hit counter incremented");
    assert_eq!(stats2["cache_misses"], "1");
    assert_eq!(
        stats2["blocks_total"].parse::<u64>().unwrap(),
        blocks_after_first,
        "cache hit must not re-run the pipeline"
    );
    assert_eq!(stats2["jobs_done"], "2");

    // STATUS agrees with the result path.
    let status = client.status(id2).unwrap();
    assert_eq!(status.state, lamc::service::JobState::Done);
    assert!(status.cached);

    client.shutdown().unwrap();
    server.join();
    manager.shutdown();
}

#[test]
fn different_config_misses_cache() {
    let (server, manager) = spawn_service(1);
    let mut client = ServiceClient::connect(server.addr()).unwrap();
    let spec = JobSpec { matrix: "planted".into(), k: 3, seed: 7, ..Default::default() };
    let a = client.submit(&spec).unwrap();
    client.wait(a, WAIT).unwrap();
    // Same matrix, different seed: must not be served from the cache.
    let b = client.submit(&JobSpec { seed: 8, ..spec }).unwrap();
    let out = client.wait(b, WAIT).unwrap();
    assert!(!out.cached);
    let stats = client.stats().unwrap();
    assert_eq!(stats["cache_hits"], "0");
    assert_eq!(stats["cache_misses"], "2");
    client.shutdown().unwrap();
    server.join();
    manager.shutdown();
}

#[test]
fn concurrent_clients_get_independent_deterministic_results() {
    let (server, manager) = spawn_service(2);
    let addr = server.addr();

    // Two clients race jobs with different seeds through the shared
    // worker pool and runner crew.
    let mut handles = Vec::new();
    for seed in [101u64, 202] {
        handles.push(std::thread::spawn(move || {
            let mut client = ServiceClient::connect(addr).unwrap();
            let spec = JobSpec { matrix: "planted".into(), k: 3, seed, ..Default::default() };
            let id = client.submit(&spec).unwrap();
            (spec, client.wait(id, WAIT).unwrap())
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Each service answer must equal a fresh local run of the exact
    // configuration the service used (per-job seeds are scheduling-order
    // independent, so concurrency cannot leak between the two jobs).
    let matrix = planted(11);
    for (spec, reply) in &results {
        let local = Lamc::new(spec.lamc_config().unwrap()).run(&matrix).unwrap();
        assert_eq!(&local.row_labels, &reply.row_labels, "seed {}", spec.seed);
        assert_eq!(&local.col_labels, &reply.col_labels, "seed {}", spec.seed);
        assert_eq!(local.k, reply.k);
    }

    client_shutdown(addr);
    server.join();
    manager.shutdown();
}

fn client_shutdown(addr: std::net::SocketAddr) {
    let mut client = ServiceClient::connect(addr).unwrap();
    client.shutdown().unwrap();
}

#[test]
fn protocol_errors_are_reported_not_fatal() {
    let (server, manager) = spawn_service(1);
    let mut client = ServiceClient::connect(server.addr()).unwrap();

    // Unknown matrix → ERR, connection stays usable.
    let err = client
        .submit(&JobSpec { matrix: "ghost".into(), ..Default::default() })
        .unwrap_err()
        .to_string();
    assert!(err.contains("no matrix named"), "{err}");

    // Unknown job id → the typed `no-such-job` error, same text from
    // every job verb, with the offending id embedded.
    let err = client.status(999).unwrap_err().to_string();
    assert!(err.contains("no-such-job id=999"), "typed STATUS error: {err}");
    let err = client.result(999).unwrap_err().to_string();
    assert!(err.contains("no-such-job id=999"), "typed RESULT error: {err}");
    let err = client.spans(999).unwrap_err().to_string();
    assert!(err.contains("no-such-job id=999"), "typed SPANS error: {err}");

    // LOAD a small dataset over the wire, then submit against it.
    let (rows, cols) = client.load_dataset("tiny", "classic4", Some(300), 5).unwrap();
    assert_eq!((rows, cols), (300, 1000));
    let id = client
        .submit(&JobSpec { matrix: "tiny".into(), k: 4, ..Default::default() })
        .unwrap();
    let out = client.wait(id, WAIT).unwrap();
    assert_eq!(out.row_labels.len(), 300);
    assert_eq!(out.col_labels.len(), 1000);

    client.shutdown().unwrap();
    server.join();
    manager.shutdown();
}

#[test]
fn append_triggers_incremental_job_and_feed_events() {
    let dir = std::env::temp_dir().join("lamc_integration_service").join("append_flow");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let matrix = planted(11);
    let cols = matrix.cols();
    let store = dir.join("planted.lamc2");
    lamc::store::pack_matrix(&matrix, &store, 32).unwrap();

    let manager = ServiceManager::new(ServiceConfig {
        runners: 1,
        queue_capacity: 16,
        cache_capacity_bytes: 16 << 20,
        ..Default::default()
    });
    manager.register_store("grow", &store).unwrap();
    let server = ServiceServer::spawn("127.0.0.1:0", manager.clone()).expect("bind ephemeral port");
    let mut client = ServiceClient::connect(server.addr()).unwrap();

    // Negotiate the unified framing; SUBSCRIBE ships only on it.
    client.hello().unwrap();
    assert!(client.is_binary(), "unified framing negotiated");

    let spec = JobSpec { matrix: "grow".into(), k: 3, seed: 7, ..Default::default() };
    let id = client.submit(&spec).unwrap();
    let first = client.wait(id, WAIT).unwrap();
    assert_eq!(first.row_labels.len(), 96);

    // The feed so far holds the first job's label update.
    let (events, cursor) = client.subscribe("grow", None).unwrap();
    assert!(events.iter().any(|e| e.contains("kind=LabelsUpdated")), "{events:?}");
    assert!(cursor.is_some(), "non-empty page advances the cursor");

    // Append a batch of fresh rows over the wire; the server grows the
    // store in place and queues an incremental re-clustering job from
    // the retained basis.
    let mut rng = lamc::rng::Xoshiro256::seed_from(0xA11D);
    let add = 8usize;
    let fresh: Vec<f32> = (0..add * cols).map(|_| rng.next_f32() - 0.5).collect();
    let reply = client.append("grow", add, cols, &fresh).unwrap();
    assert_eq!(reply.total_rows, 96 + add);
    let job = reply.job.expect("incremental job queued (basis retained)");
    let inc = client.wait(job, WAIT).unwrap();
    assert!(!inc.cached, "append invalidates the cache via the fingerprint swap");
    assert_eq!(inc.row_labels.len(), 96 + add);

    // The feed streamed the append and the fresh labels past our cursor.
    let (events, _) = client.subscribe("grow", cursor).unwrap();
    assert!(events.iter().any(|e| e.contains("kind=MatrixAppended")), "{events:?}");
    assert!(events.iter().any(|e| e.contains("kind=LabelsUpdated")), "{events:?}");

    // Incremental labels are byte-identical to a from-scratch run over
    // the grown store.
    let grown = MatrixRef::open_store(&store).unwrap();
    assert_eq!(grown.rows(), 96 + add);
    let local = Lamc::new(spec.lamc_config().unwrap()).run(&grown).unwrap();
    assert_eq!(local.row_labels, inc.row_labels);
    assert_eq!(local.col_labels, inc.col_labels);
    assert_eq!(local.k, inc.k);

    client.shutdown().unwrap();
    server.join();
    manager.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
