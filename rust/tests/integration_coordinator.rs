//! Integration: coordinator scheduling semantics and failure injection.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;
use lamc::cocluster::{AtomCocluster, CoclusterResult, SpectralCocluster};
use lamc::coordinator::{run_rounds, BlockExecutor, Router, SchedulerConfig, Stats};
use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::matrix::{DenseMatrix, Matrix};
use lamc::partition::{sample_partition, PartitionPlan};
use lamc::rng::Xoshiro256;

fn plan(phi: usize, psi: usize, m: usize, n: usize, t_p: usize) -> PartitionPlan {
    PartitionPlan { phi, psi, m, n, t_p, certified_probability: 1.0, estimated_cost: 0.0 }
}

/// Atom that counts invocations and can fail on demand — used to test
/// scheduler accounting and error propagation.
struct ProbeAtom {
    calls: AtomicUsize,
    fail_on: Option<usize>,
}

impl AtomCocluster for ProbeAtom {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn cocluster(&self, a: &Matrix, k: usize, _rng: &mut Xoshiro256) -> CoclusterResult {
        let n = self.calls.fetch_add(1, Ordering::SeqCst);
        if Some(n) == self.fail_on {
            // AtomCocluster cannot return Err; simulate a *degenerate*
            // result instead (the scheduler-level error path is tested
            // via FailingExecutor below).
            return CoclusterResult { row_labels: vec![0; a.rows()], col_labels: vec![0; a.cols()], k: 1, objective: f64::INFINITY };
        }
        CoclusterResult {
            row_labels: (0..a.rows()).map(|i| i % k).collect(),
            col_labels: (0..a.cols()).map(|j| j % k).collect(),
            k,
            objective: 1.0,
        }
    }
}

struct FailingExecutor;

impl BlockExecutor for FailingExecutor {
    fn name(&self) -> &str {
        "failing"
    }

    fn execute(&self, _block: &DenseMatrix, _k: usize, seed: u64) -> Result<CoclusterResult> {
        anyhow::bail!("injected failure (seed {seed})")
    }
}

#[test]
fn scheduler_runs_every_job_exactly_once() {
    let ds = planted_dense(&PlantedConfig { rows: 200, cols: 160, seed: 3001, ..Default::default() });
    let atom = Arc::new(ProbeAtom { calls: AtomicUsize::new(0), fail_on: None });
    let router = Router::native_only(atom.clone());
    let mut rng = Xoshiro256::seed_from(5);
    let rounds = sample_partition(200, 160, &plan(50, 40, 4, 4, 3), &mut rng);
    let stats = Stats::default();
    let out = run_rounds(&ds.matrix, &rounds, &router, &SchedulerConfig { k: 2, ..Default::default() }, &stats).unwrap();
    assert_eq!(out.len(), 48);
    assert_eq!(atom.calls.load(Ordering::SeqCst), 48);
    assert_eq!(stats.snapshot().blocks_total, 48);
    assert_eq!(stats.snapshot().blocks_native, 48);
}

#[test]
fn scheduler_telemetry_tracks_time() {
    let ds = planted_dense(&PlantedConfig { rows: 150, cols: 150, seed: 3002, ..Default::default() });
    let router = Router::native_only(Arc::new(SpectralCocluster::default()));
    let mut rng = Xoshiro256::seed_from(6);
    let rounds = sample_partition(150, 150, &plan(75, 75, 2, 2, 1), &mut rng);
    let stats = Stats::default();
    run_rounds(&ds.matrix, &rounds, &router, &SchedulerConfig::default(), &stats).unwrap();
    let snap = stats.snapshot();
    assert!(snap.gather_s > 0.0, "gather time not recorded");
    assert!(snap.exec_s > 0.0, "exec time not recorded");
}

#[test]
fn results_independent_of_worker_count() {
    let ds = planted_dense(&PlantedConfig { rows: 180, cols: 140, seed: 3003, ..Default::default() });
    let router = Router::native_only(Arc::new(SpectralCocluster::default()));
    let mut rng = Xoshiro256::seed_from(7);
    let rounds = sample_partition(180, 140, &plan(60, 70, 3, 2, 2), &mut rng);
    let mut outputs = Vec::new();
    for workers in [1, 2, 8] {
        let out = run_rounds(
            &ds.matrix,
            &rounds,
            &router,
            &SchedulerConfig { workers, k: 3, seed: 99 },
            &Stats::default(),
        )
        .unwrap();
        outputs.push(out);
    }
    for w in 1..outputs.len() {
        assert_eq!(outputs[0].len(), outputs[w].len());
        for (a, b) in outputs[0].iter().zip(&outputs[w]) {
            assert_eq!(a.1, b.1, "results differ between worker counts");
        }
    }
}

#[test]
fn executor_errors_propagate() {
    let ds = planted_dense(&PlantedConfig { rows: 100, cols: 100, seed: 3004, ..Default::default() });
    let router = Router::native_only(Arc::new(SpectralCocluster::default()));
    // Directly exercise the failing executor through the trait.
    let failing = FailingExecutor;
    assert!(failing.execute(&ds.matrix.to_dense(), 2, 0).is_err());
    // And the healthy router still succeeds on the same input.
    let mut rng = Xoshiro256::seed_from(8);
    let rounds = sample_partition(100, 100, &plan(50, 50, 2, 2, 1), &mut rng);
    let out = run_rounds(&ds.matrix, &rounds, &router, &SchedulerConfig::default(), &Stats::default()).unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn degenerate_atom_results_survive_merge() {
    // A block returning a single giant cluster must not break the
    // pipeline (robustness to "model uncertainty", paper §IV-D).
    let ds = planted_dense(&PlantedConfig { rows: 160, cols: 160, seed: 3005, ..Default::default() });
    let atom = Arc::new(ProbeAtom { calls: AtomicUsize::new(0), fail_on: Some(2) });
    let router = Router::native_only(atom);
    let mut rng = Xoshiro256::seed_from(9);
    let rounds = sample_partition(160, 160, &plan(80, 80, 2, 2, 2), &mut rng);
    let out = run_rounds(&ds.matrix, &rounds, &router, &SchedulerConfig { k: 2, ..Default::default() }, &Stats::default()).unwrap();
    let atoms: Vec<_> = out
        .iter()
        .flat_map(|(job, res)| lamc::pipeline::Lamc::block_to_atoms(job, res))
        .collect();
    let merged = lamc::merge::merge_coclusters(atoms, &lamc::merge::MergeConfig::default());
    let (rl, cl, k) = lamc::merge::extract_labels(&merged, 160, 160);
    assert_eq!(rl.len(), 160);
    assert_eq!(cl.len(), 160);
    assert!(k >= 1);
}

#[test]
fn seeds_differ_across_rounds_same_grid() {
    use lamc::coordinator::scheduler::job_seed;
    use lamc::partition::BlockJob;
    let mk = |round, grid| BlockJob { round, grid, rows: vec![], cols: vec![] };
    let mut seen = std::collections::HashSet::new();
    for round in 0..4 {
        for i in 0..4 {
            for j in 0..4 {
                assert!(seen.insert(job_seed(42, &mk(round, (i, j)))), "seed collision at {round}/{i}/{j}");
            }
        }
    }
}
