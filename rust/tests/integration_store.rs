//! Integration: the chunked store end to end — on-disk round trips, the
//! out-of-core pipeline path, ingest-then-serve over TCP, and result
//! persistence across a service restart.
//!
//! The two headline assertions (this PR's acceptance criteria):
//!
//! 1. `pipeline::run` on a store-backed matrix produces **byte-identical
//!    co-cluster labels** to the in-memory path for the same seed and
//!    config, while reading only row-band chunks (never `read_all`).
//! 2. Result-cache contents survive a `ServiceManager` restart when a
//!    store root is configured.

use std::path::PathBuf;
use std::time::Duration;

use lamc::data::synthetic::{planted_dense, planted_sparse, PlantedConfig};
use lamc::matrix::Matrix;
use lamc::pipeline::{Lamc, LamcConfig};
use lamc::rng::Xoshiro256;
use lamc::service::{JobSpec, ServiceClient, ServiceConfig, ServiceManager, ServiceServer};
use lamc::store::{pack_matrix, repack, MatrixRef, RepackOptions, StoreReader};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("lamc_integration_store").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn planted(seed: u64, sparse: bool) -> Matrix {
    let cfg = PlantedConfig {
        rows: 300,
        cols: 240,
        row_clusters: 3,
        col_clusters: 3,
        noise: 0.1,
        signal: 1.5,
        density: 0.05,
        seed,
    };
    if sparse { planted_sparse(&cfg).matrix } else { planted_dense(&cfg).matrix }
}

fn fast_config(k: usize, seed: u64) -> LamcConfig {
    let mut cfg = LamcConfig { k, seed, ..Default::default() };
    cfg.planner.candidate_sizes = vec![96, 128];
    cfg.planner.max_samplings = 6;
    cfg
}

#[test]
fn store_backed_pipeline_matches_in_memory_bit_for_bit() {
    for (case, sparse) in [("dense", false), ("sparse", true)] {
        let dir = tmp_dir(&format!("pipeline_{case}"));
        let matrix = planted(901, sparse);
        let path = dir.join("m.lamc2");
        pack_matrix(&matrix, &path, 64).unwrap();
        let stored = MatrixRef::open_store(&path).unwrap();

        let lamc = Lamc::new(fast_config(3, 0x5101));
        let in_mem = lamc.run(&matrix).unwrap();
        let out_of_core = lamc.run(&stored).unwrap();

        assert_eq!(in_mem.row_labels, out_of_core.row_labels, "{case}: row labels");
        assert_eq!(in_mem.col_labels, out_of_core.col_labels, "{case}: col labels");
        assert_eq!(in_mem.k, out_of_core.k, "{case}: k");
        assert_eq!(in_mem.plan, out_of_core.plan, "{case}: partition plan");

        // The out-of-core run streamed tiles; it never materialized the
        // matrix (tiles_served counts gathers, and the bands read are
        // exactly the store's bands, possibly repeatedly — bounded by
        // the reader's cache, not matrix size).
        match &stored {
            MatrixRef::Stored(reader) => {
                assert!(reader.tiles_served() > 0, "{case}: blocks streamed from disk");
                assert!(
                    reader.chunks_read() + reader.cache_hits() >= reader.tiles_served(),
                    "{case}: every tile touched at least one band"
                );
            }
            MatrixRef::InMem(_) => unreachable!(),
        }
    }
}

#[test]
fn store_backed_baseline_matches_in_memory() {
    let dir = tmp_dir("baseline");
    let matrix = planted(902, false);
    let path = dir.join("m.lamc2");
    pack_matrix(&matrix, &path, 64).unwrap();
    let stored = MatrixRef::open_store(&path).unwrap();
    let lamc = Lamc::new(fast_config(3, 0x5102));
    let a = lamc.run_baseline(&matrix).unwrap();
    let b = lamc.run_baseline(&stored).unwrap();
    assert_eq!(a.row_labels, b.row_labels);
    assert_eq!(a.col_labels, b.col_labels);
}

#[test]
fn random_tiles_equal_in_memory_slices_property() {
    // Property sweep across layouts, band heights and seeds: a store
    // tile must equal the in-memory gather for arbitrary index sets.
    let mut rng = Xoshiro256::seed_from(777);
    for sparse in [false, true] {
        for chunk_rows in [5, 32, 512] {
            let dir = tmp_dir(&format!("prop_{sparse}_{chunk_rows}"));
            let matrix = planted(900 + chunk_rows as u64, sparse);
            let path = dir.join("m.lamc2");
            pack_matrix(&matrix, &path, chunk_rows).unwrap();
            let reader = StoreReader::open(&path).unwrap();
            for _ in 0..10 {
                let nr = 1 + rng.next_below(40);
                let nc = 1 + rng.next_below(30);
                let rows = rng.sample_indices(matrix.rows(), nr);
                let cols = rng.sample_indices(matrix.cols(), nc);
                assert_eq!(
                    reader.tile(&rows, &cols).unwrap().data(),
                    matrix.gather_block(&rows, &cols).data(),
                    "sparse={sparse} chunk_rows={chunk_rows}"
                );
            }
        }
    }
}

#[test]
fn ingest_then_serve_through_tcp() {
    let dir = tmp_dir("serve");
    let matrix = planted(903, false);
    let store_path = dir.join("planted.lamc2");
    pack_matrix(&matrix, &store_path, 64).unwrap();

    let manager = ServiceManager::new(ServiceConfig {
        runners: 1,
        queue_capacity: 8,
        cache_capacity_bytes: 8 << 20,
        ..Default::default()
    });
    let server = ServiceServer::spawn("127.0.0.1:0", manager.clone()).unwrap();
    let mut client = ServiceClient::connect(server.addr()).unwrap();

    // LOAD the store over the wire, then submit against it.
    let (rows, cols) = client
        .load_store("planted", store_path.to_str().unwrap())
        .unwrap();
    assert_eq!((rows, cols), (300, 240));

    let spec = JobSpec { matrix: "planted".into(), k: 3, seed: 904, ..Default::default() };
    let id = client.submit(&spec).unwrap();
    let reply = client.wait(id, Duration::from_secs(180)).unwrap();
    assert_eq!(reply.row_labels.len(), 300);
    assert_eq!(reply.col_labels.len(), 240);

    // The service answer (shipped over the binary RESULTB framing) must
    // equal a local in-memory run of the identical configuration.
    let local = Lamc::new(spec.lamc_config().unwrap()).run(&matrix).unwrap();
    assert_eq!(local.row_labels, reply.row_labels);
    assert_eq!(local.col_labels, reply.col_labels);
    assert_eq!(local.k, reply.k);

    client.shutdown().unwrap();
    server.join();
    manager.shutdown();
}

#[test]
fn cache_persists_across_manager_restart() {
    let root = tmp_dir("restart_root");
    let matrix = planted(905, false);
    let spec = JobSpec { matrix: "m".into(), k: 3, seed: 906, ..Default::default() };

    let config = || ServiceConfig {
        runners: 1,
        queue_capacity: 8,
        cache_capacity_bytes: 8 << 20,
        store_root: Some(root.clone()),
        ..Default::default()
    };

    // First life: compute and (implicitly) spill the result.
    let first_labels = {
        let mgr = ServiceManager::new(config());
        mgr.register("m", matrix.clone());
        let id = mgr.submit(spec.clone()).unwrap();
        let record = mgr.wait(id, Duration::from_secs(180)).expect("job finished");
        assert_eq!(record.state, lamc::service::JobState::Done);
        assert!(!record.cached, "first run computes");
        mgr.shutdown();
        record.result.unwrap()
    };

    // Second life: same store root, fresh process state. The identical
    // submission must be served from the persisted cache — no pipeline.
    let mgr = ServiceManager::new(config());
    mgr.register("m", matrix);
    let id = mgr.submit(spec).unwrap();
    let record = mgr.wait(id, Duration::from_secs(180)).expect("job finished");
    assert_eq!(record.state, lamc::service::JobState::Done);
    assert!(record.cached, "restart survivor must be a cache hit");
    let out = record.result.unwrap();
    assert_eq!(out.row_labels, first_labels.row_labels);
    assert_eq!(out.col_labels, first_labels.col_labels);
    assert_eq!(out.k, first_labels.k);
    let snap = mgr.stats().snapshot();
    assert_eq!(snap.cache_hits, 1);
    assert_eq!(snap.blocks_total, 0, "no block ever executed in the second life");
    assert_eq!(mgr.cache().disk_hits(), 1);
    mgr.shutdown();
}

#[test]
fn repacked_store_serves_identical_labels_and_hits_the_same_cache() {
    // pack (row-band) → repack (tiled) → submit against both: labels
    // byte-identical to the in-memory run, and because repack preserves
    // the content fingerprint, the second submission is a cache hit —
    // re-chunking never invalidates cached results.
    let dir = tmp_dir("repack_serve");
    let matrix = planted(910, false);
    let band_path = dir.join("m.lamc2");
    let tiled_path = dir.join("m.lamc3");
    let band_summary = pack_matrix(&matrix, &band_path, 64).unwrap();
    let tiled_summary = repack(
        &band_path,
        &tiled_path,
        &RepackOptions { chunk_rows: 48, chunk_cols: Some(80), ..Default::default() },
    )
    .unwrap();
    assert!(tiled_summary.tiled);
    assert_eq!(tiled_summary.fingerprint, band_summary.fingerprint, "identity preserved");

    // Labels from the repacked store equal the in-memory run.
    let lamc = Lamc::new(fast_config(3, 0x5103));
    let in_mem = lamc.run(&matrix).unwrap();
    let stored = MatrixRef::open_store(&tiled_path).unwrap();
    let out_of_core = lamc.run(&stored).unwrap();
    assert_eq!(in_mem.row_labels, out_of_core.row_labels);
    assert_eq!(in_mem.col_labels, out_of_core.col_labels);
    assert_eq!(in_mem.k, out_of_core.k);

    // Same fingerprint ⇒ same cache key: a job against the repacked
    // store is answered from the result computed against the original.
    let mgr = ServiceManager::new(ServiceConfig {
        runners: 1,
        queue_capacity: 8,
        cache_capacity_bytes: 8 << 20,
        ..Default::default()
    });
    mgr.register_store("band", &band_path).unwrap();
    mgr.register_store("tiled", &tiled_path).unwrap();
    let spec = |name: &str| JobSpec { matrix: name.into(), k: 3, seed: 911, ..Default::default() };
    let a = mgr.submit(spec("band")).unwrap();
    assert!(!mgr.wait(a, Duration::from_secs(180)).unwrap().cached);
    let b = mgr.submit(spec("tiled")).unwrap();
    assert!(
        mgr.wait(b, Duration::from_secs(180)).unwrap().cached,
        "repacked store must hit the original's cache entry"
    );
    mgr.shutdown();
}

#[test]
fn repack_respects_the_reader_cache_byte_bound() {
    // The peak-memory guard: repack a matrix several times larger than
    // the reader's chunk cache and assert (via the cache counters) that
    // the byte bound held — the pass streams, it never accumulates.
    let dir = tmp_dir("repack_memory");
    let matrix = planted(912, false); // 300 x 240 dense = 288 KB of f32
    let band_path = dir.join("m.lamc2");
    let tiled_path = dir.join("m.lamc3");
    pack_matrix(&matrix, &band_path, 32).unwrap(); // one band = 30 KB
    let budget = 64 << 10; // 64 KB ≪ matrix size
    let reader = StoreReader::open_with_cache(&band_path, budget).unwrap();
    lamc::store::repack_reader(&reader, &tiled_path, 32, Some(60), lamc::store::Codec::None).unwrap();
    // The teeth of this guard: every source chunk hit disk exactly once
    // (the sweep streams, it never re-reads around a thrashing cache)…
    assert_eq!(
        reader.chunks_read() as usize,
        reader.n_chunks(),
        "sequential sweep reads each chunk exactly once"
    );
    // …and the cache actually cycled under a budget far below matrix
    // size (evictions prove the bound was binding, not just unreached;
    // cache_peak_bytes() ≤ budget holds by ByteLru construction and
    // documents which tier the bound lives in).
    assert!(reader.cache_evictions() > 0, "budget smaller than the matrix must evict");
    assert!(
        reader.cache_peak_bytes() <= budget,
        "cache peaked at {} bytes, budget {budget}",
        reader.cache_peak_bytes()
    );
    // And the repacked store still reconstructs the same matrix.
    let got = StoreReader::open(&tiled_path).unwrap().read_all().unwrap();
    match (&matrix, &got) {
        (Matrix::Dense(a), Matrix::Dense(b)) => assert_eq!(a, b),
        _ => panic!("layout changed"),
    }
}

#[test]
fn store_registration_uses_header_fingerprint_for_caching() {
    // Two registrations of the same store file — e.g. before and after a
    // restart, or under different names — must produce the same cache
    // key, without scanning payloads.
    let dir = tmp_dir("fingerprint");
    let matrix = planted(907, true);
    let path = dir.join("m.lamc2");
    let summary = pack_matrix(&matrix, &path, 32).unwrap();

    let mgr = ServiceManager::new(ServiceConfig {
        runners: 1,
        queue_capacity: 8,
        cache_capacity_bytes: 8 << 20,
        ..Default::default()
    });
    let fp_a = {
        mgr.register_store("a", &path).unwrap();
        MatrixRef::open_store(&path).unwrap().fingerprint()
    };
    assert_eq!(fp_a, summary.fingerprint, "registration fingerprint comes from the header");

    // Same content under two names: the second submission hits the
    // cache because the matrix half of the key is the content hash.
    mgr.register_store("b", &path).unwrap();
    let spec = |name: &str| JobSpec { matrix: name.into(), k: 3, seed: 908, ..Default::default() };
    let a = mgr.submit(spec("a")).unwrap();
    assert!(!mgr.wait(a, Duration::from_secs(180)).unwrap().cached);
    let b = mgr.submit(spec("b")).unwrap();
    assert!(mgr.wait(b, Duration::from_secs(180)).unwrap().cached, "same store, same key");
    mgr.shutdown();
}
