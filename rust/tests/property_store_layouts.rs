//! Layout-equivalence property harness + corruption-injection sweep
//! + 1-node-vs-N-node shard-routing equivalence harness.
//!
//! The contract every store layout — current and future — must keep:
//!
//! 1. **Byte-identical reads.** For any matrix content and any
//!    `tile(rows, cols)` query, the in-memory `Matrix`, the row-band
//!    LAMC2 reader and the tiled LAMC3 reader return the same bytes
//!    (and `read_all` reconstructs the exact matrix) — under every
//!    payload codec, with the content fingerprint codec-invariant.
//! 2. **Byte-identical co-clustering.** `Lamc::run` produces the same
//!    labels whichever backing the pipeline streams from, compressed
//!    or not.
//! 3. **Typed failure, never a panic.** Damage to any structural region
//!    of either format surfaces as the right `StoreError` variant, and
//!    `lamc inspect --verify` exits non-zero on a damaged store.
//! 4. **Byte-identical routing.** A `ShardRouter` scattering the same
//!    run across 2–3 worker nodes over loopback TCP yields the same
//!    labels, the same `k`, and the same consensus co-cluster ordering
//!    as the in-process single-node run — including when a flaky
//!    worker drops its connection mid-round and jobs take the
//!    retry path.
//! 5. **Advisory observability.** Attaching a lifecycle-event journal
//!    to a run — even one small enough to overflow and drop events —
//!    changes nothing about the labels, `k`, or consensus ordering.
//! 6. **Byte-identical incremental re-clustering.** Appending row
//!    batches to a store and running `Lamc::run_incremental` against
//!    the retained basis yields the same labels as a from-scratch run
//!    on the concatenated matrix — both formats, both codecs — and a
//!    crash-torn append surfaces as a typed `StoreError` at open.
//!
//! Seeded and reproducible via `testkit` (`LAMC_PROP_SEED` /
//! `LAMC_PROP_CASES` env overrides).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::Command;

use lamc::data::synthetic::{planted_dense, planted_sparse, PlantedConfig};
use lamc::matrix::{CsrMatrix, DenseMatrix, Matrix};
use lamc::pipeline::{Lamc, LamcConfig};
use lamc::rng::Xoshiro256;
use lamc::service::protocol::{self, ShardSetInfo};
use lamc::service::{
    ServiceConfig, ServiceManager, ServiceServer, ShardRouter, ShardRouterConfig,
};
use lamc::store::{
    pack_matrix, pack_matrix_tiled, pack_matrix_tiled_with_codec, pack_matrix_with_codec,
    shard_store, ChunkWriter, Codec, MatrixRef, ShardManifest, StoreError, StoreReader,
};
use lamc::testkit;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("lamc_property_layouts").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One generated case: a matrix shape/content seed and a chunk grid.
#[derive(Debug)]
struct LayoutCase {
    seed: u64,
    rows: usize,
    cols: usize,
    sparse: bool,
    chunk_rows: usize,
    chunk_cols: usize,
}

fn build_matrix(seed: u64, rows: usize, cols: usize, sparse: bool) -> Matrix {
    let mut rng = Xoshiro256::seed_from(seed);
    if sparse {
        let nnz = (rows * cols / 3).max(1);
        let mut trip = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            trip.push((rng.next_below(rows), rng.next_below(cols), rng.next_f32() + 0.01));
        }
        Matrix::Sparse(CsrMatrix::from_triplets(rows, cols, trip))
    } else {
        Matrix::Dense(DenseMatrix::randn(rows, cols, &mut rng))
    }
}

#[test]
fn any_tile_query_is_byte_identical_across_layouts() {
    let dir = tmp_dir("tile_equiv");
    let band_path = dir.join("m.lamc2");
    let tiled_path = dir.join("m.lamc3");
    testkit::check(
        "tile(rows, cols) equal across Matrix / LAMC2 / LAMC3",
        testkit::default_cases(),
        |rng| LayoutCase {
            seed: rng.next_u64(),
            rows: 1 + rng.next_below(60),
            cols: 1 + rng.next_below(40),
            sparse: rng.next_below(2) == 1,
            chunk_rows: 1 + rng.next_below(16),
            chunk_cols: 1 + rng.next_below(16),
        },
        |case| {
            let matrix = build_matrix(case.seed, case.rows, case.cols, case.sparse);
            pack_matrix(&matrix, &band_path, case.chunk_rows)
                .map_err(|e| format!("pack lamc2: {e:#}"))?;
            pack_matrix_tiled(&matrix, &tiled_path, case.chunk_rows, case.chunk_cols)
                .map_err(|e| format!("pack lamc3: {e:#}"))?;
            let band = StoreReader::open(&band_path).map_err(|e| format!("open lamc2: {e:#}"))?;
            let tiled = StoreReader::open(&tiled_path).map_err(|e| format!("open lamc3: {e:#}"))?;

            let mut rng = Xoshiro256::seed_from(case.seed ^ 0xBEEF);
            for q in 0..6 {
                let nr = 1 + rng.next_below(case.rows.min(20));
                let nc = 1 + rng.next_below(case.cols.min(20));
                let rows = rng.sample_indices(case.rows, nr);
                let cols = rng.sample_indices(case.cols, nc);
                let want = matrix.gather_block(&rows, &cols);
                let from_band = band.tile(&rows, &cols).map_err(|e| format!("{e:#}"))?;
                let from_tiled = tiled.tile(&rows, &cols).map_err(|e| format!("{e:#}"))?;
                if from_band.data() != want.data() {
                    return Err(format!("query {q}: lamc2 differs (rows {rows:?} cols {cols:?})"));
                }
                if from_tiled.data() != want.data() {
                    return Err(format!("query {q}: lamc3 differs (rows {rows:?} cols {cols:?})"));
                }
            }

            // Whole-matrix reconstruction is exact for both layouts.
            for (which, reader) in [("lamc2", &band), ("lamc3", &tiled)] {
                let got = reader.read_all().map_err(|e| format!("{which} read_all: {e:#}"))?;
                match (&matrix, &got) {
                    (Matrix::Dense(a), Matrix::Dense(b)) if a == b => {}
                    (Matrix::Sparse(a), Matrix::Sparse(b))
                        if a.nnz() == b.nnz()
                            && a.to_dense().data() == b.to_dense().data() => {}
                    _ => return Err(format!("{which}: read_all does not reconstruct the matrix")),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tile_queries_and_fingerprints_are_codec_invariant() {
    // Same contract as the layout sweep, one axis up: for each geometry,
    // a shuffle-lz store must serve the exact bytes of its codec=none
    // twin, carry the same content fingerprint (it chains *uncompressed*
    // payload checksums), and never store more payload than raw.
    let dir = tmp_dir("codec_equiv");
    testkit::check(
        "tile(rows, cols) + fingerprint equal across codec {none, shuffle-lz}",
        testkit::default_cases(),
        |rng| LayoutCase {
            seed: rng.next_u64(),
            rows: 1 + rng.next_below(60),
            cols: 1 + rng.next_below(40),
            sparse: rng.next_below(2) == 1,
            chunk_rows: 1 + rng.next_below(16),
            chunk_cols: 1 + rng.next_below(16),
        },
        |case| {
            let matrix = build_matrix(case.seed, case.rows, case.cols, case.sparse);
            let mut stores = Vec::new();
            for codec in [Codec::None, Codec::ShuffleLz] {
                let tag = codec.as_str();
                let band_path = dir.join(format!("m_{tag}.lamc2"));
                let tiled_path = dir.join(format!("m_{tag}.lamc3"));
                let s2 = pack_matrix_with_codec(&matrix, &band_path, case.chunk_rows, codec)
                    .map_err(|e| format!("pack lamc2 {tag}: {e:#}"))?;
                let s3 = pack_matrix_tiled_with_codec(
                    &matrix,
                    &tiled_path,
                    case.chunk_rows,
                    case.chunk_cols,
                    codec,
                )
                .map_err(|e| format!("pack lamc3 {tag}: {e:#}"))?;
                for s in [&s2, &s3] {
                    if s.stored_payload_bytes > s.raw_payload_bytes {
                        return Err(format!(
                            "{tag}: stored {} > raw {} payload bytes (store-smaller-of broken)",
                            s.stored_payload_bytes, s.raw_payload_bytes
                        ));
                    }
                }
                stores.push((band_path, tiled_path, s2, s3));
            }
            let (_, _, none2, none3) = &stores[0];
            let (band_lz, tiled_lz, lz2, lz3) = &stores[1];
            if none2.fingerprint != lz2.fingerprint {
                return Err("lamc2 fingerprint changed under shuffle-lz".into());
            }
            if none3.fingerprint != lz3.fingerprint {
                return Err("lamc3 fingerprint changed under shuffle-lz".into());
            }

            let band = StoreReader::open(band_lz).map_err(|e| format!("open lamc2 lz: {e:#}"))?;
            let tiled = StoreReader::open(tiled_lz).map_err(|e| format!("open lamc3 lz: {e:#}"))?;
            let mut rng = Xoshiro256::seed_from(case.seed ^ 0xC0DEC);
            for q in 0..4 {
                let nr = 1 + rng.next_below(case.rows.min(20));
                let nc = 1 + rng.next_below(case.cols.min(20));
                let rows = rng.sample_indices(case.rows, nr);
                let cols = rng.sample_indices(case.cols, nc);
                let want = matrix.gather_block(&rows, &cols);
                if band.tile(&rows, &cols).map_err(|e| format!("{e:#}"))?.data() != want.data() {
                    return Err(format!("query {q}: lamc2 shuffle-lz differs"));
                }
                if tiled.tile(&rows, &cols).map_err(|e| format!("{e:#}"))?.data() != want.data() {
                    return Err(format!("query {q}: lamc3 shuffle-lz differs"));
                }
            }
            for (which, reader) in [("lamc2", &band), ("lamc3", &tiled)] {
                let got = reader.read_all().map_err(|e| format!("{which} read_all: {e:#}"))?;
                match (&matrix, &got) {
                    (Matrix::Dense(a), Matrix::Dense(b)) if a == b => {}
                    (Matrix::Sparse(a), Matrix::Sparse(b))
                        if a.nnz() == b.nnz()
                            && a.to_dense().data() == b.to_dense().data() => {}
                    _ => {
                        return Err(format!(
                            "{which}: read_all does not reconstruct under shuffle-lz"
                        ))
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn coclustering_labels_are_byte_identical_across_backings() {
    for (name, sparse) in [("dense", false), ("sparse", true)] {
        let dir = tmp_dir(&format!("e2e_{name}"));
        let cfg = PlantedConfig {
            rows: 160,
            cols: 120,
            row_clusters: 3,
            col_clusters: 3,
            noise: 0.1,
            signal: 1.5,
            density: 0.08,
            seed: 0xE2E0 + sparse as u64,
        };
        let matrix = if sparse { planted_sparse(&cfg).matrix } else { planted_dense(&cfg).matrix };

        let band_path = dir.join("m.lamc2");
        let tiled_path = dir.join("m.lamc3");
        let lz_path = dir.join("m_lz.lamc3");
        pack_matrix(&matrix, &band_path, 48).unwrap();
        pack_matrix_tiled(&matrix, &tiled_path, 48, 40).unwrap();
        pack_matrix_tiled_with_codec(&matrix, &lz_path, 48, 40, Codec::ShuffleLz).unwrap();
        let band = MatrixRef::open_store(&band_path).unwrap();
        let tiled = MatrixRef::open_store(&tiled_path).unwrap();
        let lz = MatrixRef::open_store(&lz_path).unwrap();

        let mut config = LamcConfig { k: 3, seed: 0x1A3C, ..Default::default() };
        config.planner.candidate_sizes = vec![48, 64];
        config.planner.max_samplings = 6;
        let lamc = Lamc::new(config);

        let in_mem = lamc.run(&matrix).unwrap();
        let from_band = lamc.run(&band).unwrap();
        let from_tiled = lamc.run(&tiled).unwrap();
        let from_lz = lamc.run(&lz).unwrap();

        assert_eq!(in_mem.row_labels, from_band.row_labels, "{name}: lamc2 row labels");
        assert_eq!(in_mem.col_labels, from_band.col_labels, "{name}: lamc2 col labels");
        assert_eq!(in_mem.row_labels, from_tiled.row_labels, "{name}: lamc3 row labels");
        assert_eq!(in_mem.col_labels, from_tiled.col_labels, "{name}: lamc3 col labels");
        assert_eq!(in_mem.row_labels, from_lz.row_labels, "{name}: shuffle-lz row labels");
        assert_eq!(in_mem.col_labels, from_lz.col_labels, "{name}: shuffle-lz col labels");
        assert_eq!(in_mem.k, from_band.k, "{name}: k");
        assert_eq!(in_mem.k, from_tiled.k, "{name}: k");
        assert_eq!(in_mem.k, from_lz.k, "{name}: shuffle-lz k");

        // The tiled run streamed strictly fewer payload bytes per tile
        // gather than full-band decoding would cost; at minimum it
        // actually streamed (nothing materialized the matrix).
        match &tiled {
            MatrixRef::Stored(r) => assert!(r.tiles_served() > 0, "{name}: tiles streamed"),
            MatrixRef::InMem(_) => unreachable!(),
        }
    }
}

/// Event emission is advisory: running the exact same config with a
/// trace journal attached — one so small the ring is forced to drop
/// events mid-run — must not perturb the labels, `k`, or consensus
/// ordering by a single byte (docs/OBSERVABILITY.md § Guarantees).
#[test]
fn event_emission_is_advisory_labels_byte_identical() {
    use lamc::trace::{Event, Journal, Trace};
    use std::sync::Arc;

    let cfg = PlantedConfig {
        rows: 160,
        cols: 120,
        row_clusters: 3,
        col_clusters: 3,
        noise: 0.1,
        signal: 1.5,
        density: 0.08,
        seed: 0xADB1,
    };
    let matrix = planted_dense(&cfg).matrix;
    let mut config = LamcConfig { k: 3, seed: 0x1A3C, ..Default::default() };
    config.planner.candidate_sizes = vec![48, 64];
    config.planner.max_samplings = 6;

    let silent = Lamc::new(config.clone()).run(&matrix).unwrap();

    // Capacity 2 cannot hold even one round's start/complete pair plus
    // the merge events — the ring must wrap and drop.
    let journal = Arc::new(Journal::new(2));
    let mut traced_cfg = config;
    traced_cfg.trace = Trace::to_journal(Arc::clone(&journal));
    let traced = Lamc::new(traced_cfg).run(&matrix).unwrap();

    assert_eq!(silent.row_labels, traced.row_labels, "traced: row labels");
    assert_eq!(silent.col_labels, traced.col_labels, "traced: col labels");
    assert_eq!(silent.k, traced.k, "traced: k");
    assert_eq!(silent.coclusters, traced.coclusters, "traced: consensus ordering");

    // The journal really was active and really did overflow: the read
    // side must surface the truncation as a synthetic Dropped marker.
    assert!(journal.last_seq().unwrap_or(0) > 2, "pipeline emitted through the trace");
    assert!(journal.dropped() > 0, "tiny ring forced drops");
    let events = journal.events_after(None, 64);
    assert!(
        matches!(events.first().map(|r| &r.event), Some(Event::Dropped { .. })),
        "gap marker first, got {:?}",
        events.first()
    );
}

#[test]
fn column_heavy_planner_queries_read_fewer_bytes_tiled() {
    // Acceptance shape at the harness level: same planner-style column
    // slice, both layouts, cold caches — the tiled store must win on
    // bytes off disk.
    let dir = tmp_dir("colheavy");
    let mut rng = Xoshiro256::seed_from(77);
    let matrix = Matrix::Dense(DenseMatrix::randn(128, 96, &mut rng));
    let band_path = dir.join("m.lamc2");
    let tiled_path = dir.join("m.lamc3");
    pack_matrix(&matrix, &band_path, 32).unwrap();
    pack_matrix_tiled(&matrix, &tiled_path, 32, 16).unwrap();
    let band = StoreReader::open_with_cache(&band_path, 0).unwrap();
    let tiled = StoreReader::open_with_cache(&tiled_path, 0).unwrap();
    let rows: Vec<usize> = (0..128).collect();
    let cols: Vec<usize> = (16..32).collect(); // exactly column band 1
    assert_eq!(
        band.tile(&rows, &cols).unwrap().data(),
        tiled.tile(&rows, &cols).unwrap().data(),
        "same bytes out"
    );
    assert!(
        tiled.bytes_read() < band.bytes_read(),
        "tiled {} B < row-band {} B",
        tiled.bytes_read(),
        band.bytes_read()
    );
}

// ---- append + incremental re-clustering equivalence --------------------

/// One generated append case: base shape, store geometry, and how many
/// row batches get appended.
#[derive(Debug)]
struct AppendCase {
    idx: usize,
    seed: u64,
    rows: usize,
    cols: usize,
    tiled: bool,
    codec: Codec,
    batches: usize,
}

#[test]
fn append_then_incremental_recluster_is_byte_identical() {
    // The sweep must cover every (format, codec) cell at least once;
    // with 4 cells, 8 cases is the floor.
    let cases = testkit::default_cases().clamp(8, 12);
    let counter = std::cell::Cell::new(0usize);
    testkit::check(
        "append K batches + run_incremental == from-scratch run on the grown matrix",
        cases,
        |rng| {
            let idx = counter.get();
            counter.set(idx + 1);
            AppendCase {
                idx,
                seed: rng.next_u64(),
                rows: 48 + rng.next_below(40),
                cols: 40 + rng.next_below(24),
                // Deterministic cell walk: every format x codec pair is
                // exercised regardless of the seeded RNG stream.
                tiled: idx % 2 == 1,
                codec: if (idx / 2) % 2 == 0 { Codec::None } else { Codec::ShuffleLz },
                batches: 1 + rng.next_below(3),
            }
        },
        |case| {
            let dir = tmp_dir(&format!("append_equiv_{}", case.idx));
            let mut rng = Xoshiro256::seed_from(case.seed);
            let mut data: Vec<f32> =
                (0..case.rows * case.cols).map(|_| rng.next_f32() - 0.5).collect();
            let base =
                Matrix::Dense(DenseMatrix::from_vec(case.rows, case.cols, data.clone()));
            let path = dir.join(if case.tiled { "m.lamc3" } else { "m.lamc2" });
            if case.tiled {
                pack_matrix_tiled_with_codec(&base, &path, 16, 16, case.codec)
            } else {
                pack_matrix_with_codec(&base, &path, 16, case.codec)
            }
            .map_err(|e| format!("pack: {e:#}"))?;

            let mut config = LamcConfig { k: 3, seed: 0x1A3C ^ case.seed, ..Default::default() };
            config.planner.candidate_sizes = vec![32, 48];
            config.planner.max_samplings = 5;
            let lamc = Lamc::new(config);
            let opts = lamc.options();

            // Seed the basis with a tracked run on the original store.
            let stored = MatrixRef::open_store(&path).map_err(|e| format!("open: {e:#}"))?;
            let base_generation = stored.generation();
            let (_, mut basis) =
                lamc.run_tracked(&stored, &opts).map_err(|e| format!("tracked run: {e:#}"))?;

            let mut total_rows = case.rows;
            for b in 0..case.batches {
                // Grow the store by one sealed batch of fresh rows.
                let add = 1 + rng.next_below(12);
                let fresh: Vec<f32> =
                    (0..add * case.cols).map(|_| rng.next_f32() - 0.5).collect();
                let mut w =
                    ChunkWriter::append_to(&path).map_err(|e| format!("append_to: {e:#}"))?;
                for r in 0..add {
                    w.append_dense_row(&fresh[r * case.cols..(r + 1) * case.cols])
                        .map_err(|e| format!("append row: {e:#}"))?;
                }
                w.finish().map_err(|e| format!("finish append: {e:#}"))?;
                data.extend_from_slice(&fresh);
                total_rows += add;

                let stored =
                    MatrixRef::open_store(&path).map_err(|e| format!("reopen: {e:#}"))?;
                if stored.rows() != total_rows {
                    return Err(format!(
                        "batch {b}: store has {} rows, want {total_rows}",
                        stored.rows()
                    ));
                }
                // Dirty tracking attributes exactly the appended tail,
                // extended back to the last band boundary when the first
                // append re-sealed a partial band (chunk_rows is 16).
                let dirty_lo = case.rows - case.rows % 16;
                let dirty = stored.dirty_rows_since(base_generation);
                if dirty != vec![(dirty_lo, total_rows)] {
                    return Err(format!(
                        "batch {b}: dirty rows {dirty:?}, want [({dirty_lo}, {total_rows})]"
                    ));
                }

                // From-scratch reference on the concatenated matrix.
                let grown = Matrix::Dense(DenseMatrix::from_vec(
                    total_rows,
                    case.cols,
                    data.clone(),
                ));
                let scratch =
                    lamc.run(&grown).map_err(|e| format!("from-scratch run: {e:#}"))?;
                let (inc, next) = lamc
                    .run_incremental(&stored, &opts, &basis)
                    .map_err(|e| format!("incremental run: {e:#}"))?;
                basis = next;

                if inc.row_labels != scratch.row_labels {
                    return Err(format!("batch {b}: row labels diverge from from-scratch run"));
                }
                if inc.col_labels != scratch.col_labels {
                    return Err(format!("batch {b}: col labels diverge from from-scratch run"));
                }
                if inc.k != scratch.k {
                    return Err(format!("batch {b}: k {} vs from-scratch {}", inc.k, scratch.k));
                }
                if inc.coclusters != scratch.coclusters {
                    return Err(format!("batch {b}: consensus co-cluster ordering diverges"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn append_crash_truncation_is_typed_never_a_panic() {
    let dir = tmp_dir("append_crash");
    let mut rng = Xoshiro256::seed_from(21);
    let matrix = Matrix::Dense(DenseMatrix::randn(40, 12, &mut rng));

    for fmt in ["lamc2", "lamc3"] {
        let clean = dir.join(format!("clean.{fmt}"));
        if fmt == "lamc2" {
            pack_matrix(&matrix, &clean, 8).unwrap();
        } else {
            pack_matrix_tiled(&matrix, &clean, 8, 5).unwrap();
        }
        let clean_gen = StoreReader::open(&clean).unwrap().generation();

        // A completed append: rows visible, generation bumped by one,
        // dirty tracking pinned to exactly the appended band.
        let grown = dir.join(format!("grown.{fmt}"));
        std::fs::copy(&clean, &grown).unwrap();
        let mut w = ChunkWriter::append_to(&grown).unwrap();
        for r in 0..10 {
            let row: Vec<f32> = (0..12).map(|c| (r * 12 + c) as f32 * 0.25).collect();
            w.append_dense_row(&row).unwrap();
        }
        w.finish().unwrap();
        let reader = StoreReader::open(&grown).unwrap();
        assert_eq!(reader.rows(), 50, "{fmt}: appended rows visible");
        assert_eq!(reader.generation(), clean_gen + 1, "{fmt}: generation bumped");
        assert_eq!(
            reader.dirty_rows_since(clean_gen),
            vec![(40, 50)],
            "{fmt}: dirty rows are exactly the appended tail"
        );
        assert!(reader.verify().is_ok(), "{fmt}: grown store verifies");
        drop(reader);

        // A crash-torn append: the rewritten trailer is cut off at
        // several depths. Every prefix must fail *typed* at open or
        // verify — never a panic, never silently serving partial rows.
        for cut in [1usize, 9, 25, 41] {
            let p = damaged(&grown, &format!("cut{cut}.{fmt}"), |b| {
                let keep = b.len() - cut;
                b.truncate(keep);
            });
            match probe(&p) {
                Ok(()) => panic!("{fmt}: store cut {cut} bytes short still verifies"),
                Err("untyped") => panic!("{fmt}: cut {cut} produced an untyped error"),
                Err(_) => {}
            }
            assert!(
                !run_inspect_verify(&p).success(),
                "{fmt}: inspect --verify passes a store cut {cut} bytes short"
            );
        }
    }
}

// ---- corruption-injection sweep ---------------------------------------

/// Write a damaged copy of `src` produced by `mutate` and return it.
fn damaged(src: &Path, name: &str, mutate: impl FnOnce(&mut Vec<u8>)) -> PathBuf {
    let mut bytes = std::fs::read(src).unwrap();
    mutate(&mut bytes);
    let path = src.with_file_name(name);
    std::fs::write(&path, &bytes).unwrap();
    path
}

/// Open + fully verify, mapping any failure to its typed variant name.
fn probe(path: &Path) -> Result<(), &'static str> {
    let verdict = |e: &anyhow::Error| match e.downcast_ref::<StoreError>() {
        Some(StoreError::NotAStore(_)) => "NotAStore",
        Some(StoreError::Truncated { .. }) => "Truncated",
        Some(StoreError::Corrupt { .. }) => "Corrupt",
        Some(StoreError::UnsupportedVersion { .. }) => "UnsupportedVersion",
        None => "untyped",
    };
    let reader = match StoreReader::open_with_cache(path, 0) {
        Ok(r) => r,
        Err(e) => return Err(verdict(&e)),
    };
    if let Err(e) = reader.verify() {
        return Err(verdict(&e));
    }
    if let Err(e) = reader.tile(&[0], &[0]) {
        return Err(verdict(&e));
    }
    Ok(())
}

/// Trailer layout: `footer_len (8) · footer_checksum (8) · magic (8)`.
fn footer_bounds(bytes: &[u8]) -> (usize, usize) {
    let n = bytes.len();
    let footer_len =
        u64::from_le_bytes(bytes[n - 24..n - 16].try_into().unwrap()) as usize;
    let start = n - 24 - footer_len;
    (start, footer_len)
}

/// Rewrite footer-body word `word_idx` through `f`, then recompute the
/// trailer's footer checksum so only deeper validation can object.
fn patch_footer_word(b: &mut [u8], word_idx: usize, f: impl FnOnce(u64) -> u64) {
    let (start, len) = footer_bounds(b);
    let at = start + word_idx * 8;
    let v = u64::from_le_bytes(b[at..at + 8].try_into().unwrap());
    b[at..at + 8].copy_from_slice(&f(v).to_le_bytes());
    let ck = lamc::store::checksum_bytes(&b[start..start + len]);
    let n = b.len();
    b[n - 16..n - 8].copy_from_slice(&ck.to_le_bytes());
}

fn run_inspect_verify(store: &Path) -> std::process::ExitStatus {
    Command::new(env!("CARGO_BIN_EXE_lamc"))
        .args(["inspect", "--store", store.to_str().unwrap(), "--verify"])
        .output()
        .expect("spawn lamc")
        .status
}

/// `lamc inspect --verify` with the mmap read path disabled, so the
/// pread fallback gets the same end-to-end coverage.
fn run_inspect_verify_no_mmap(store: &Path) -> std::process::ExitStatus {
    Command::new(env!("CARGO_BIN_EXE_lamc"))
        .env("LAMC_NO_MMAP", "1")
        .args(["inspect", "--store", store.to_str().unwrap(), "--verify"])
        .output()
        .expect("spawn lamc")
        .status
}

#[test]
fn corruption_in_any_region_is_a_typed_error_never_a_panic() {
    let dir = tmp_dir("corruption");
    let mut rng = Xoshiro256::seed_from(99);
    let matrix = Matrix::Dense(DenseMatrix::randn(40, 12, &mut rng));

    for fmt in ["lamc2", "lamc3"] {
        let clean = dir.join(format!("clean.{fmt}"));
        if fmt == "lamc2" {
            pack_matrix(&matrix, &clean, 8).unwrap();
        } else {
            pack_matrix_tiled(&matrix, &clean, 8, 5).unwrap();
        }
        assert!(probe(&clean).is_ok(), "{fmt}: clean store verifies");
        assert!(run_inspect_verify(&clean).success(), "{fmt}: inspect --verify passes clean");

        // Region 1: leading magic — not a store at all.
        let p = damaged(&clean, &format!("magic.{fmt}"), |b| b[0] ^= 0xFF);
        assert_eq!(probe(&p), Err("NotAStore"), "{fmt}: magic flip");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on magic flip");

        // Region 2: a chunk payload byte — checksum catches it.
        let p = damaged(&clean, &format!("payload.{fmt}"), |b| b[10] ^= 0xFF);
        assert_eq!(probe(&p), Err("Corrupt"), "{fmt}: payload flip");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on payload flip");

        // Region 3: a byte inside the footer body (a stored chunk
        // checksum) — the footer's own checksum catches it at open.
        let p = damaged(&clean, &format!("index.{fmt}"), |b| {
            let (start, len) = footer_bounds(b);
            b[start + len - 1] ^= 0xFF;
        });
        assert_eq!(probe(&p), Err("Corrupt"), "{fmt}: footer body flip");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on footer flip");

        // Region 4: header version word — patched consistently (footer
        // checksum recomputed) so it surfaces as UnsupportedVersion.
        let p = damaged(&clean, &format!("version.{fmt}"), |b| {
            let (start, len) = footer_bounds(b);
            b[start..start + 8].copy_from_slice(&999u64.to_le_bytes());
            let ck = lamc::store::checksum_bytes(&b[start..start + len]);
            let n = b.len();
            b[n - 16..n - 8].copy_from_slice(&ck.to_le_bytes());
        });
        assert_eq!(probe(&p), Err("UnsupportedVersion"), "{fmt}: future version");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on future version");

        // Region 5: trailer footer_len — claims more footer than file.
        let p = damaged(&clean, &format!("trailer.{fmt}"), |b| {
            let n = b.len();
            b[n - 24..n - 16].copy_from_slice(&(1u64 << 40).to_le_bytes());
        });
        assert_eq!(probe(&p), Err("Truncated"), "{fmt}: trailer length lie");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on trailer lie");

        // Region 6: truncation — the tail (and footer magic) is gone.
        let p = damaged(&clean, &format!("trunc.{fmt}"), |b| {
            let keep = b.len() - 40;
            b.truncate(keep);
        });
        assert_eq!(probe(&p), Err("Truncated"), "{fmt}: truncated file");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on truncation");

        // Region 7: trailer magic swapped to the *other* version's —
        // outside the footer checksum's coverage, so it needs its own
        // consistency check against the leading magic.
        let p = damaged(&clean, &format!("xmagic.{fmt}"), |b| {
            let n = b.len();
            let other: &[u8; 8] = if fmt == "lamc2" { b"LAMC3FTR" } else { b"LAMC2FTR" };
            b[n - 8..].copy_from_slice(other);
        });
        assert_eq!(probe(&p), Err("Corrupt"), "{fmt}: cross-version trailer magic");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on trailer swap");
    }
}

#[test]
fn compressed_payload_corruption_is_typed_and_fails_inspect() {
    // Mostly-zero dense content so shuffle-lz genuinely engages: every
    // chunk stores compressed, and the sweep exercises the codec decode
    // path, not the raw fallback.
    let dir = tmp_dir("codec_corruption");
    let mut rng = Xoshiro256::seed_from(5);
    let mut m = DenseMatrix::randn(48, 16, &mut rng);
    for (i, v) in m.data_mut().iter_mut().enumerate() {
        if i % 8 != 0 {
            *v = 0.0;
        }
    }
    let matrix = Matrix::Dense(m);

    for fmt in ["lamc2", "lamc3"] {
        let clean = dir.join(format!("clean.{fmt}"));
        let summary = if fmt == "lamc2" {
            pack_matrix_with_codec(&matrix, &clean, 8, Codec::ShuffleLz).unwrap()
        } else {
            pack_matrix_tiled_with_codec(&matrix, &clean, 8, 8, Codec::ShuffleLz).unwrap()
        };
        assert!(
            summary.stored_payload_bytes < summary.raw_payload_bytes,
            "{fmt}: sparse-ish payload compresses ({} vs {} bytes)",
            summary.stored_payload_bytes,
            summary.raw_payload_bytes
        );
        assert!(probe(&clean).is_ok(), "{fmt}: clean compressed store verifies");
        assert!(run_inspect_verify(&clean).success(), "{fmt}: inspect passes clean");
        assert!(
            run_inspect_verify_no_mmap(&clean).success(),
            "{fmt}: inspect passes clean via the pread fallback"
        );

        // A flipped byte inside a compressed payload: the stored-byte
        // checksum catches it before any decompression runs.
        let p = damaged(&clean, &format!("payload.{fmt}"), |b| b[10] ^= 0xFF);
        assert_eq!(probe(&p), Err("Corrupt"), "{fmt}: compressed payload flip");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on payload flip");

        // Inflate chunk 0's declared raw_len (footer checksum patched to
        // match): the stream then decodes to fewer bytes than declared,
        // which must surface as Corrupt from the codec layer itself.
        // Footer geometry: v3 = 9 header words + 8/entry (raw_len is
        // entry word 7); v4 = 10 header words + 10/entry (word 9).
        let raw_len_word = if fmt == "lamc2" { 9 + 7 } else { 10 + 9 };
        let p = damaged(&clean, &format!("rawlen.{fmt}"), |b| {
            patch_footer_word(b, raw_len_word, |raw_len| raw_len + 1);
        });
        assert_eq!(probe(&p), Err("Corrupt"), "{fmt}: raw_len lie");
        assert!(!run_inspect_verify(&p).success(), "{fmt}: inspect fails on raw_len lie");
    }
}

#[test]
fn crafted_overlapping_extents_are_rejected_at_open() {
    // Both extents stay inside the payload region and the footer
    // checksum is made consistent, so only decode_footer's pairwise
    // disjointness check stands between a reader and silently serving
    // chunk 0's bytes for part of chunk 1.
    let dir = tmp_dir("overlap");
    let mut rng = Xoshiro256::seed_from(13);
    let matrix = Matrix::Dense(DenseMatrix::randn(40, 12, &mut rng));
    let clean = dir.join("clean.lamc2");
    pack_matrix(&matrix, &clean, 8).unwrap(); // 5 equal 8-row bands

    // v1 footer: 8 header words + 6 words per entry; entry 1's offset
    // is word 14. Pull it back one byte -> overlap with chunk 0.
    let p = damaged(&clean, "overlap.lamc2", |b| {
        patch_footer_word(b, 8 + 6, |off| off - 1);
    });
    assert_eq!(probe(&p), Err("Corrupt"), "overlapping extents");
    assert!(!run_inspect_verify(&p).success(), "inspect fails on overlap");

    // Alias entry 1 onto entry 0's extent exactly (equal band shapes,
    // so the lengths already match).
    let p = damaged(&clean, "alias.lamc2", |b| {
        patch_footer_word(b, 8 + 6, |_| 8);
    });
    assert_eq!(probe(&p), Err("Corrupt"), "aliased extents");
    assert!(!run_inspect_verify(&p).success(), "inspect fails on alias");
}

// ---- 1-node-vs-N-node shard-routing equivalence -----------------------

/// One generated routing case: matrix content, shard count, worker
/// count, and whether a flaky worker joins the cluster.
#[derive(Debug)]
struct ShardCase {
    idx: usize,
    seed: u64,
    rows: usize,
    cols: usize,
    sparse: bool,
    k: usize,
    n_shards: usize,
    n_workers: usize,
    flaky: bool,
}

/// A worker that joins the cluster correctly — it answers `HELLO` with
/// the real proto/version and `SHARDS` claiming *every* band of the
/// manifest, exactly like a fully-replicated node — and then hangs up
/// on any job verb. Because it advertises ownership of all bands and
/// registers first (worker index 0), the router's deterministic
/// owner-selection sends the first round of jobs straight at it,
/// forcing the `WorkerLost` → retry path before the cluster settles on
/// the live workers.
fn spawn_flaky_worker(name: &str, manifest: &ShardManifest) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let info = ShardSetInfo {
        name: name.to_string(),
        rows: manifest.rows,
        cols: manifest.cols,
        nnz: manifest.nnz,
        sparse: manifest.sparse,
        fingerprint: manifest.fingerprint,
        bands: manifest.band_spans(),
    };
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(mut stream) = conn else { break };
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            loop {
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
                let reply = match line.split_whitespace().next().unwrap_or("") {
                    "HELLO" => format!(
                        "OK proto={} version={}\n",
                        protocol::PROTO_VERSION,
                        env!("CARGO_PKG_VERSION")
                    ),
                    "SHARDS" => format!(
                        "OK sets=1\n{}\nEND\n",
                        protocol::encode_shard_set(&info).unwrap()
                    ),
                    // Any job verb: drop the connection mid-round.
                    _ => break,
                };
                if stream.write_all(reply.as_bytes()).is_err() || stream.flush().is_err() {
                    break;
                }
            }
        }
    });
    addr
}

#[test]
fn routed_run_is_byte_identical_to_single_node() {
    // The acceptance floor is 20 seeded configs; clamp the env override
    // so a low LAMC_PROP_CASES cannot drop below it.
    let cases = testkit::default_cases().clamp(20, 24);
    let counter = std::cell::Cell::new(0usize);
    testkit::check(
        "2-/3-worker routed run == single-node run (labels, k, consensus order)",
        cases,
        |rng| {
            let idx = counter.get();
            counter.set(idx + 1);
            ShardCase {
                idx,
                seed: rng.next_u64(),
                rows: 64 + rng.next_below(48),
                cols: 48 + rng.next_below(48),
                sparse: rng.next_below(2) == 1,
                k: 2 + rng.next_below(3),
                n_shards: 2 + rng.next_below(3),
                n_workers: 2 + rng.next_below(2),
                // Every 4th case exercises the fault-injection retry
                // path (deterministic, so the floor always includes it).
                flaky: idx % 4 == 3,
            }
        },
        |case| {
            let dir = tmp_dir(&format!("shard_equiv_{}", case.idx));
            let matrix = build_matrix(case.seed, case.rows, case.cols, case.sparse);

            // Pack, then split into row-band shard stores + manifest.
            let store_path = dir.join("m.lamc3");
            pack_matrix_tiled(&matrix, &store_path, 16, 16)
                .map_err(|e| format!("pack: {e:#}"))?;
            let reader = StoreReader::open(&store_path).map_err(|e| format!("open: {e:#}"))?;
            let (manifest_path, manifest) = shard_store(&reader, &dir, "m", case.n_shards)
                .map_err(|e| format!("shard: {e:#}"))?;
            // Band rounding can coalesce shards; ownership is over what
            // actually exists.
            let n_bands = manifest.entries.len();

            // Identical config on both sides. Workers pinned: byte
            // identity requires the same round plan, and plan geometry
            // depends on the resolved worker count.
            let mut config =
                LamcConfig { k: case.k, seed: 0x1A3C ^ case.seed, workers: 2, ..Default::default() };
            config.planner.candidate_sizes = vec![32, 48];
            config.planner.max_samplings = 6;

            // Reference: in-process single-node run.
            let local = Lamc::new(config.clone())
                .run(&matrix)
                .map_err(|e| format!("single-node run: {e:#}"))?;

            // Cluster: N in-process workers over loopback TCP with
            // disjoint band ownership (band i -> worker i mod N), plus
            // — in flaky cases — a fake worker claiming every band that
            // dies on first contact with a job.
            let mut addrs = Vec::new();
            let mut flaky_addr = String::new();
            if case.flaky {
                flaky_addr = spawn_flaky_worker("m", &manifest).to_string();
                addrs.push(flaky_addr.clone());
            }
            let mut servers = Vec::new();
            for w in 0..case.n_workers {
                let owned: Vec<usize> =
                    (0..n_bands).filter(|i| i % case.n_workers == w).collect();
                if owned.is_empty() {
                    continue;
                }
                let manager = ServiceManager::new(ServiceConfig { runners: 0, ..Default::default() });
                manager
                    .register_shards("m", &manifest_path, Some(&owned))
                    .map_err(|e| format!("register worker {w}: {e:#}"))?;
                let server = ServiceServer::spawn("127.0.0.1:0", manager)
                    .map_err(|e| format!("spawn worker {w}: {e:#}"))?;
                addrs.push(server.addr().to_string());
                servers.push(server);
            }

            let router = ShardRouter::connect(&addrs, ShardRouterConfig::default())
                .map_err(|e| format!("router connect: {e:#}"))?;
            let routed = router
                .run_config("m", &config)
                .map_err(|e| format!("routed run: {e:#}"))?;

            if case.flaky {
                // The retry path must actually have fired: the flaky
                // worker took the first jobs, dropped them, and was
                // marked dead; the run still completed.
                let health = router.worker_health();
                let dead: Vec<String> =
                    health.iter().filter(|(_, alive)| !alive).map(|(a, _)| a.clone()).collect();
                if dead != [flaky_addr.clone()] {
                    return Err(format!(
                        "expected exactly the flaky worker {flaky_addr} dead, health: {health:?}"
                    ));
                }
            }

            if routed.row_labels != local.row_labels {
                return Err("row labels differ from single-node run".into());
            }
            if routed.col_labels != local.col_labels {
                return Err("col labels differ from single-node run".into());
            }
            if routed.k != local.k {
                return Err(format!("k differs: routed {} vs local {}", routed.k, local.k));
            }
            // Consensus ordering: the merged co-cluster sequence itself
            // must match, not just the labels extracted from it.
            if routed.coclusters != local.coclusters {
                return Err("consensus co-cluster set/order differs from single-node run".into());
            }

            drop(router);
            for server in servers {
                server.shutdown();
                server.join().shutdown();
            }
            Ok(())
        },
    );
}
