//! Fault-injection + protocol integration tests for the shard router.
//!
//! Workers here are real `lamc serve --shards` subprocesses (so
//! `kill()` genuinely severs their TCP connections mid-round) or
//! in-process servers where the scenario only needs wire behaviour.
//! The contract under test, from docs/SERVICE.md:
//!
//! * a worker lost mid-round is retried on surviving owners, and the
//!   retried run stays **byte-identical** to a single-node run;
//! * losing the only owner of a band is a typed `shard band lost`
//!   error — never a hang, never partial labels;
//! * a worker that accepts jobs but never answers trips the job-level
//!   wall-clock timeout (`shard job timeout`), not an infinite wait;
//! * the router front end answers `SUBMIT`/`STATUS`/`RESULTB`/`STATS`
//!   itself, with per-node store/cache counters summed across workers;
//! * a proto-mismatched `HELLO` is rejected with a typed error line.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use lamc::trace::{SpanRecord, ROOT_SPAN};

use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::matrix::Matrix;
use lamc::pipeline::{Lamc, LamcConfig};
use lamc::service::protocol::{self, ShardSetInfo};
use lamc::service::{
    JobSpec, ServiceClient, ServiceConfig, ServiceManager, ServiceServer, ShardRouter,
    ShardRouterConfig, ShardServer,
};
use lamc::store::{pack_matrix_tiled, shard_store, ShardManifest, StoreReader};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("lamc_integration_shard")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A packed + sharded matrix plus the config both sides of an
/// equivalence check run with.
struct Fixture {
    matrix: Matrix,
    manifest_path: PathBuf,
    manifest: ShardManifest,
    config: LamcConfig,
}

fn fixture(name: &str, n_shards: usize) -> Fixture {
    let dir = tmp_dir(name);
    let matrix = planted_dense(&PlantedConfig {
        rows: 120,
        cols: 90,
        row_clusters: 3,
        col_clusters: 3,
        noise: 0.1,
        signal: 1.5,
        density: 0.08,
        seed: 0x5A4D,
    })
    .matrix;
    let store_path = dir.join("m.lamc3");
    pack_matrix_tiled(&matrix, &store_path, 16, 16).unwrap();
    let reader = StoreReader::open(&store_path).unwrap();
    let (manifest_path, manifest) = shard_store(&reader, &dir, "m", n_shards).unwrap();
    assert_eq!(manifest.entries.len(), n_shards, "fixture shards");

    // Workers pinned: the routed plan must match the reference plan.
    let mut config = LamcConfig { k: 3, seed: 0x5A4D, workers: 2, ..Default::default() };
    config.planner.candidate_sizes = vec![32, 48];
    config.planner.max_samplings = 4;
    Fixture { matrix, manifest_path, manifest, config }
}

/// Spawn a `lamc serve` subprocess and return it with its announced
/// address. Stdout keeps draining on a background thread so the child
/// never blocks on a full pipe.
fn spawn_worker(shards_binding: &str) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_lamc"))
        .args(["serve", "--addr", "127.0.0.1:0", "--runners", "1", "--shards", shards_binding])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn lamc serve");
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("read worker stdout");
        assert!(n > 0, "worker exited before announcing its address");
        if let Some(rest) = line.strip_prefix("lamc service listening on ") {
            break rest.trim().to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    (child, addr)
}

fn kill(mut child: Child) {
    let _ = child.kill();
    let _ = child.wait();
}

#[test]
fn killed_worker_jobs_are_retried_byte_identically() {
    let fx = fixture("retry_equiv", 2);
    let local = Lamc::new(fx.config.clone()).run(&fx.matrix).unwrap();

    // Two fully-replicated workers: either one can run any job.
    let binding = format!("m={}", fx.manifest_path.display());
    let (w0, a0) = spawn_worker(&binding);
    let (w1, a1) = spawn_worker(&binding);
    let router =
        ShardRouter::connect(&[a0.clone(), a1.clone()], ShardRouterConfig::default()).unwrap();

    // Healthy cluster first: the routed run matches the reference.
    let routed = router.run_config("m", &fx.config).unwrap();
    assert_eq!(routed.row_labels, local.row_labels, "healthy: row labels");
    assert_eq!(routed.col_labels, local.col_labels, "healthy: col labels");

    // Kill worker 0. Its connection is already established and was
    // used for the run above, so the next round hits a dead socket
    // mid-scatter: those jobs must take the retry path onto worker 1.
    kill(w0);
    let routed = router.run_config("m", &fx.config).unwrap();
    assert_eq!(routed.row_labels, local.row_labels, "retried: row labels");
    assert_eq!(routed.col_labels, local.col_labels, "retried: col labels");
    assert_eq!(routed.k, local.k, "retried: k");
    assert_eq!(routed.coclusters, local.coclusters, "retried: consensus ordering");

    let health = router.worker_health();
    let dead: Vec<String> =
        health.iter().filter(|(_, alive)| !alive).map(|(a, _)| a.clone()).collect();
    assert_eq!(dead, [a0], "exactly the killed worker is marked dead: {health:?}");

    kill(w1);
}

/// Drain a job's full event journal through the wire cursor protocol
/// (EVENTSB with text fallback — whatever the client negotiated).
fn drain_events(client: &mut ServiceClient, id: u64) -> Vec<String> {
    let mut lines = Vec::new();
    let mut cursor = None;
    loop {
        let (page, next) = client.events(id, cursor).unwrap();
        if page.is_empty() {
            break;
        }
        lines.extend(page);
        cursor = next;
    }
    lines
}

fn kind_of(line: &str) -> &str {
    line.split_whitespace().find_map(|t| t.strip_prefix("kind=")).unwrap_or("")
}

#[test]
fn killed_worker_event_stream_narrates_lost_retry_done_in_order() {
    let fx = fixture("retry_events", 2);
    let spec = JobSpec { matrix: "m".into(), k: 3, seed: 0x5A4D, workers: 2, ..Default::default() };
    // Byte-identity reference: the same spec's config run in process.
    let local = Lamc::new(spec.lamc_config().unwrap()).run(&fx.matrix).unwrap();

    // Two fully-replicated subprocess workers behind a router front
    // end, so the event stream is read over the real EVENTS protocol.
    let binding = format!("m={}", fx.manifest_path.display());
    let (w0, a0) = spawn_worker(&binding);
    let (w1, a1) = spawn_worker(&binding);
    let router = ShardRouter::connect(&[a0, a1], ShardRouterConfig::default()).unwrap();
    let front = ShardServer::spawn("127.0.0.1:0", router).unwrap();
    let mut client = ServiceClient::connect(front.addr()).unwrap();

    // Healthy run first: establishes the connections the kill severs,
    // and its journal must narrate a clean arc (no loss, no retry).
    let id = client.submit(&spec).unwrap();
    let healthy = client.wait(id, Duration::from_secs(120)).unwrap();
    assert_eq!(healthy.row_labels, local.row_labels, "healthy: row labels");
    let lines = drain_events(&mut client, id);
    let kinds: Vec<&str> = lines.iter().map(|l| kind_of(l)).collect();
    assert!(kinds.contains(&"RoundCompleted"), "healthy stream: {kinds:?}");
    assert!(kinds.contains(&"BlockScattered"), "healthy stream: {kinds:?}");
    assert!(!kinds.contains(&"WorkerLost"), "healthy stream: {kinds:?}");
    assert_eq!(kinds.last(), Some(&"JobDone"), "healthy stream: {kinds:?}");

    // Kill worker 0 and resubmit: the scatter hits a dead socket, the
    // jobs retry onto worker 1, and the journal must narrate exactly
    // that — WorkerLost, then WorkerRetry, then JobDone — while the
    // labels stay byte-identical to the single-node reference.
    kill(w0);
    let id = client.submit(&spec).unwrap();
    let retried = client.wait(id, Duration::from_secs(120)).unwrap();
    assert_eq!(retried.row_labels, local.row_labels, "retried: row labels");
    assert_eq!(retried.col_labels, local.col_labels, "retried: col labels");

    let lines = drain_events(&mut client, id);
    let kinds: Vec<&str> = lines.iter().map(|l| kind_of(l)).collect();
    let pos = |k: &str| {
        kinds.iter().position(|x| *x == k).unwrap_or_else(|| panic!("no {k} in {kinds:?}"))
    };
    assert_eq!(pos("JobQueued"), 0, "stream starts at the queue: {kinds:?}");
    assert!(pos("WorkerLost") < pos("WorkerRetry"), "loss precedes retry: {kinds:?}");
    assert!(pos("WorkerRetry") < pos("JobDone"), "retry precedes done: {kinds:?}");
    assert!(pos("MergeCompleted") < pos("JobDone"), "merge inside the job: {kinds:?}");
    assert_eq!(kinds.last(), Some(&"JobDone"), "terminal event: {kinds:?}");

    // Stitched span tree under retry: the dispatch that died on worker
    // 0 and the retry that landed on worker 1 are *both* scatter spans
    // under the SAME round span — a retry never grows a second round.
    let spans = client.spans(id).unwrap();
    let by_id: HashMap<u64, &SpanRecord> = spans.iter().map(|s| (s.id, s)).collect();
    let scatters: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.name.starts_with("scatter-")).collect();
    assert!(!scatters.is_empty(), "retried run records scatter spans");
    for s in &scatters {
        assert!(
            by_id[&s.parent].name.starts_with("round-"),
            "scatter parents under a round span: {s:?}"
        );
    }
    let mut by_name: HashMap<&str, Vec<&SpanRecord>> = HashMap::new();
    for s in &scatters {
        by_name.entry(s.name.as_str()).or_default().push(s);
    }
    let retried_job = by_name
        .values()
        .find(|group| group.len() >= 2)
        .unwrap_or_else(|| panic!("some job scattered twice (dead dispatch + retry): {scatters:?}"));
    assert!(
        retried_job.iter().all(|s| s.parent == retried_job[0].parent),
        "both dispatches hang off the same round span: {retried_job:?}"
    );

    // Cursor seqs are strictly increasing across the whole drain.
    let seqs: Vec<u64> = lines
        .iter()
        .map(|l| {
            l.split_whitespace()
                .find_map(|t| t.strip_prefix("seq="))
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| panic!("no seq in '{l}'"))
        })
        .collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "monotonic seqs: {seqs:?}");

    drop(front);
    kill(w1);
}

#[test]
fn losing_the_only_owner_of_a_band_is_a_typed_error() {
    let fx = fixture("band_lost", 2);

    // Disjoint ownership: worker 0 is the only owner of band 0.
    let (w0, a0) = spawn_worker(&format!("m={}:0", fx.manifest_path.display()));
    let (w1, a1) = spawn_worker(&format!("m={}:1", fx.manifest_path.display()));
    let router = ShardRouter::connect(&[a0, a1], ShardRouterConfig::default()).unwrap();
    kill(w0);

    let started = Instant::now();
    let err = router.run_config("m", &fx.config).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shard"), "typed shard error, got: {msg}");
    assert!(
        msg.contains("shard band lost") || msg.contains("shard worker lost"),
        "tagged variant, got: {msg}"
    );
    // Fail-fast, not a hang: one dead-socket detection + one retry.
    assert!(started.elapsed() < Duration::from_secs(60), "took {:?}", started.elapsed());

    kill(w1);
}

/// A worker that joins the cluster correctly (`HELLO` + `SHARDS`
/// claiming every band) and then reads job verbs without ever
/// answering them — the pathological peer the io/job timeouts exist
/// for.
fn spawn_hung_worker(name: &str, manifest: &ShardManifest) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let info = ShardSetInfo {
        name: name.to_string(),
        rows: manifest.rows,
        cols: manifest.cols,
        nnz: manifest.nnz,
        sparse: manifest.sparse,
        fingerprint: manifest.fingerprint,
        bands: manifest.band_spans(),
    };
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(mut stream) = conn else { break };
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            loop {
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
                let reply = match line.split_whitespace().next().unwrap_or("") {
                    "HELLO" => format!(
                        "OK proto={} version={}\n",
                        protocol::PROTO_VERSION,
                        env!("CARGO_PKG_VERSION")
                    ),
                    "SHARDS" => format!(
                        "OK sets=1\n{}\nEND\n",
                        protocol::encode_shard_set(&info).unwrap()
                    ),
                    // A job verb: go silent. The connection stays open
                    // so only a timeout can unblock the router.
                    _ => {
                        std::thread::sleep(Duration::from_secs(30));
                        break;
                    }
                };
                if stream.write_all(reply.as_bytes()).is_err() || stream.flush().is_err() {
                    break;
                }
            }
        }
    });
    addr
}

#[test]
fn hung_worker_trips_the_job_timeout() {
    let fx = fixture("job_timeout", 2);
    // One hung worker, no retries, and a per-exchange io timeout wider
    // than the job budget: the only thing that can unblock the first
    // job is the wall-clock deadline, so the surfaced error must be
    // the job-timeout variant.
    let a0 = spawn_hung_worker("m", &fx.manifest);
    let cfg = ShardRouterConfig {
        retries: 0,
        io_timeout: Duration::from_secs(10),
        job_timeout: Duration::from_secs(2),
    };
    let router = ShardRouter::connect(&[a0], cfg).unwrap();

    let started = Instant::now();
    let err = router.run_config("m", &fx.config).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("shard job timeout"), "typed timeout, got: {msg}");
    assert!(started.elapsed() < Duration::from_secs(30), "took {:?}", started.elapsed());
}

/// Spawn an in-process worker owning the given shard indices.
fn in_process_worker(fx: &Fixture, indices: &[usize]) -> ServiceServer {
    let manager = ServiceManager::new(ServiceConfig { runners: 0, ..Default::default() });
    manager.register_shards("m", &fx.manifest_path, Some(indices)).unwrap();
    ServiceServer::spawn("127.0.0.1:0", manager).unwrap()
}

#[test]
fn router_front_end_serves_results_and_aggregated_stats() {
    let fx = fixture("front_end", 2);
    let w0 = in_process_worker(&fx, &[0]);
    let w1 = in_process_worker(&fx, &[1]);
    let worker_addrs = [w0.addr().to_string(), w1.addr().to_string()];
    let router = ShardRouter::connect(&worker_addrs, ShardRouterConfig::default()).unwrap();
    let front = ShardServer::spawn("127.0.0.1:0", router).unwrap();

    // SUBMIT + wait (RESULTB framing) through the router front end.
    let spec = JobSpec { matrix: "m".into(), k: 3, seed: 0x5A4D, workers: 2, ..Default::default() };
    let mut client = ServiceClient::connect(front.addr()).unwrap();
    let id = client.submit(&spec).unwrap();
    let reply = client.wait(id, Duration::from_secs(120)).unwrap();

    // Byte-identical to running the same spec's config in process.
    let local = Lamc::new(spec.lamc_config().unwrap()).run(&fx.matrix).unwrap();
    assert_eq!(reply.row_labels, local.row_labels, "front-end row labels");
    assert_eq!(reply.col_labels, local.col_labels, "front-end col labels");
    assert_eq!(reply.k, local.k, "front-end k");

    // ROUTE introspection.
    let route = client.route().unwrap();
    assert_eq!(route.get("workers").map(String::as_str), Some("2"));
    assert_eq!(route.get("live").map(String::as_str), Some("2"));

    // STATS: the router's store/cache counters are the sum of the
    // per-node counters (the aggregation-bug regression check).
    let routed_stats = client.stats().unwrap();
    let mut chunk_sum = 0u64;
    let mut bytes_sum = 0u64;
    for addr in &worker_addrs {
        let stats = ServiceClient::connect(addr.as_str()).unwrap().stats().unwrap();
        chunk_sum += stats["store_chunks_read"].parse::<u64>().unwrap();
        bytes_sum += stats["store_bytes_read"].parse::<u64>().unwrap();
    }
    assert!(chunk_sum > 0, "workers actually streamed shard chunks");
    assert_eq!(routed_stats["store_chunks_read"].parse::<u64>().unwrap(), chunk_sum);
    assert_eq!(routed_stats["store_bytes_read"].parse::<u64>().unwrap(), bytes_sum);
    assert_eq!(routed_stats.get("workers").map(String::as_str), Some("2"));
    assert_eq!(routed_stats.get("workers_live").map(String::as_str), Some("2"));
    for key in ["gather_s", "exec_s", "merge_s", "jobs_done"] {
        assert!(routed_stats.contains_key(key), "router STATS carries {key}");
    }
    assert_eq!(routed_stats.get("jobs_done").map(String::as_str), Some("1"));

    drop(client);
    drop(front);
    for server in [w0, w1] {
        server.shutdown();
        server.join().shutdown();
    }
}

#[test]
fn routed_span_tree_stitches_worker_spans_under_router_rounds() {
    let fx = fixture("span_tree", 2);
    // Disjoint ownership forces cross-worker gathers, so the tree
    // carries worker sheets from both `GATHERB` and `EXECB` exchanges.
    let w0 = in_process_worker(&fx, &[0]);
    let w1 = in_process_worker(&fx, &[1]);
    let worker_addrs = [w0.addr().to_string(), w1.addr().to_string()];
    let router = ShardRouter::connect(&worker_addrs, ShardRouterConfig::default()).unwrap();
    let front = ShardServer::spawn("127.0.0.1:0", router).unwrap();
    let spec = JobSpec { matrix: "m".into(), k: 3, seed: 0x5A4D, workers: 2, ..Default::default() };
    let mut client = ServiceClient::connect(front.addr()).unwrap();
    let id = client.submit(&spec).unwrap();
    client.wait(id, Duration::from_secs(120)).unwrap();

    let spans = client.spans(id).unwrap();
    assert!(!spans.is_empty(), "routed job records a span tree");
    let by_id: HashMap<u64, &SpanRecord> = spans.iter().map(|s| (s.id, s)).collect();
    assert_eq!(by_id.len(), spans.len(), "span ids are unique after stitching");

    // Exactly one root, the job span; every other span reaches it.
    let roots: Vec<&SpanRecord> = spans.iter().filter(|s| s.parent == ROOT_SPAN).collect();
    assert_eq!(roots.len(), 1, "one stitched tree: {roots:?}");
    assert_eq!(roots[0].name, "job", "anchored at the job span");
    for s in &spans {
        let mut cur: &SpanRecord = s;
        let mut hops = 0;
        while cur.parent != ROOT_SPAN {
            cur = by_id
                .get(&cur.parent)
                .copied()
                .unwrap_or_else(|| panic!("dangling parent: {s:?}"));
            hops += 1;
            assert!(hops <= spans.len(), "parent cycle at {s:?}");
        }
        assert_eq!(cur.id, roots[0].id, "every span reaches the job root: {s:?}");
    }

    // Every worker-emitted span sits under a scatter span, which sits
    // under a router round span — the cross-node acceptance invariant —
    // and the anchoring rule keeps it inside its parent's window even
    // though worker clocks never agreed with the router's.
    let mut worker_spans = 0;
    for s in &spans {
        if s.name != "gather" && s.name != "exec" {
            continue;
        }
        worker_spans += 1;
        assert!(s.worker < 2, "worker track id: {s:?}");
        let scatter = by_id[&s.parent];
        assert!(scatter.name.starts_with("scatter-"), "worker span under a scatter: {s:?}");
        let round = by_id[&scatter.parent];
        assert!(round.name.starts_with("round-"), "scatter under a router round: {scatter:?}");
        assert!(
            s.start_us >= scatter.start_us && s.end_us() <= scatter.end_us(),
            "anchored span escapes its exchange window: {s:?} vs {scatter:?}"
        );
    }
    assert!(worker_spans >= 2, "worker sheets were stitched in: {spans:?}");

    // Rounds parent directly under the job span, and the merge rides
    // with them.
    for s in &spans {
        if s.name.starts_with("round-") || s.name == "merge" || s.name == "queue" {
            assert_eq!(by_id[&s.parent].name, "job", "direct child of the job: {s:?}");
        }
    }
    assert!(spans.iter().any(|s| s.name == "merge"), "merge span recorded: {spans:?}");

    drop(client);
    drop(front);
    for server in [w0, w1] {
        server.shutdown();
        server.join().shutdown();
    }
}

#[test]
fn proto_mismatched_hello_is_rejected() {
    let fx = fixture("hello_mismatch", 2);
    let worker = in_process_worker(&fx, &[0, 1]);

    let mut stream = TcpStream::connect(worker.addr()).unwrap();
    stream.write_all(b"HELLO proto=99 version=0.0.0\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    BufReader::new(&stream).read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR "), "rejected, got: {line}");
    assert!(line.contains("protocol version mismatch"), "typed message, got: {line}");

    worker.shutdown();
    worker.join().shutdown();
}
