//! Prefetch determinism + safety (ISSUE 5 satellite).
//!
//! The background prefetcher is *advisory*: it may only change
//! wall-clock time, never results, errors, or the hot cache's
//! effectiveness. These tests pin that contract:
//!
//! * labels are byte-identical with prefetch off, on, and starved down
//!   to a one-chunk budget;
//! * `prefetch_wasted_bytes` stays 0 when the plan matches actual
//!   access (every prefetched chunk is consumed, nothing is churned);
//! * prefetch never evicts chunks the current round re-reads — the hot
//!   cache's hit counter does not regress versus a prefetch-free run.

use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::partition::{sample_partition, CoclusterPrior, PartitionPlan, PlannerConfig};
use lamc::rng::Xoshiro256;
use lamc::store::{pack_matrix, pack_matrix_tiled, StoreReader, DEFAULT_CACHE_BYTES};
use lamc::{Lamc, LamcConfig};

fn store_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("lamc_prefetch_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fast_config(k: usize) -> LamcConfig {
    LamcConfig {
        k,
        planner: PlannerConfig {
            candidate_sizes: vec![128],
            prior: CoclusterPrior { row_fraction: 0.2, col_fraction: 0.2, t_m: 6, t_n: 6 },
            max_samplings: 4,
            ..Default::default()
        },
        workers: 2,
        seed: 0xFE7C,
        ..Default::default()
    }
}

fn wait_prefetch_idle(r: &StoreReader) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    while !r.prefetch_idle() {
        assert!(std::time::Instant::now() < deadline, "prefetch never drained");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Labels must be byte-identical across: in-memory, store without
/// prefetch, store with the default prefetch budget, and store with a
/// budget of exactly one chunk (maximum starvation — the prefetcher
/// can hold a single tile and must wait for consumption).
#[test]
fn labels_identical_with_prefetch_off_on_and_one_chunk_budget() {
    let ds = planted_dense(&PlantedConfig {
        rows: 300,
        cols: 240,
        row_clusters: 3,
        col_clusters: 3,
        noise: 0.12,
        signal: 1.5,
        seed: 0x5A11,
        ..Default::default()
    });
    let path = store_dir().join("equiv.lamc3");
    pack_matrix_tiled(&ds.matrix, &path, 64, 64).unwrap();
    let one_chunk_bytes = 64 * 64 * 4;

    let lamc = Lamc::new(fast_config(3));
    let want = lamc.run(&ds.matrix).unwrap();

    for (name, prefetch_budget) in
        [("off", 0usize), ("default", 32 << 20), ("one-chunk", one_chunk_bytes)]
    {
        let reader = StoreReader::open_with_budgets(&path, DEFAULT_CACHE_BYTES, prefetch_budget).unwrap();
        let got = lamc.run(&reader).unwrap();
        assert_eq!(got.row_labels, want.row_labels, "row labels differ (prefetch={name})");
        assert_eq!(got.col_labels, want.col_labels, "col labels differ (prefetch={name})");
        assert_eq!(got.k, want.k, "k differs (prefetch={name})");
        if prefetch_budget == 0 {
            assert_eq!(reader.prefetch_issued(), 0, "budget 0 must disable prefetch");
        }
    }
}

/// A plan that exactly matches the upcoming access pattern wastes
/// nothing: every prefetched chunk is consumed (promoted into the hot
/// cache), none is ever evicted unconsumed, and the demand path never
/// touches the disk at all.
#[test]
fn matching_plan_wastes_zero_bytes() {
    let ds = planted_dense(&PlantedConfig { rows: 200, cols: 100, seed: 0x5A12, ..Default::default() });
    let path = store_dir().join("matching.lamc2");
    pack_matrix(&ds.matrix, &path, 32).unwrap(); // 7 row bands
    let reader = StoreReader::open_with_budgets(&path, DEFAULT_CACHE_BYTES, 32 << 20).unwrap();

    let plan = PartitionPlan {
        phi: 64,
        psi: 50,
        m: 4,
        n: 2,
        t_p: 2,
        certified_probability: 1.0,
        estimated_cost: 0.0,
    };
    let mut rng = Xoshiro256::seed_from(77);
    let rounds = sample_partition(200, 100, &plan, &mut rng);

    // Warm everything the rounds will touch, then access in plan order.
    reader.prefetch_plan(&rounds);
    wait_prefetch_idle(&reader);
    assert_eq!(reader.prefetch_issued(), 7, "every row band fetched exactly once");
    for round in &rounds {
        for job in &round.jobs {
            reader.tile(&job.rows, &job.cols).unwrap();
        }
    }
    assert_eq!(reader.prefetch_wasted_bytes(), 0, "matching plan must waste nothing");
    assert_eq!(reader.prefetch_hits(), 7, "each prefetched band consumed once");
    assert_eq!(reader.chunks_read(), 7, "the demand path never read the disk");
    assert!(reader.cache_hits() > 0, "re-reads served by the hot cache");
}

/// Prefetch must never evict a chunk the current round re-reads: with
/// the same hot-cache budget and the same access sequence, the hot
/// cache hits at least as often with prefetch on as with it off.
#[test]
fn prefetch_never_regresses_hot_cache_hits() {
    let ds = planted_dense(&PlantedConfig { rows: 160, cols: 120, seed: 0x5A13, ..Default::default() });
    let path = store_dir().join("no_regress.lamc3");
    pack_matrix_tiled(&ds.matrix, &path, 32, 40).unwrap();

    let plan = PartitionPlan {
        phi: 80,
        psi: 60,
        m: 2,
        n: 2,
        t_p: 2,
        certified_probability: 1.0,
        estimated_cost: 0.0,
    };
    let hot_budget = 1 << 20;

    let run = |prefetch_budget: usize| -> (u64, u64) {
        let reader = StoreReader::open_with_budgets(&path, hot_budget, prefetch_budget).unwrap();
        let mut rng = Xoshiro256::seed_from(88);
        let rounds = sample_partition(160, 120, &plan, &mut rng);
        if prefetch_budget > 0 {
            reader.prefetch_plan(&rounds);
            wait_prefetch_idle(&reader);
        }
        for round in &rounds {
            for job in &round.jobs {
                reader.tile(&job.rows, &job.cols).unwrap();
            }
        }
        (reader.cache_hits(), reader.prefetch_wasted_bytes())
    };

    let (hits_off, _) = run(0);
    let (hits_on, wasted_on) = run(8 << 20);
    assert!(
        hits_on >= hits_off,
        "prefetch regressed hot-cache hits: {hits_on} < {hits_off}"
    );
    assert_eq!(wasted_on, 0, "ample budget + matching plan wastes nothing");
}
