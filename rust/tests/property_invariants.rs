//! Property-based tests over the library's core invariants (testkit).

use lamc::cocluster::{AtomCocluster, SpectralCocluster};
use lamc::matrix::{CsrMatrix, DenseMatrix, Matrix};
use lamc::merge::{extract_labels, jaccard, merge_coclusters, Cocluster, MergeConfig};
use lamc::metrics::{adjusted_rand_index, normalized_mutual_information};
use lamc::partition::prob_model::{detection_probability, failure_bound, required_samplings, CoclusterPrior};
use lamc::partition::{sample_partition, PartitionPlan};
use lamc::rng::Xoshiro256;
use lamc::testkit::{check, default_cases, in_range};

#[test]
fn prop_csr_dense_round_trip() {
    check(
        "csr↔dense round trip",
        default_cases(),
        |rng| {
            let (m, n) = (rng.next_range(1, 40), rng.next_range(1, 40));
            let nnz = rng.next_below(m * n + 1);
            let trip: Vec<(usize, usize, f32)> = (0..nnz)
                .map(|_| (rng.next_below(m), rng.next_below(n), rng.next_f32() + 0.01))
                .collect();
            (m, n, trip)
        },
        |(m, n, trip)| {
            let s = CsrMatrix::from_triplets(*m, *n, trip.clone());
            let back = CsrMatrix::from_dense(&s.to_dense());
            if back != s {
                return Err("round trip changed the matrix".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_matmul_matches_f64_oracle() {
    check(
        "blocked matmul vs f64 oracle",
        24,
        |rng| {
            let (m, k, n) = (rng.next_range(1, 60), rng.next_range(1, 60), rng.next_range(1, 20));
            (DenseMatrix::randn(m, k, rng), DenseMatrix::randn(k, n, rng))
        },
        |(a, b)| {
            let fast = lamc::linalg::matmul(a, b);
            for i in 0..a.rows() {
                for j in 0..b.cols() {
                    let want: f64 = (0..a.cols()).map(|t| a.get(i, t) as f64 * b.get(t, j) as f64).sum();
                    if (fast.get(i, j) as f64 - want).abs() > 1e-3 {
                        return Err(format!("({i},{j}): {} vs {want}", fast.get(i, j)));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_qr_orthonormal_and_reconstructs() {
    check(
        "householder QR invariants",
        24,
        |rng| {
            let k = rng.next_range(1, 12);
            let m = rng.next_range(k, 80);
            DenseMatrix::randn(m, k, rng)
        },
        |a| {
            let (q, r) = lamc::linalg::qr_thin(a);
            let defect = lamc::linalg::qr::orthonormality_defect(&q);
            if defect > 1e-4 {
                return Err(format!("orthonormality defect {defect}"));
            }
            let back = lamc::linalg::matmul(&q, &r);
            let err = back.max_abs_diff(a);
            if err > 1e-3 {
                return Err(format!("reconstruction error {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metrics_axioms() {
    check(
        "NMI/ARI axioms",
        default_cases(),
        |rng| {
            let n = rng.next_range(2, 200);
            let k = rng.next_range(1, 6);
            let a: Vec<usize> = (0..n).map(|_| rng.next_below(k)).collect();
            let b: Vec<usize> = (0..n).map(|_| rng.next_below(k)).collect();
            (a, b)
        },
        |(a, b)| {
            let nmi = normalized_mutual_information(a, b);
            in_range(nmi, 0.0, 1.0, "nmi")?;
            let ari = adjusted_rand_index(a, b);
            in_range(ari, -1.0, 1.0, "ari")?;
            // Symmetry.
            if (nmi - normalized_mutual_information(b, a)).abs() > 1e-12 {
                return Err("nmi asymmetric".into());
            }
            if (ari - adjusted_rand_index(b, a)).abs() > 1e-12 {
                return Err("ari asymmetric".into());
            }
            // Self-agreement.
            if (normalized_mutual_information(a, a) - 1.0).abs() > 1e-12 {
                return Err("nmi(a,a) != 1".into());
            }
            if (adjusted_rand_index(a, a) - 1.0).abs() > 1e-12 {
                return Err("ari(a,a) != 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metrics_relabel_invariant() {
    check(
        "metrics invariant under label permutation",
        default_cases(),
        |rng| {
            let n = rng.next_range(4, 120);
            let k = rng.next_range(2, 5);
            let a: Vec<usize> = (0..n).map(|_| rng.next_below(k)).collect();
            let b: Vec<usize> = (0..n).map(|_| rng.next_below(k)).collect();
            let perm = rng.permutation(k);
            let b_perm: Vec<usize> = b.iter().map(|&l| perm[l]).collect();
            (a, b, b_perm)
        },
        |(a, b, b_perm)| {
            if (normalized_mutual_information(a, b) - normalized_mutual_information(a, b_perm)).abs() > 1e-12 {
                return Err("nmi not relabel-invariant".into());
            }
            if (adjusted_rand_index(a, b) - adjusted_rand_index(a, b_perm)).abs() > 1e-12 {
                return Err("ari not relabel-invariant".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partition_rounds_cover_exactly() {
    check(
        "every sampling round is a partition of the index space",
        32,
        |rng| {
            let rows = rng.next_range(10, 300);
            let cols = rng.next_range(10, 300);
            let phi = rng.next_range(3, rows);
            let psi = rng.next_range(3, cols);
            let plan = PartitionPlan {
                phi,
                psi,
                m: rows.div_ceil(phi),
                n: cols.div_ceil(psi),
                t_p: rng.next_range(1, 3),
                certified_probability: 1.0,
                estimated_cost: 0.0,
            };
            let mut sub = rng.split();
            let rounds = sample_partition(rows, cols, &plan, &mut sub);
            (rows, cols, plan, rounds)
        },
        |(rows, cols, plan, rounds)| {
            if rounds.len() != plan.t_p {
                return Err("wrong round count".into());
            }
            for round in rounds {
                let mut row_hits = vec![0usize; *rows];
                let mut col_hits = vec![0usize; *cols];
                for job in &round.jobs {
                    for &r in &job.rows {
                        row_hits[r] += 1;
                    }
                    for &c in &job.cols {
                        col_hits[c] += 1;
                    }
                }
                // Each row id appears once per block-column band.
                if row_hits.iter().any(|&h| h != plan.n.min(cols.div_ceil(plan.psi))) {
                    return Err(format!("row coverage {:?}", row_hits.iter().take(5).collect::<Vec<_>>()));
                }
                if col_hits.iter().any(|&h| h != plan.m.min(rows.div_ceil(plan.phi))) {
                    return Err("col coverage wrong".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_theorem1_bound_dominates_monte_carlo() {
    // The paper's central claim (Theorem 1): the analytic failure bound
    // dominates the empirical miss rate of random shuffling.
    check(
        "Thm 1 bound ≥ empirical miss rate",
        8,
        |rng| {
            let total = 150 + rng.next_below(100);
            let frac = 0.15 + 0.2 * rng.next_f64();
            let phi = 30 + rng.next_below(40);
            (total, frac, phi, rng.split())
        },
        |(total, frac, phi, rng)| {
            let prior = CoclusterPrior { row_fraction: *frac, col_fraction: *frac, t_m: 5, t_n: 5 };
            let m = total.div_ceil(*phi);
            let bound = failure_bound(&prior, *phi, *phi, m, m);
            let members = (*total as f64 * frac) as usize;
            let mut rng = rng.clone();
            let trials = 600;
            let mut misses = 0;
            for _ in 0..trials {
                let perm = rng.permutation(*total);
                let mut band_counts = vec![0usize; m];
                for (pos, &id) in perm.iter().enumerate() {
                    if id < members {
                        band_counts[(pos / phi).min(m - 1)] += 1;
                    }
                }
                let col_perm = rng.permutation(*total);
                let mut col_counts = vec![0usize; m];
                for (pos, &id) in col_perm.iter().enumerate() {
                    if id < members {
                        col_counts[(pos / phi).min(m - 1)] += 1;
                    }
                }
                let detected = band_counts.iter().any(|&x| x >= prior.t_m)
                    && col_counts.iter().any(|&x| x >= prior.t_n);
                if !detected {
                    misses += 1;
                }
            }
            let empirical = misses as f64 / trials as f64;
            if empirical > bound + 0.03 {
                return Err(format!("empirical {empirical} > bound {bound}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_required_samplings_is_minimal_and_sufficient() {
    check(
        "Eq. 4 T_p solver minimal + sufficient",
        default_cases(),
        |rng| {
            let prior = CoclusterPrior {
                row_fraction: 0.1 + 0.3 * rng.next_f64(),
                col_fraction: 0.1 + 0.3 * rng.next_f64(),
                t_m: rng.next_range(2, 10),
                t_n: rng.next_range(2, 10),
            };
            let phi = rng.next_range(40, 300);
            let psi = rng.next_range(40, 300);
            let (m, n) = (rng.next_range(2, 8), rng.next_range(2, 8));
            let p = 0.5 + 0.49 * rng.next_f64();
            (prior, phi, psi, m, n, p)
        },
        |(prior, phi, psi, m, n, p)| {
            match required_samplings(prior, *phi, *psi, *m, *n, *p) {
                None => Ok(()), // vacuous bound: nothing to check
                Some(tp) => {
                    let achieved = detection_probability(prior, *phi, *psi, *m, *n, tp);
                    if achieved < *p {
                        return Err(format!("tp={tp} gives {achieved} < {p}"));
                    }
                    if tp > 1 {
                        let under = detection_probability(prior, *phi, *psi, *m, *n, tp - 1);
                        if under >= *p {
                            return Err(format!("tp={tp} not minimal"));
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_merge_output_labels_total_and_bounded() {
    check(
        "merge + extract covers every id with a bounded label",
        32,
        |rng| {
            let rows = rng.next_range(10, 120);
            let cols = rng.next_range(10, 120);
            let n_atoms = rng.next_range(1, 30);
            let atoms: Vec<Cocluster> = (0..n_atoms)
                .map(|_| {
                    let nr = rng.next_range(1, rows);
                    let nc = rng.next_range(1, cols);
                    Cocluster::atom(
                        rng.sample_indices(rows, nr).into_iter().map(|x| x as u32).collect(),
                        rng.sample_indices(cols, nc).into_iter().map(|x| x as u32).collect(),
                        rng.next_f64(),
                    )
                })
                .collect();
            (rows, cols, atoms)
        },
        |(rows, cols, atoms)| {
            let merged = merge_coclusters(atoms.clone(), &MergeConfig::default());
            let (rl, cl, k) = extract_labels(&merged, *rows, *cols);
            if rl.len() != *rows || cl.len() != *cols {
                return Err("label length".into());
            }
            if rl.iter().chain(cl.iter()).any(|&l| l >= k) {
                return Err("label out of range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_merge_idempotent_on_merged_output() {
    check(
        "merging already-merged clusters at τ=1 is identity-sized",
        16,
        |rng| {
            let n_atoms = rng.next_range(2, 20);
            let atoms: Vec<Cocluster> = (0..n_atoms)
                .map(|_| {
                    let base = rng.next_below(4) * 50;
                    let nr = rng.next_range(3, 20);
                    let nc = rng.next_range(3, 20);
                    Cocluster::atom(
                        (0..nr).map(|i| (base + i) as u32).collect(),
                        (0..nc).map(|i| (base + i) as u32).collect(),
                        0.0,
                    )
                })
                .collect();
            atoms
        },
        |atoms| {
            let cfg = MergeConfig::default();
            let once = merge_coclusters(atoms.clone(), &cfg);
            let strict = MergeConfig { tau: 1.0, ..cfg };
            let twice = merge_coclusters(once.clone(), &strict);
            if twice.len() > once.len() {
                return Err(format!("re-merge grew: {} -> {}", once.len(), twice.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_jaccard_bounds_and_identity() {
    check(
        "jaccard axioms",
        default_cases(),
        |rng| {
            let n = rng.next_range(0, 50);
            let mut a: Vec<u32> = (0..n).map(|_| rng.next_below(100) as u32).collect();
            a.sort_unstable();
            a.dedup();
            let m = rng.next_range(0, 50);
            let mut b: Vec<u32> = (0..m).map(|_| rng.next_below(100) as u32).collect();
            b.sort_unstable();
            b.dedup();
            (a, b)
        },
        |(a, b)| {
            let j = jaccard(a, b);
            in_range(j, 0.0, 1.0, "jaccard")?;
            if (jaccard(a, a) - 1.0).abs() > 1e-12 {
                return Err("jaccard(a,a) != 1".into());
            }
            if (j - jaccard(b, a)).abs() > 1e-12 {
                return Err("jaccard asymmetric".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scc_permutation_equivariance() {
    // Permuting rows of the input permutes the row labels identically
    // (up to the same RNG stream). This is the invariant the shuffled
    // partition sampler relies on.
    check(
        "SCC equivariant under row permutation",
        6,
        |rng| {
            let ds = lamc::data::synthetic::planted_dense(&lamc::data::synthetic::PlantedConfig {
                rows: 60,
                cols: 50,
                row_clusters: 3,
                col_clusters: 3,
                noise: 0.05,
                signal: 2.0,
                seed: rng.next_u64(),
                ..Default::default()
            });
            let perm = rng.permutation(60);
            (ds, perm, rng.next_u64())
        },
        |(ds, perm, seed)| {
            let scc = SpectralCocluster::default();
            let dense = ds.matrix.to_dense();
            let mut rng1 = Xoshiro256::seed_from(*seed);
            let base = scc.cocluster(&ds.matrix, 3, &mut rng1);
            let permuted = dense.gather_block(perm, &(0..50).collect::<Vec<_>>());
            let mut rng2 = Xoshiro256::seed_from(*seed);
            let shuffled = scc.cocluster(&Matrix::Dense(permuted), 3, &mut rng2);
            // Same partition structure: NMI between base labels pulled
            // through the permutation and shuffled labels must be 1.
            let pulled: Vec<usize> = perm.iter().map(|&i| base.row_labels[i]).collect();
            let nmi = normalized_mutual_information(&pulled, &shuffled.row_labels);
            if nmi < 0.95 {
                return Err(format!("row-permutation broke SCC: nmi {nmi}"));
            }
            Ok(())
        },
    );
}
