//! Integration: the full LAMC pipeline over planted datasets.

use lamc::data::synthetic::{planted_dense, planted_sparse, PlantedConfig};
use lamc::metrics::score_coclustering;
use lamc::partition::prob_model::CoclusterPrior;
use lamc::partition::PlannerConfig;
use lamc::pipeline::{AtomKind, Lamc, LamcConfig};

fn fast_planner() -> PlannerConfig {
    PlannerConfig {
        candidate_sizes: vec![128, 192, 256],
        prior: CoclusterPrior { row_fraction: 0.18, col_fraction: 0.18, t_m: 6, t_n: 6 },
        max_samplings: 8,
        ..Default::default()
    }
}

#[test]
fn lamc_scc_recovers_dense_structure() {
    let ds = planted_dense(&PlantedConfig {
        rows: 600,
        cols: 500,
        row_clusters: 4,
        col_clusters: 4,
        noise: 0.15,
        signal: 1.5,
        seed: 1001,
        ..Default::default()
    });
    let lamc = Lamc::new(LamcConfig { k: 4, planner: fast_planner(), ..Default::default() });
    let out = lamc.run(&ds.matrix).unwrap();
    let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
    assert!(s.nmi() > 0.7, "nmi {} (k={}, plan {:?})", s.nmi(), out.k, out.plan);
    assert!(s.ari() > 0.5, "ari {}", s.ari());
    // Partitioning actually happened.
    assert!(out.plan.total_blocks() > 1);
    assert_eq!(out.stats.blocks_total as usize, out.plan.total_blocks());
}

#[test]
fn lamc_scc_recovers_sparse_structure() {
    let ds = planted_sparse(&PlantedConfig {
        rows: 900,
        cols: 600,
        row_clusters: 4,
        col_clusters: 4,
        density: 0.06,
        signal: 3.0,
        seed: 1002,
        ..Default::default()
    });
    let lamc = Lamc::new(LamcConfig { k: 4, planner: fast_planner(), ..Default::default() });
    let out = lamc.run(&ds.matrix).unwrap();
    let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
    assert!(s.nmi() > 0.55, "nmi {}", s.nmi());
}

#[test]
fn lamc_pnmtf_runs_end_to_end() {
    let ds = planted_dense(&PlantedConfig {
        rows: 400,
        cols: 300,
        row_clusters: 3,
        col_clusters: 3,
        noise: 0.1,
        signal: 1.5,
        seed: 1003,
        ..Default::default()
    });
    let lamc = Lamc::new(LamcConfig {
        k: 3,
        atom: AtomKind::Pnmtf,
        planner: fast_planner(),
        ..Default::default()
    });
    let out = lamc.run(&ds.matrix).unwrap();
    let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
    assert!(s.nmi() > 0.35, "nmi {}", s.nmi());
}

#[test]
fn lamc_quality_tracks_baseline_on_dense() {
    // The paper's Table III: LAMC trades little quality for its speedup.
    let ds = planted_dense(&PlantedConfig {
        rows: 500,
        cols: 400,
        row_clusters: 4,
        col_clusters: 4,
        noise: 0.15,
        signal: 1.5,
        seed: 1004,
        ..Default::default()
    });
    let lamc = Lamc::new(LamcConfig { k: 4, planner: fast_planner(), ..Default::default() });
    let part = lamc.run(&ds.matrix).unwrap();
    let base = lamc.run_baseline(&ds.matrix).unwrap();
    let s_part = score_coclustering(&ds.row_labels, &part.row_labels, &ds.col_labels, &part.col_labels);
    let s_base = score_coclustering(&ds.row_labels, &base.row_labels, &ds.col_labels, &base.col_labels);
    assert!(
        s_part.nmi() > s_base.nmi() - 0.25,
        "partitioned quality collapsed: {} vs baseline {}",
        s_part.nmi(),
        s_base.nmi()
    );
}

#[test]
fn deterministic_given_seed() {
    let ds = planted_dense(&PlantedConfig { rows: 300, cols: 300, seed: 1005, ..Default::default() });
    let cfg = LamcConfig { k: 4, planner: fast_planner(), seed: 77, ..Default::default() };
    let a = Lamc::new(cfg.clone()).run(&ds.matrix).unwrap();
    let b = Lamc::new(cfg).run(&ds.matrix).unwrap();
    assert_eq!(a.row_labels, b.row_labels);
    assert_eq!(a.col_labels, b.col_labels);
    assert_eq!(a.k, b.k);
}

#[test]
fn label_shapes_always_match_input() {
    for (rows, cols) in [(150, 90), (301, 299), (128, 512)] {
        let ds = planted_dense(&PlantedConfig { rows, cols, seed: 1006, ..Default::default() });
        let out = Lamc::new(LamcConfig { k: 4, planner: fast_planner(), ..Default::default() })
            .run(&ds.matrix)
            .unwrap();
        assert_eq!(out.row_labels.len(), rows);
        assert_eq!(out.col_labels.len(), cols);
        assert!(out.row_labels.iter().all(|&l| l < out.k));
        assert!(out.col_labels.iter().all(|&l| l < out.k));
    }
}

#[test]
fn small_matrix_falls_back_to_whole_plan() {
    let ds = planted_dense(&PlantedConfig { rows: 80, cols: 80, seed: 1007, ..Default::default() });
    let out = Lamc::new(LamcConfig { k: 3, planner: fast_planner(), ..Default::default() })
        .run(&ds.matrix)
        .unwrap();
    assert_eq!(out.plan.total_blocks(), 1, "tiny input should not partition");
    assert_eq!(out.row_labels.len(), 80);
}
