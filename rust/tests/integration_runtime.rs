//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a loud message) when the manifest is absent so `cargo test`
//! stays usable on a fresh checkout.

use std::sync::Arc;

use lamc::data::synthetic::{planted_dense, PlantedConfig};
use lamc::matrix::Matrix;
use lamc::metrics::score_coclustering;
use lamc::partition::prob_model::CoclusterPrior;
use lamc::partition::PlannerConfig;
use lamc::pipeline::{AtomKind, Lamc, LamcConfig};
use lamc::runtime::{Manifest, RuntimePool, RuntimePoolConfig};

fn pool() -> Option<Arc<RuntimePool>> {
    let Some(path) = lamc::runtime::find_manifest() else {
        eprintln!("SKIP: artifacts/manifest.tsv not found — run `make artifacts`");
        return None;
    };
    let manifest = Manifest::load(&path).expect("manifest parses");
    Some(RuntimePool::start(manifest, RuntimePoolConfig { servers: 2 }).expect("pool starts"))
}

fn planted_block(rows: usize, cols: usize, k: usize, seed: u64) -> (lamc::matrix::DenseMatrix, Vec<usize>, Vec<usize>) {
    let ds = planted_dense(&PlantedConfig {
        rows,
        cols,
        row_clusters: k,
        col_clusters: k,
        noise: 0.1,
        signal: 1.5,
        seed,
        ..Default::default()
    });
    (ds.matrix.to_dense(), ds.row_labels, ds.col_labels)
}

#[test]
fn every_artifact_loads_and_executes() {
    let Some(pool) = pool() else { return };
    for spec in &pool.manifest().artifacts.clone() {
        let spec = pool.spec_for(&spec.kind, spec.phi, spec.psi, 2).expect("spec self-fit");
        let (block, _, _) = planted_block(spec.phi, spec.psi, 2, 2001);
        let out = pool.execute(Arc::clone(&spec), block, 2, 7).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        out.validate(spec.phi, spec.psi).unwrap();
        eprintln!("artifact {} ok (objective {:.4})", spec.name, out.objective);
    }
}

#[test]
fn scc_artifact_recovers_planted_block() {
    let Some(pool) = pool() else { return };
    let spec = pool.spec_for("scc_block", 256, 256, 4).expect("scc_256 exists");
    let (block, rl, cl) = planted_block(256, 256, 4, 2002);
    let out = pool.execute(spec, block, 4, 11).expect("execute");
    let s = score_coclustering(&rl, &out.row_labels, &cl, &out.col_labels);
    assert!(s.nmi() > 0.75, "pjrt scc nmi {}", s.nmi());
}

#[test]
fn padded_execution_matches_exact_region() {
    // A 200x190 block padded into the 256x256 artifact must cluster the
    // real region as well as the native route does.
    let Some(pool) = pool() else { return };
    let spec = pool.spec_for("scc_block", 200, 190, 3).expect("fit");
    let (block, rl, cl) = planted_block(200, 190, 3, 2003);
    let out = pool.execute(spec, block.clone(), 3, 13).expect("execute");
    out.validate(200, 190).unwrap();
    let s = score_coclustering(&rl, &out.row_labels, &cl, &out.col_labels);
    assert!(s.nmi() > 0.8, "padded pjrt nmi {}", s.nmi());
}

#[test]
fn pjrt_and_native_routes_agree_on_quality() {
    let Some(pool) = pool() else { return };
    let spec = pool.spec_for("scc_block", 256, 256, 4).expect("fit");
    let (block, rl, cl) = planted_block(256, 256, 4, 2004);
    let pjrt = pool.execute(spec, block.clone(), 4, 17).expect("pjrt");
    let native = {
        use lamc::cocluster::AtomCocluster;
        let mut rng = lamc::rng::Xoshiro256::seed_from(17);
        lamc::cocluster::SpectralCocluster::default().cocluster(&Matrix::Dense(block), 4, &mut rng)
    };
    let s_pjrt = score_coclustering(&rl, &pjrt.row_labels, &cl, &pjrt.col_labels);
    let s_native = score_coclustering(&rl, &native.row_labels, &cl, &native.col_labels);
    assert!(
        (s_pjrt.nmi() - s_native.nmi()).abs() < 0.15,
        "route quality diverged: pjrt {} native {}",
        s_pjrt.nmi(),
        s_native.nmi()
    );
}

#[test]
fn pnmtf_artifact_recovers_planted_block() {
    let Some(pool) = pool() else { return };
    let spec = pool.spec_for("pnmtf_block", 128, 128, 3).expect("pnmtf_128 exists");
    let (block, rl, cl) = planted_block(128, 128, 3, 2005);
    let out = pool.execute(spec, block, 3, 19).expect("execute");
    let s = score_coclustering(&rl, &out.row_labels, &cl, &out.col_labels);
    assert!(s.nmi() > 0.5, "pjrt pnmtf nmi {}", s.nmi());
}

#[test]
fn full_pipeline_on_pjrt_route() {
    let Some(pool) = pool() else { return };
    let ds = planted_dense(&PlantedConfig {
        rows: 700,
        cols: 600,
        row_clusters: 4,
        col_clusters: 4,
        noise: 0.15,
        signal: 1.5,
        seed: 2006,
        ..Default::default()
    });
    let lamc = Lamc::new(LamcConfig {
        k: 4,
        atom: AtomKind::Scc,
        runtime: Some(pool),
        planner: PlannerConfig {
            prior: CoclusterPrior { row_fraction: 0.18, col_fraction: 0.18, t_m: 6, t_n: 6 },
            max_samplings: 6,
            ..Default::default()
        },
        ..Default::default()
    });
    let out = lamc.run(&ds.matrix).unwrap();
    assert!(out.stats.blocks_pjrt > 0, "no blocks took the PJRT route: {}", out.stats);
    assert_eq!(out.stats.pjrt_fallbacks, 0, "pjrt route had failures: {}", out.stats);
    let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
    assert!(s.nmi() > 0.6, "pjrt pipeline nmi {}", s.nmi());
}

#[test]
fn invalid_requests_are_rejected_not_crashed() {
    let Some(pool) = pool() else { return };
    let spec = pool.spec_for("scc_block", 128, 128, 2).expect("fit");
    // Block bigger than the artifact.
    let (big, _, _) = planted_block(spec.phi + 1, 10, 2, 2007);
    assert!(pool.execute(Arc::clone(&spec), big, 2, 1).is_err());
    // k over kmax.
    let (ok, _, _) = planted_block(64, 64, 2, 2008);
    assert!(pool.execute(spec, ok, 99, 1).is_err());
}
