//! Runtime pool: a handle that fans [`ExecRequest`]s out to PJRT server
//! threads and exposes a blocking `execute` API usable from any worker
//! of the §IV-C scheduler.

use std::sync::{mpsc, Arc, Mutex};

use anyhow::{Context, Result};

use crate::cocluster::CoclusterResult;
use crate::matrix::DenseMatrix;

use super::artifact::{ArtifactSpec, Manifest};
use super::server::{serve, ExecRequest};

#[derive(Clone, Debug)]
pub struct RuntimePoolConfig {
    /// Dedicated PJRT server threads. XLA's CPU executor is itself
    /// multithreaded, so 1–2 servers usually saturate a workstation.
    pub servers: usize,
}

impl Default for RuntimePoolConfig {
    fn default() -> Self {
        Self { servers: 2 }
    }
}

/// Shared, cloneable handle to the PJRT server threads.
///
/// Dropping the last handle closes the request channel, which shuts the
/// servers down; `JoinHandle`s are detached (server loops hold no state
/// that needs flushing).
pub struct RuntimePool {
    manifest: Manifest,
    specs: Vec<Arc<ArtifactSpec>>,
    tx: mpsc::Sender<ExecRequest>,
}

impl RuntimePool {
    /// Spin up servers for every artifact in the manifest.
    pub fn start(manifest: Manifest, config: RuntimePoolConfig) -> Result<Arc<Self>> {
        anyhow::ensure!(!manifest.artifacts.is_empty(), "manifest has no artifacts");
        for a in &manifest.artifacts {
            anyhow::ensure!(a.path.exists(), "artifact file missing: {:?} (run `make artifacts`)", a.path);
        }
        let (tx, rx) = mpsc::channel::<ExecRequest>();
        let shared_rx = Arc::new(Mutex::new(rx));
        for i in 0..config.servers.max(1) {
            let queue = Arc::clone(&shared_rx);
            std::thread::Builder::new()
                .name(format!("pjrt-server-{i}"))
                .spawn(move || serve(queue))
                .context("spawn pjrt server")?;
        }
        let specs = manifest.artifacts.iter().cloned().map(Arc::new).collect();
        Ok(Arc::new(Self { manifest, specs, tx }))
    }

    /// Convenience: locate the manifest on disk and start.
    pub fn from_default_manifest(config: RuntimePoolConfig) -> Result<Arc<Self>> {
        let path = super::find_manifest().context("artifacts/manifest.tsv not found (run `make artifacts`)")?;
        let manifest = Manifest::load(&path)?;
        Self::start(manifest, config)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Find the best-fitting artifact spec for a block, if any.
    pub fn spec_for(&self, kind: &str, rows: usize, cols: usize, k: usize) -> Option<Arc<ArtifactSpec>> {
        let spec = self.manifest.best_fit(kind, rows, cols, k)?;
        self.specs.iter().find(|s| s.name == spec.name).cloned()
    }

    /// Execute a block co-clustering on the PJRT route (blocking).
    pub fn execute(&self, spec: Arc<ArtifactSpec>, block: DenseMatrix, k: usize, seed: i32) -> Result<CoclusterResult> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(ExecRequest { spec, block, k, seed, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("runtime pool is shut down"))?;
        reply_rx.recv().context("pjrt server dropped the reply channel")?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn start_rejects_empty_manifest() {
        let m = Manifest::default();
        assert!(RuntimePool::start(m, RuntimePoolConfig::default()).is_err());
    }

    #[test]
    fn start_rejects_missing_files() {
        let m = Manifest::parse(
            "name\tkind\tphi\tpsi\trank\tkmax\tkmeans_iters\tpath\nx\tscc_block\t8\t8\t2\t4\t4\tdoes_not_exist.hlo.txt\n",
            Path::new("/nonexistent"),
        )
        .unwrap();
        let err = match RuntimePool::start(m, RuntimePoolConfig::default()) {
            Err(e) => e,
            Ok(_) => panic!("expected missing-file error"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
