//! PJRT server thread: owns a (non-`Send`) client + compiled executables
//! and serves one §IV-C block co-clustering request at a time.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cocluster::CoclusterResult;
use crate::matrix::DenseMatrix;

use super::artifact::ArtifactSpec;

/// A block-co-clustering request for the PJRT route.
pub struct ExecRequest {
    pub spec: Arc<ArtifactSpec>,
    /// The gathered block (r ≤ φ, c ≤ ψ); the server zero-pads.
    pub block: DenseMatrix,
    /// Number of co-clusters to extract (≤ spec.kmax).
    pub k: usize,
    /// PRNG seed for the in-graph sketch + k-means init.
    pub seed: i32,
    /// Reply channel.
    pub reply: mpsc::Sender<Result<CoclusterResult>>,
}

/// Shared FIFO the servers pull from.
pub type SharedQueue = Arc<std::sync::Mutex<mpsc::Receiver<ExecRequest>>>;

/// Server main loop: compile-on-first-use cache keyed by artifact name.
pub fn serve(queue: SharedQueue) {
    // Client creation can fail only on catastrophic PJRT issues; in that
    // case every request gets the error forwarded.
    let client = xla::PjRtClient::cpu();
    let mut executables: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    loop {
        let req = {
            let guard = queue.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // pool dropped the sender: shut down
            }
        };
        let result = match &client {
            Ok(c) => execute(c, &mut executables, &req),
            Err(e) => Err(anyhow::anyhow!("PJRT client init failed: {e}")),
        };
        // Receiver may have given up (timeout); ignore send errors.
        let _ = req.reply.send(result);
    }
}

fn get_executable<'a>(
    client: &xla::PjRtClient,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    spec: &ArtifactSpec,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(&spec.name) {
        let proto = xla::HloModuleProto::from_text_file(
            spec.path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("load HLO {:?}: {e}", spec.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", spec.name))?;
        cache.insert(spec.name.clone(), exe);
    }
    Ok(cache.get(&spec.name).unwrap())
}

fn execute(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    req: &ExecRequest,
) -> Result<CoclusterResult> {
    let spec = &req.spec;
    let (r, c) = (req.block.rows(), req.block.cols());
    anyhow::ensure!(r <= spec.phi && c <= spec.psi, "block {r}x{c} exceeds artifact {}x{}", spec.phi, spec.psi);
    anyhow::ensure!(req.k >= 1 && req.k <= spec.kmax, "k={} outside artifact kmax={}", req.k, spec.kmax);

    let exe = get_executable(client, cache, spec)?;

    let padded = if r == spec.phi && c == spec.psi {
        req.block.clone()
    } else {
        req.block.pad_to(spec.phi, spec.psi)
    };
    let a = xla::Literal::vec1(padded.data())
        .reshape(&[spec.phi as i64, spec.psi as i64])
        .map_err(|e| anyhow::anyhow!("reshape block literal: {e}"))?;
    let seed = xla::Literal::vec1(&[req.seed]);
    let k_lit = xla::Literal::vec1(&[req.k as i32]);
    // Centroid init indices into the stacked embedding [rows; cols]:
    // strided picks across real (non-padding) rows and cols, seed-rotated.
    let mut init = Vec::with_capacity(spec.kmax);
    let offset = (req.seed.unsigned_abs() as usize) % r.max(1);
    for t in 0..spec.kmax {
        let idx = if t % 2 == 0 {
            // row-side pick
            (offset + t * r / spec.kmax.max(1)) % r.max(1)
        } else {
            // col-side pick, offset past the φ row slots
            spec.phi + ((offset + t * c / spec.kmax.max(1)) % c.max(1))
        };
        init.push(idx as i32);
    }
    let init_lit = xla::Literal::vec1(&init);
    // Actual (unpadded) block dims: the graph masks padding out of the
    // embedding, centroid updates and the objective.
    let dims = xla::Literal::vec1(&[r as i32, c as i32]);

    let mut result = exe
        .execute::<xla::Literal>(&[a, seed, k_lit, init_lit, dims])
        .map_err(|e| anyhow::anyhow!("execute {}: {e}", spec.name))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
    let parts = result
        .decompose_tuple()
        .map_err(|e| anyhow::anyhow!("decompose result tuple: {e}"))?;
    anyhow::ensure!(parts.len() == 3, "artifact returned {} outputs, want 3", parts.len());
    let row_labels_full: Vec<i32> = parts[0].to_vec().map_err(|e| anyhow::anyhow!("row labels: {e}"))?;
    let col_labels_full: Vec<i32> = parts[1].to_vec().map_err(|e| anyhow::anyhow!("col labels: {e}"))?;
    let inertia: Vec<f32> = parts[2].to_vec().map_err(|e| anyhow::anyhow!("inertia: {e}"))?;

    // Crop padding; clamp defensively so a buggy artifact cannot poison
    // downstream label arrays.
    let k = req.k;
    let row_labels = row_labels_full[..r].iter().map(|&l| (l.max(0) as usize).min(k - 1)).collect();
    let col_labels = col_labels_full[..c].iter().map(|&l| (l.max(0) as usize).min(k - 1)).collect();
    Ok(CoclusterResult {
        row_labels,
        col_labels,
        k,
        objective: inertia.first().copied().unwrap_or(f32::NAN) as f64,
    })
}
