//! Artifact manifest: what `python/compile/aot.py` emitted — the static
//! block shapes the §IV-C parallel co-clustering stage can offload.
//!
//! Format: TSV with header, one row per compiled HLO module:
//! `name  kind  phi  psi  rank  kmax  kmeans_iters  path`
//! (paths relative to the manifest's directory). TSV keeps the rust side
//! dependency-free — no JSON parser needed.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One compiled block-co-clustering executable.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// Graph kind: "scc_block" (spectral) or "pnmtf_block".
    pub kind: String,
    /// Static block rows the module was lowered for.
    pub phi: usize,
    /// Static block cols.
    pub psi: usize,
    /// Embedding rank (spectral) / factor rank (pnmtf).
    pub rank: usize,
    /// Maximum k supported (runtime `k` input is masked up to this).
    pub kmax: usize,
    /// k-means / update iterations baked into the graph.
    pub iters: usize,
    /// Absolute path to the HLO text file.
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str, base: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        let mut lines = text.lines().enumerate();
        let Some((_, header)) = lines.next() else {
            bail!("empty manifest");
        };
        let want = "name\tkind\tphi\tpsi\trank\tkmax\tkmeans_iters\tpath";
        if header.trim() != want {
            bail!("unexpected manifest header:\n  got  {header}\n  want {want}");
        }
        for (no, line) in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 8 {
                bail!("manifest line {}: expected 8 columns, got {}", no + 1, cols.len());
            }
            let parse = |s: &str, what: &str| -> Result<usize> {
                s.parse::<usize>().with_context(|| format!("manifest line {}: bad {what}: {s}", no + 1))
            };
            artifacts.push(ArtifactSpec {
                name: cols[0].to_string(),
                kind: cols[1].to_string(),
                phi: parse(cols[2], "phi")?,
                psi: parse(cols[3], "psi")?,
                rank: parse(cols[4], "rank")?,
                kmax: parse(cols[5], "kmax")?,
                iters: parse(cols[6], "kmeans_iters")?,
                path: base.join(cols[7]),
            });
        }
        Ok(Self { artifacts })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read manifest {path:?}"))?;
        let base = path.parent().unwrap_or(Path::new("."));
        Self::parse(&text, base)
    }

    /// Find the smallest artifact of `kind` that fits an `r×c` block
    /// (block is zero-padded up to the artifact's static shape).
    pub fn best_fit(&self, kind: &str, r: usize, c: usize, k: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.phi >= r && a.psi >= c && a.kmax >= k)
            .min_by_key(|a| a.phi * a.psi)
    }

    /// Block shapes available for `kind` — fed to the partition planner
    /// as preferred candidate sizes so whole grids hit the PJRT route.
    pub fn candidate_sizes(&self, kind: &str) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .flat_map(|a| [a.phi, a.psi])
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name\tkind\tphi\tpsi\trank\tkmax\tkmeans_iters\tpath\n\
scc_256\tscc_block\t256\t256\t6\t8\t16\tscc_256.hlo.txt\n\
scc_512\tscc_block\t512\t512\t6\t8\t16\tscc_512.hlo.txt\n\
pnmtf_256\tpnmtf_block\t256\t256\t8\t8\t30\tpnmtf_256.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].phi, 256);
        assert_eq!(m.artifacts[0].path, Path::new("/tmp/a/scc_256.hlo.txt"));
    }

    #[test]
    fn best_fit_prefers_smallest_fitting() {
        let m = Manifest::parse(SAMPLE, Path::new("")).unwrap();
        assert_eq!(m.best_fit("scc_block", 200, 256, 4).unwrap().name, "scc_256");
        assert_eq!(m.best_fit("scc_block", 300, 100, 4).unwrap().name, "scc_512");
        assert!(m.best_fit("scc_block", 600, 600, 4).is_none());
        assert!(m.best_fit("scc_block", 10, 10, 99).is_none());
    }

    #[test]
    fn candidate_sizes_deduped_sorted() {
        let m = Manifest::parse(SAMPLE, Path::new("")).unwrap();
        assert_eq!(m.candidate_sizes("scc_block"), vec![256, 512]);
        assert_eq!(m.candidate_sizes("pnmtf_block"), vec![256]);
    }

    #[test]
    fn rejects_bad_header_and_columns() {
        assert!(Manifest::parse("nope\n", Path::new("")).is_err());
        let bad = "name\tkind\tphi\tpsi\trank\tkmax\tkmeans_iters\tpath\nx\tonly-two\n";
        assert!(Manifest::parse(bad, Path::new("")).is_err());
    }
}
