//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! (the accelerated execution backend for the paper's §IV-C parallel
//! block co-clustering; compiled only with the `pjrt` cargo feature).
//!
//! Layer-2/-1 computations are lowered once at build time
//! (`make artifacts` → `artifacts/*.hlo.txt` + `artifacts/manifest.tsv`)
//! and served from here on the request path — Python is never invoked.
//!
//! Threading model: the `xla` crate's `PjRtClient` is `Rc`-backed (not
//! `Send`), so the pool spawns dedicated **server threads**, each owning
//! its own CPU client and lazily-compiled executables. Callers submit
//! [`server::ExecRequest`]s over a channel and block on a per-request
//! reply channel. XLA's CPU executor is internally multithreaded, so a
//! small number of servers saturates the machine.

pub mod artifact;
pub mod pool;
pub mod server;

pub use artifact::{ArtifactSpec, Manifest};
pub use pool::{RuntimePool, RuntimePoolConfig};

/// Default location of the artifact manifest relative to the repo root.
pub const DEFAULT_MANIFEST: &str = "artifacts/manifest.tsv";

/// Locate the artifacts directory: `LAMC_ARTIFACTS` env override, else
/// walk up from the current dir looking for `artifacts/manifest.tsv`.
pub fn find_manifest() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("LAMC_ARTIFACTS") {
        let p = std::path::PathBuf::from(p).join("manifest.tsv");
        return p.exists().then_some(p);
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_MANIFEST);
        if cand.exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
