//! `lamc` — launcher for the LAMC co-clustering framework.
//!
//! Commands:
//! * `run`      — run LAMC (or a baseline) on a named dataset, report
//!                time + NMI/ARI against the planted ground truth.
//! * `plan`     — show the partition plan the probabilistic model picks.
//! * `pack`     — convert a dataset or matrix file into a chunked store
//!                (row-band LAMC2, or tiled LAMC3 with `--chunk-cols`)
//!                for out-of-core runs.
//! * `ingest`   — stream rows from stdin into a store.
//! * `repack`   — re-chunk an existing store (row-band ↔ tiled, new
//!                band/tile extents) store-to-store, without
//!                materializing the matrix.
//! * `inspect`  — print (and optionally checksum-verify) a store's
//!                self-description.
//! * `shard`    — split a store into contiguous row-band shard stores
//!                plus a band-ownership manifest (LAMCM1).
//! * `serve`    — run the long-lived co-clustering service (TCP);
//!                `--shards` registers shard bands for routed runs.
//! * `route`    — run a shard router fronting multiple worker nodes.
//! * `submit`   — submit a job to a running service (or router).
//! * `status`   — query a job's state (or server-wide stats) on a
//!                running service.
//! * `append`   — append rows (stdin) to a store-backed matrix on a
//!                running service; the server seals them as new row
//!                bands and queues an incremental re-clustering.
//! * `watch`    — stream a job's lifecycle events (EVENTS cursor
//!                protocol) until it finishes, or follow a matrix's
//!                append/label-update feed (`--follow`, SUBSCRIBE verb).
//! * `profile`  — print a job's span tree with critical-path analysis
//!                (SPANS verb).
//! * `trace-export` — dump a job's span tree as Chrome trace-event
//!                JSON (load in Perfetto or chrome://tracing).
//! * `metrics`  — print a running service's Prometheus-style metrics
//!                exposition (METRICS verb).
//! * `load`     — load a dataset, matrix file or store on a running
//!                service.
//! * `shutdown` — ask a running service to stop accepting connections.
//! * `datasets` — list available dataset specs.
//! * `artifacts`— show the AOT artifact manifest the runtime would use.
//! * `version`  — print the crate version.
//!
//! Examples:
//! ```text
//! lamc run --dataset amazon1000 --method lamc-scc --k 5
//! lamc plan --rows 18000 --cols 1000 --p-thresh 0.99
//! lamc pack --dataset rcv1_large --output rcv1.lamc2
//! lamc repack --store rcv1.lamc2 --output rcv1.lamc3 --chunk-cols 256
//! lamc inspect --store rcv1.lamc3 --verify
//! lamc serve --addr 127.0.0.1:4666 --store-root /var/lib/lamc
//! lamc load --addr 127.0.0.1:4666 --name rcv1 --store rcv1.lamc2
//! lamc submit --addr 127.0.0.1:4666 --matrix rcv1 --k 6 --wait
//! lamc status --addr 127.0.0.1:4666 --id 1
//! ```
//!
//! Unknown commands or flags print the usage to stderr and exit
//! non-zero.

#![allow(unknown_lints)]
#![allow(clippy::field_reassign_with_default)]

use std::io::BufRead;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use lamc::cli::Args;
use lamc::data;
use lamc::metrics::score_coclustering;
use lamc::partition::{plan, PlannerConfig};
use lamc::pipeline::{AtomKind, Lamc, LamcConfig};
#[cfg(feature = "pjrt")]
use lamc::runtime::{Manifest, RuntimePool, RuntimePoolConfig};
use lamc::service::{JobSpec, ServiceClient, ServiceConfig, ServiceManager, ServiceServer};
use lamc::store::{ChunkWriter, Layout, StoreReader, StoreSummary, DEFAULT_CHUNK_ROWS};

const USAGE: &str = "\
lamc — Large-scale Adaptive Matrix Co-clustering

USAGE:
  lamc run      --dataset <amazon1000|classic4|rcv1_large> [--method lamc-scc|lamc-pnmtf|scc|pnmtf]
                [--k N] [--rows N] [--seed N] [--workers N] [--p-thresh F]
                [--tau F] [--no-runtime] [--verbose]
  lamc plan     --rows N --cols N [--p-thresh F] [--row-frac F] [--col-frac F]
  lamc pack     (--dataset NAME [--rows N] [--seed N] | --input FILE.lamc|.mtx)
                --output FILE [--chunk-rows N] [--codec none|shuffle-lz]
                [--chunk-cols N|auto (tiled LAMC3; auto = planner dry-run psi)]
  lamc ingest   --output FILE --cols N [--format dense|sparse] [--chunk-rows N]
                [--chunk-cols N|auto] [--rows-hint N (required by auto)]
                [--codec none|shuffle-lz] (rows on stdin; see docs/STORE.md)
  lamc repack   --store FILE --output FILE [--chunk-rows N]
                [--chunk-cols N|0|auto (0 = row-band)] [--cache-mb N]
                [--codec none|shuffle-lz (recompress or decompress)]
  lamc inspect  --store FILE [--verify]
  lamc shard    --store FILE --output-dir DIR --shards N [--stem NAME]
  lamc serve    [--addr HOST:PORT] [--runners N] [--queue N] [--cache-mb N]
                [--store-root DIR] [--cache-disk-mb N] [--stores name=file.lamc2,...]
                [--shards name=manifest.lamcm[:IDX:IDX...],...]
                [--datasets a,b] [--seed N] [--job-ttl SECS|0=keep] [--verbose]
  lamc route    [--addr HOST:PORT] --workers HOST:PORT,HOST:PORT,...
                [--retries N] [--io-timeout SECS] [--job-timeout SECS]
  lamc submit   [--addr HOST:PORT] --matrix NAME [--method M] [--k N] [--seed N]
                [--p-thresh F] [--tau F] [--workers N] [--wait] [--timeout SECS]
                [--labels-out FILE (with --wait)]
  lamc status   [--addr HOST:PORT] [--id N]
  lamc append   [--addr HOST:PORT] --name NAME --cols N [--format dense|sparse]
                (rows on stdin, ingest formats; see docs/STORE.md)
  lamc watch    [--addr HOST:PORT] (--id N | --name NAME --follow [--once])
                [--timeout SECS]
  lamc profile  [--addr HOST:PORT] --id N
  lamc trace-export [--addr HOST:PORT] --id N [--format chrome] [--out FILE]
  lamc metrics  [--addr HOST:PORT]
  lamc load     [--addr HOST:PORT] --name NAME
                (--dataset D [--rows N] [--seed N] | --path FILE | --store FILE.lamc2)
  lamc shutdown [--addr HOST:PORT]
  lamc datasets
  lamc artifacts
  lamc version
";

const DEFAULT_ADDR: &str = "127.0.0.1:4666";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        if e.is::<lamc::cli::UsageError>() {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&["verbose", "no-runtime", "help", "wait", "verify", "follow", "once"])?;
    if args.has("verbose") {
        lamc::logging::set_level(lamc::logging::Level::Debug);
    }
    if args.has("help") || args.command.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.command.as_deref().unwrap() {
        "run" => cmd_run(&args),
        "plan" => cmd_plan(&args),
        "pack" => cmd_pack(&args),
        "ingest" => cmd_ingest(&args),
        "repack" => cmd_repack(&args),
        "inspect" => cmd_inspect(&args),
        "shard" => cmd_shard(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "submit" => cmd_submit(&args),
        "status" => cmd_status(&args),
        "append" => cmd_append(&args),
        "watch" => cmd_watch(&args),
        "profile" => cmd_profile(&args),
        "trace-export" => cmd_trace_export(&args),
        "metrics" => cmd_metrics(&args),
        "load" => cmd_load(&args),
        "shutdown" => cmd_shutdown(&args),
        "datasets" => cmd_datasets(&args),
        "artifacts" => cmd_artifacts(&args),
        "version" => cmd_version(&args),
        other => Err(lamc::cli::UsageError(format!("unknown command '{other}'")).into()),
    }
}

/// The self-description lines shared by `pack`/`ingest`/`repack`
/// summaries and `inspect` — one printer so the two can never diverge
/// (CI greps this text).
#[allow(clippy::too_many_arguments)]
fn print_store_description(
    tiled: bool,
    layout: Layout,
    rows: usize,
    cols: usize,
    nnz: u64,
    chunks: usize,
    chunk_rows: usize,
    chunk_cols: usize,
) {
    println!("format      : {}", if tiled { "lamc3 (tiled)" } else { "lamc2 (row-band)" });
    println!("layout      : {}", layout.as_str());
    println!("shape       : {rows} x {cols} ({nnz} stored entries)");
    if tiled {
        println!("chunks      : {chunks} tiles of {chunk_rows} x {chunk_cols}");
    } else {
        println!("chunks      : {chunks} bands of {chunk_rows} rows");
    }
}

fn print_summary(s: &StoreSummary) {
    println!("store       : {:?}", s.path);
    print_store_description(
        s.tiled, s.layout, s.rows, s.cols, s.nnz, s.chunks, s.chunk_rows, s.chunk_cols,
    );
    println!("codec       : {}", s.codec.as_str());
    if s.codec != lamc::store::Codec::None && s.raw_payload_bytes > 0 {
        println!(
            "payload     : {} -> {} bytes stored ({:.1}% of raw)",
            s.raw_payload_bytes,
            s.stored_payload_bytes,
            100.0 * s.stored_payload_bytes as f64 / s.raw_payload_bytes as f64
        );
    }
    println!("fingerprint : {:016x}", s.fingerprint);
    println!("file size   : {} bytes", s.file_bytes);
}

/// Resolve a `--chunk-cols` value against known matrix dims: a tile
/// width, `auto` (ψ from a planner dry run on the dims — LAMC3 tiles
/// aligned with the column spans the pipeline will gather), or absent
/// (0 = row-band layout). `auto` collapsing to ≥ `cols` means the
/// planner would not partition: one full-width band, i.e. row-band.
fn resolve_chunk_cols(args: &Args, rows: usize, cols: usize) -> Result<usize> {
    match args.get("chunk-cols") {
        None => Ok(0),
        Some("auto") => {
            let psi = lamc::partition::auto_chunk_cols(rows, cols);
            if psi >= cols {
                println!("chunk-cols  : auto -> row-band (planner keeps {rows} x {cols} whole)");
                Ok(0)
            } else {
                println!("chunk-cols  : auto -> {psi} (planner dry-run psi for {rows} x {cols})");
                Ok(psi)
            }
        }
        Some(_) => args.get_usize("chunk-cols", 0),
    }
}

/// Resolve a `--codec` value (absent = uncompressed payloads).
fn resolve_codec(args: &Args) -> Result<lamc::store::Codec> {
    match args.get("codec") {
        None => Ok(lamc::store::Codec::None),
        Some(s) => lamc::store::Codec::parse(s).ok_or_else(|| {
            lamc::cli::UsageError(format!("unknown --codec '{s}' (want none|shuffle-lz)")).into()
        }),
    }
}

fn cmd_pack(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "dataset",
        "input",
        "output",
        "rows",
        "seed",
        "chunk-rows",
        "chunk-cols",
        "codec",
    ])?;
    let output = PathBuf::from(args.get("output").context("--output required")?);
    let chunk_rows = args.get_usize("chunk-rows", DEFAULT_CHUNK_ROWS)?;
    let matrix = match (args.get("dataset"), args.get("input")) {
        (Some(name), None) => {
            let rows = args.get("rows").map(|r| r.parse::<usize>()).transpose()?;
            let seed = args.get_u64("seed", 42)?;
            data::datasets::build(name, rows, seed)
                .with_context(|| format!("unknown dataset '{name}'"))?
                .matrix
        }
        (None, Some(file)) => {
            let path = Path::new(file);
            if path.extension().and_then(|e| e.to_str()) == Some("mtx") {
                lamc::matrix::Matrix::Sparse(lamc::matrix::io::read_matrix_market(path)?)
            } else {
                lamc::matrix::io::load(path)?
            }
        }
        _ => {
            return Err(lamc::cli::UsageError(
                "pack needs exactly one of --dataset or --input".into(),
            )
            .into())
        }
    };
    let chunk_cols = resolve_chunk_cols(args, matrix.rows(), matrix.cols())?;
    let codec = resolve_codec(args)?;
    let summary = if chunk_cols > 0 {
        lamc::store::pack_matrix_tiled_with_codec(&matrix, &output, chunk_rows, chunk_cols, codec)?
    } else {
        lamc::store::pack_matrix_with_codec(&matrix, &output, chunk_rows, codec)?
    };
    print_summary(&summary);
    Ok(())
}

/// Re-chunk a store into a new geometry, streaming band by band —
/// `--chunk-cols N` produces a tiled (LAMC3) store, `0` (or absent,
/// when the source is row-band) a row-band one. Band/tile extents
/// default to the source's.
fn cmd_repack(args: &Args) -> Result<()> {
    args.expect_flags(&["store", "output", "chunk-rows", "chunk-cols", "cache-mb", "codec"])?;
    let store = PathBuf::from(args.get("store").context("--store required")?);
    let output = PathBuf::from(args.get("output").context("--output required")?);
    let cache_budget = args.get_usize("cache-mb", 0)? << 20;
    let reader = StoreReader::open_with_cache(&store, cache_budget)?;
    let h = reader.header();
    let chunk_rows = args.get_usize("chunk-rows", h.chunk_rows)?;
    let chunk_cols = match args.get("chunk-cols") {
        // `auto`: ψ dry run on the source header dims (rows are known
        // here, unlike ingest — the store is self-describing).
        Some("auto") => match resolve_chunk_cols(args, h.rows, h.cols)? {
            0 => None,
            w => Some(w),
        },
        Some(_) => match args.get_usize("chunk-cols", 0)? {
            0 => None,
            w => Some(w),
        },
        None if h.is_tiled() => Some(h.chunk_cols),
        None => None,
    };
    // Like the geometry flags, --codec defaults to the source's.
    let codec = match args.get("codec") {
        None => h.codec,
        Some(_) => resolve_codec(args)?,
    };
    let summary = lamc::store::repack_reader(&reader, &output, chunk_rows, chunk_cols, codec)?;
    print_summary(&summary);
    println!(
        "source      : {} chunks read, {} payload bytes streamed",
        reader.chunks_read(),
        reader.bytes_read()
    );
    Ok(())
}

/// Stream rows from stdin into a store. Dense format: one row per line,
/// whitespace-separated values. Sparse format: one row per line of
/// `col:value` tokens (possibly none). Blank lines and `#` comments are
/// skipped. This is the out-of-core ingest path: the matrix is never
/// resident — only the current row band is.
fn cmd_ingest(args: &Args) -> Result<()> {
    args.expect_flags(&["output", "cols", "format", "chunk-rows", "chunk-cols", "rows-hint", "codec"])?;
    let output = PathBuf::from(args.get("output").context("--output required")?);
    let cols = args.get_usize("cols", 0)?;
    anyhow::ensure!(cols > 0, "--cols required (row width is fixed up front)");
    let chunk_rows = args.get_usize("chunk-rows", DEFAULT_CHUNK_ROWS)?;
    // `auto` needs both dims for the planner dry run, but a streaming
    // ingest doesn't know its row count until the stream ends — the
    // caller supplies an estimate via --rows-hint (ψ is insensitive to
    // modest error: the planner quantizes to candidate block sizes).
    let chunk_cols = match args.get("chunk-cols") {
        Some("auto") => {
            let rows_hint = args.get_usize("rows-hint", 0)?;
            anyhow::ensure!(
                rows_hint > 0,
                "--chunk-cols auto on ingest needs --rows-hint N (row count is unknown until the stream ends)"
            );
            resolve_chunk_cols(args, rows_hint, cols)?
        }
        _ => args.get_usize("chunk-cols", 0)?,
    };
    let layout = match args.get_or("format", "dense") {
        "dense" => Layout::Dense,
        "sparse" => Layout::Csr,
        other => bail!("unknown --format '{other}' (want dense|sparse)"),
    };
    let mut writer = if chunk_cols > 0 {
        ChunkWriter::create_tiled(&output, layout, cols, chunk_rows, chunk_cols)?
    } else {
        ChunkWriter::create(&output, layout, cols, chunk_rows)?
    };
    writer.set_codec(resolve_codec(args)?);
    let stdin = std::io::stdin();
    let mut dense_row: Vec<f32> = Vec::with_capacity(cols);
    let mut sparse_row: Vec<(u32, f32)> = Vec::new();
    for (lineno, line) in stdin.lock().lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parse = || -> Result<()> {
            match layout {
                Layout::Dense => {
                    dense_row.clear();
                    for tok in line.split_whitespace() {
                        dense_row.push(tok.parse::<f32>()?);
                    }
                    writer.append_dense_row(&dense_row)
                }
                Layout::Csr => {
                    sparse_row.clear();
                    for tok in line.split_whitespace() {
                        let (j, v) = tok.split_once(':').context("want col:value")?;
                        sparse_row.push((j.parse::<u32>()?, v.parse::<f32>()?));
                    }
                    writer.append_sparse_row(&sparse_row)
                }
            }
        };
        parse().with_context(|| format!("stdin line {}", lineno + 1))?;
    }
    let summary = writer.finish()?;
    print_summary(&summary);
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.expect_flags(&["store"])?;
    let path = PathBuf::from(args.get("store").context("--store required")?);
    let reader = StoreReader::open(&path)?;
    let h = reader.header();
    println!("store       : {path:?}");
    print_store_description(
        h.is_tiled(), h.layout, h.rows, h.cols, h.nnz, h.n_chunks, h.chunk_rows, h.chunk_cols,
    );
    if h.is_tiled() {
        println!("grid        : {} x {} tile grid", h.n_row_bands(), h.n_col_bands());
    }
    println!("codec       : {}", h.codec.as_str());
    println!("fingerprint : {:016x}", h.fingerprint);
    // What `--chunk-cols auto` would pick for these dims, and whether
    // this store's tiles already align with the planner's column spans.
    let psi = lamc::partition::auto_chunk_cols(h.rows, h.cols);
    if psi < h.cols {
        let aligned = h.is_tiled() && h.chunk_cols == psi;
        println!(
            "auto psi    : {psi}{}",
            if aligned { " (tile width aligned)" } else { " (repack --chunk-cols auto to align)" }
        );
    }
    if args.has("verify") {
        reader.verify()?;
        let io = reader.io_counters();
        println!(
            "verify      : OK ({} chunks, {} payload bytes checksummed, {} bytes decoded)",
            io.chunks_read, io.bytes_read, io.bytes_decoded
        );
        println!(
            "io counters : cache_hits={} prefetch_issued={} prefetch_hits={} prefetch_wasted_bytes={}",
            io.cache_hits, io.prefetch_issued, io.prefetch_hits, io.prefetch_wasted_bytes
        );
    }
    Ok(())
}

/// Split a store into contiguous, chunk-aligned row-band shard stores
/// plus a band-ownership manifest — the unit `lamc serve --shards`
/// workers register and `lamc route` scatters over.
fn cmd_shard(args: &Args) -> Result<()> {
    args.expect_flags(&["store", "output-dir", "shards", "stem"])?;
    let store = PathBuf::from(args.get("store").context("--store required")?);
    let out_dir = PathBuf::from(args.get("output-dir").context("--output-dir required")?);
    let n = args.get_usize("shards", 0)?;
    anyhow::ensure!(n > 0, "--shards required (how many row bands)");
    let default_stem =
        store.file_stem().and_then(|s| s.to_str()).unwrap_or("matrix").to_string();
    let stem = args.get_or("stem", &default_stem);
    let reader = StoreReader::open(&store)?;
    let (manifest_path, manifest) = lamc::store::shard_store(&reader, &out_dir, stem, n)?;
    println!("sharded {:?} into {} band(s):", store, manifest.entries.len());
    for e in &manifest.entries {
        println!("  shard {} : rows {}..{} -> {:?}", e.index, e.row_lo, e.row_hi, manifest.shard_path(e));
    }
    println!("manifest    : {manifest_path:?}");
    println!("fingerprint : {:016x}", manifest.fingerprint);
    Ok(())
}

/// Front a fleet of `lamc serve --shards` workers with a shard router:
/// discovers band ownership over the wire, then serves the standard
/// submit/status/result protocol with routed, byte-identical runs.
fn cmd_route(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "workers", "retries", "io-timeout", "job-timeout"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let workers: Vec<String> = args
        .get("workers")
        .context("--workers required (host:port,host:port,...)")?
        .split(',')
        .filter(|w| !w.is_empty())
        .map(str::to_string)
        .collect();
    let defaults = lamc::service::ShardRouterConfig::default();
    let cfg = lamc::service::ShardRouterConfig {
        retries: args.get_usize("retries", defaults.retries)?,
        io_timeout: std::time::Duration::from_secs(
            args.get_u64("io-timeout", defaults.io_timeout.as_secs())?,
        ),
        job_timeout: std::time::Duration::from_secs(
            args.get_u64("job-timeout", defaults.job_timeout.as_secs())?,
        ),
    };
    let router = lamc::service::ShardRouter::connect(&workers, cfg)?;
    let mut names: Vec<&String> = router.topology().keys().collect();
    names.sort();
    for name in names {
        let t = &router.topology()[name];
        println!("matrix {name}: {} x {}, {} band(s)", t.rows, t.cols, t.bands.len());
    }
    let server = lamc::service::ShardServer::spawn(addr, router)?;
    println!("lamc shard router listening on {}", server.addr());
    println!("submit with: lamc submit --addr {} --matrix <name>", server.addr());
    // Blocks until a SHUTDOWN request stops the accept loop.
    server.join();
    println!("shutdown requested; router stopped");
    Ok(())
}

fn cmd_load(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "name", "dataset", "path", "store", "rows", "seed"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let name = args.get("name").context("--name required")?;
    let mut client = ServiceClient::connect(addr)?;
    let (rows, cols) = match (args.get("dataset"), args.get("path"), args.get("store")) {
        (Some(ds), None, None) => {
            let rows = args.get("rows").map(|r| r.parse::<usize>()).transpose()?;
            client.load_dataset(name, ds, rows, args.get_u64("seed", 42)?)?
        }
        (None, Some(p), None) => client.load_file(name, p)?,
        (None, None, Some(s)) => client.load_store(name, s)?,
        _ => {
            return Err(lamc::cli::UsageError(
                "load needs exactly one of --dataset, --path or --store".into(),
            )
            .into())
        }
    };
    println!("loaded '{name}': {rows} x {cols}");
    Ok(())
}

fn cmd_shutdown(args: &Args) -> Result<()> {
    args.expect_flags(&["addr"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let mut client = ServiceClient::connect(addr)?;
    client.shutdown()?;
    println!("shutdown requested at {addr}");
    Ok(())
}

fn cmd_version(args: &Args) -> Result<()> {
    args.expect_flags(&[])?;
    println!("lamc {}", env!("CARGO_PKG_VERSION"));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "addr", "runners", "queue", "cache-mb", "cache-disk-mb", "datasets", "seed",
        "store-root", "stores", "shards", "job-ttl",
    ])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let defaults = ServiceConfig::default();
    // Absent: default retention. 0: disable the sweep (keep records for
    // the server's lifetime). N: sweep finished records after N seconds.
    let job_ttl = match args.get("job-ttl") {
        None => defaults.job_ttl,
        Some(_) => match args.get_u64("job-ttl", 0)? {
            0 => None,
            secs => Some(std::time::Duration::from_secs(secs)),
        },
    };
    let config = ServiceConfig {
        runners: args.get_usize("runners", 2)?.max(1),
        queue_capacity: args.get_usize("queue", 64)?.max(1),
        cache_capacity_bytes: args.get_usize("cache-mb", 64)? << 20,
        store_root: args.get("store-root").map(PathBuf::from),
        cache_disk_capacity_bytes: args
            .get_usize("cache-disk-mb", defaults.cache_disk_capacity_bytes >> 20)?
            << 20,
        job_ttl,
    };
    let seed = args.get_u64("seed", 42)?;
    let manager = ServiceManager::new(config);
    if let Some(names) = args.get("datasets") {
        for name in names.split(',').filter(|n| !n.is_empty()) {
            let (r, c) = manager.load_dataset(name, name, None, seed)?;
            println!("loaded dataset {name}: {r} x {c}");
        }
    }
    if let Some(stores) = args.get("stores") {
        for binding in stores.split(',').filter(|b| !b.is_empty()) {
            let (name, file) = binding
                .split_once('=')
                .with_context(|| format!("--stores wants name=file, got '{binding}'"))?;
            let (r, c) = manager.register_store(name, Path::new(file))?;
            println!("registered store {name}: {r} x {c} (disk-resident)");
        }
    }
    // `name=manifest.lamcm` registers every band of the manifest on
    // this worker (full replication); `name=manifest.lamcm:0:2` only
    // the listed band indices (disjoint ownership across a fleet).
    if let Some(shards) = args.get("shards") {
        for binding in shards.split(',').filter(|b| !b.is_empty()) {
            let (name, rest) = binding.split_once('=').with_context(|| {
                format!("--shards wants name=manifest.lamcm[:idx...], got '{binding}'")
            })?;
            let mut parts = rest.split(':');
            let manifest = parts.next().context("missing manifest path")?;
            let indices: Vec<usize> = parts
                .map(|p| {
                    p.parse::<usize>()
                        .with_context(|| format!("bad shard index '{p}' in '{binding}'"))
                })
                .collect::<Result<_>>()?;
            let set = manager.register_shards(
                name,
                Path::new(manifest),
                if indices.is_empty() { None } else { Some(&indices) },
            )?;
            println!(
                "registered shards {name}: {} x {}, {} band(s) owned",
                set.rows,
                set.cols,
                set.bands.len()
            );
        }
    }
    let server = ServiceServer::spawn(addr, manager)?;
    println!("lamc service listening on {}", server.addr());
    println!("submit with: lamc submit --addr {} --matrix <name>", server.addr());
    // Blocks until a SHUTDOWN request stops the accept loop.
    let manager = server.join();
    println!("shutdown requested; draining queued jobs");
    manager.shutdown();
    Ok(())
}

fn job_spec_from_args(args: &Args) -> Result<JobSpec> {
    let defaults = JobSpec::default();
    Ok(JobSpec {
        matrix: args.get("matrix").context("--matrix required")?.to_string(),
        method: args.get_or("method", &defaults.method).to_string(),
        k: args.get_usize("k", defaults.k)?,
        seed: args.get_u64("seed", defaults.seed)?,
        p_thresh: args.get_f64("p-thresh", defaults.p_thresh)?,
        tau: args.get_f64("tau", defaults.tau)?,
        workers: args.get_usize("workers", defaults.workers)?,
    })
}

fn cmd_submit(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "matrix", "method", "k", "seed", "p-thresh", "tau", "workers", "timeout", "labels-out"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    if args.get("labels-out").is_some() && !args.has("wait") {
        bail!("--labels-out requires --wait (labels are fetched when the job finishes)");
    }
    let spec = job_spec_from_args(args)?;
    let mut client = ServiceClient::connect(addr)?;
    let id = client.submit(&spec)?;
    println!("submitted job {id} (matrix={}, method={}, k={})", spec.matrix, spec.method, spec.k);
    if args.has("wait") {
        let timeout = std::time::Duration::from_secs(args.get_u64("timeout", 600)?);
        let out = client.wait(id, timeout)?;
        println!("job {id} done: k={} rows={} cols={} cached={}", out.k, out.row_labels.len(), out.col_labels.len(), out.cached);
        // Byte-stable label dump — the single-node vs routed runs of the
        // CI shard smoke are compared with `cmp` on exactly this text.
        if let Some(path) = args.get("labels-out") {
            let text = format!(
                "k {}\nrows {}\ncols {}\n",
                out.k,
                lamc::service::protocol::encode_labels(&out.row_labels),
                lamc::service::protocol::encode_labels(&out.col_labels),
            );
            std::fs::write(path, text).with_context(|| format!("write labels to {path}"))?;
            println!("labels written to {path}");
        }
    } else {
        println!("poll with: lamc status --addr {addr} --id {id}");
    }
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "id"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let mut client = ServiceClient::connect(addr)?;
    match args.get("id") {
        Some(_) => {
            let id = args.get_u64("id", 0)?;
            let s = client.status(id)?;
            print!("job {id}: {}", s.state.as_str());
            if s.cached {
                print!(" (cached)");
            }
            if let Some(e) = s.error {
                print!(" error={e}");
            }
            println!();
        }
        None => {
            for (k, v) in client.stats()? {
                println!("{k:<22} {v}");
            }
        }
    }
    Ok(())
}

/// Append rows (stdin, the `lamc ingest` line formats) to a
/// store-backed matrix on a running service. The server seals them as
/// new row bands with a bumped footer generation and — when an earlier
/// job left a run basis — queues an incremental re-clustering whose id
/// is printed for `lamc watch`.
fn cmd_append(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "name", "cols", "format"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let name = args.get("name").context("--name required (target matrix)")?;
    let cols = args.get_usize("cols", 0)?;
    anyhow::ensure!(cols > 0, "--cols required (row width of the target store)");
    let sparse = match args.get_or("format", "dense") {
        "dense" => false,
        "sparse" => true,
        other => bail!("unknown --format '{other}' (want dense|sparse)"),
    };
    let stdin = std::io::stdin();
    let mut values: Vec<f32> = Vec::new();
    let mut rows = 0usize;
    for (lineno, line) in stdin.lock().lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parse = || -> Result<()> {
            let at = values.len();
            if sparse {
                values.resize(at + cols, 0.0);
                for tok in line.split_whitespace() {
                    let (j, v) = tok.split_once(':').context("want col:value")?;
                    let j: usize = j.parse()?;
                    anyhow::ensure!(j < cols, "column {j} out of range (--cols {cols})");
                    values[at + j] = v.parse::<f32>()?;
                }
            } else {
                for tok in line.split_whitespace() {
                    values.push(tok.parse::<f32>()?);
                }
                anyhow::ensure!(
                    values.len() - at == cols,
                    "row has {} values, want {cols}",
                    values.len() - at
                );
            }
            Ok(())
        };
        parse().with_context(|| format!("stdin line {}", lineno + 1))?;
        rows += 1;
    }
    anyhow::ensure!(rows > 0, "no rows on stdin to append");
    let mut client = ServiceClient::connect(addr)?;
    let reply = client.append(name, rows, cols, &values)?;
    println!(
        "appended {rows} row(s) to '{name}': now {} rows, generation {}",
        reply.total_rows, reply.generation
    );
    match reply.job {
        Some(id) => println!("incremental re-clustering queued as job {id} (lamc watch --id {id})"),
        None => println!("no incremental job queued (no prior run to extend — submit one)"),
    }
    Ok(())
}

/// Follow a matrix's feed journal (`SUBSCRIBE` cursor protocol): print
/// `MatrixAppended` / `LabelsUpdated` events as they land. `--once`
/// exits after the first page carrying a label update — the CI stream
/// smoke waits on exactly that. Requires the unified binary framing, so
/// servers that predate `HELLO framing=binary` answer with a typed
/// error.
fn cmd_watch_follow(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let name = args.get("name").context("--name required with --follow")?;
    let timeout = std::time::Duration::from_secs(args.get_u64("timeout", 600)?);
    let deadline = std::time::Instant::now() + timeout;
    let mut client = ServiceClient::connect(addr)?;
    client.hello()?;
    let mut cursor: Option<u64> = None;
    const BACKOFF_FLOOR: std::time::Duration = std::time::Duration::from_millis(25);
    const BACKOFF_CAP: std::time::Duration = std::time::Duration::from_millis(1000);
    let mut backoff = BACKOFF_FLOOR;
    loop {
        let (lines, next) = client.subscribe(name, cursor)?;
        let mut label_update = false;
        for line in &lines {
            println!("{line}");
            label_update |= line.split_whitespace().any(|t| t == "kind=LabelsUpdated");
        }
        // `--once` returns after the *page* that carried a label update,
        // so every event already in the feed (e.g. the MatrixAppended
        // preceding it) is printed before exit.
        if args.has("once") && label_update {
            return Ok(());
        }
        if let Some(n) = next {
            cursor = Some(n);
        }
        if lines.is_empty() {
            anyhow::ensure!(
                std::time::Instant::now() < deadline,
                "timed out after {}s following '{name}'",
                timeout.as_secs()
            );
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(BACKOFF_CAP);
        } else {
            backoff = BACKOFF_FLOOR;
        }
    }
}

/// Tail a job's lifecycle event journal until a terminal event lands.
/// Polls the `EVENTS` cursor protocol (so restarts/reconnects resume at
/// the last seen sequence number) and prints one event per line — the
/// CI shard smoke greps this transcript for `RoundCompleted`. With
/// `--follow --name`, tails a matrix feed instead (see
/// [`cmd_watch_follow`]).
fn cmd_watch(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "id", "timeout", "name"])?;
    if args.has("follow") || args.get("name").is_some() {
        return cmd_watch_follow(args);
    }
    let addr = args.get_or("addr", DEFAULT_ADDR);
    anyhow::ensure!(args.get("id").is_some(), "--id required (job to watch)");
    let id = args.get_u64("id", 0)?;
    let timeout = std::time::Duration::from_secs(args.get_u64("timeout", 600)?);
    let deadline = std::time::Instant::now() + timeout;
    let mut client = ServiceClient::connect(addr)?;
    let mut cursor: Option<u64> = None;
    // Exponential poll backoff: a busy job is re-polled almost
    // immediately, an idle one settles to one request per second
    // instead of hammering the server at a fixed rate.
    const BACKOFF_FLOOR: std::time::Duration = std::time::Duration::from_millis(25);
    const BACKOFF_CAP: std::time::Duration = std::time::Duration::from_millis(1000);
    let mut backoff = BACKOFF_FLOOR;
    loop {
        let (lines, next) = client.events(id, cursor)?;
        for line in &lines {
            println!("{line}");
            if let Some(kind) = line.split_whitespace().find_map(|t| t.strip_prefix("kind=")) {
                match kind {
                    "JobDone" => return Ok(()),
                    // Non-zero exit: `run()` bubbles this into exit(1).
                    "JobFailed" => bail!("job {id} failed (see event stream above)"),
                    _ => {}
                }
            }
        }
        if let Some(n) = next {
            cursor = Some(n);
        }
        // An empty page leaves the cursor where it was; double the wait
        // before asking again. Any progress resets the backoff.
        if lines.is_empty() {
            anyhow::ensure!(
                std::time::Instant::now() < deadline,
                "timed out after {}s waiting for job {id} to finish",
                timeout.as_secs()
            );
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(BACKOFF_CAP);
        } else {
            backoff = BACKOFF_FLOOR;
        }
    }
}

/// Print a job's stitched span tree plus critical-path analysis: per
/// round, the slowest child span (on a router that is the straggling
/// worker's scatter) and its share of the round's wall-clock, then the
/// prefetch-overlap ratio from the server's `STATS` counters.
fn cmd_profile(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "id"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    anyhow::ensure!(args.get("id").is_some(), "--id required (job to profile)");
    let id = args.get_u64("id", 0)?;
    let mut client = ServiceClient::connect(addr)?;
    let spans = client.spans(id)?;
    anyhow::ensure!(
        !spans.is_empty(),
        "job {id} has no recorded spans yet (still queued, or submitted to an older server?)"
    );
    println!("job {id}: {} span(s)", spans.len());
    print!("{}", lamc::trace::export::render_tree(&spans));
    println!();
    print!("{}", lamc::trace::export::critical_path_report(&spans));
    let stats = client.stats()?;
    let stat = |k: &str| stats.get(k).and_then(|v| v.parse::<u64>().ok()).unwrap_or(0);
    println!(
        "{}",
        lamc::trace::export::prefetch_overlap_line(
            stat("prefetch_hits"),
            stat("store_chunks_read")
        )
    );
    Ok(())
}

/// Dump a job's span tree as Chrome trace-event JSON — one track per
/// worker — to stdout or `--out FILE`.
fn cmd_trace_export(args: &Args) -> Result<()> {
    args.expect_flags(&["addr", "id", "format", "out"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    anyhow::ensure!(args.get("id").is_some(), "--id required (job to export)");
    let id = args.get_u64("id", 0)?;
    let format = args.get_or("format", "chrome");
    anyhow::ensure!(format == "chrome", "unknown --format '{format}' (want chrome)");
    let mut client = ServiceClient::connect(addr)?;
    let spans = client.spans(id)?;
    anyhow::ensure!(
        !spans.is_empty(),
        "job {id} has no recorded spans yet (still queued, or submitted to an older server?)"
    );
    let json = lamc::trace::export::chrome_trace_json(&spans);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("write trace to {path}"))?;
            println!(
                "wrote {} span(s) to {path} (load in Perfetto or chrome://tracing)",
                spans.len()
            );
        }
        None => println!("{json}"),
    }
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    args.expect_flags(&["addr"])?;
    let addr = args.get_or("addr", DEFAULT_ADDR);
    let mut client = ServiceClient::connect(addr)?;
    print!("{}", client.metrics()?);
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_flags(&["dataset", "method", "k", "rows", "seed", "workers", "p-thresh", "tau"])?;
    let dataset = args.get("dataset").context("--dataset required")?;
    let method = args.get_or("method", "lamc-scc").to_lowercase();
    let seed = args.get_u64("seed", 42)?;
    let rows = args.get("rows").map(|r| r.parse::<usize>()).transpose()?;

    let spec = data::datasets::spec(dataset).with_context(|| format!("unknown dataset '{dataset}'"))?;
    let k = args.get_usize("k", spec.row_clusters)?;
    lamc::log_info!("building dataset {dataset} (rows={rows:?})");
    let ds = data::datasets::build(dataset, rows, seed).unwrap();

    let (atom, partitioned): (AtomKind, bool) = match method.as_str() {
        "lamc-scc" => (AtomKind::Scc, true),
        "lamc-pnmtf" => (AtomKind::Pnmtf, true),
        "scc" => (AtomKind::Scc, false),
        "pnmtf" => (AtomKind::Pnmtf, false),
        other => bail!("unknown method '{other}'"),
    };

    #[cfg(feature = "pjrt")]
    let runtime = if partitioned && !args.has("no-runtime") {
        match RuntimePool::from_default_manifest(RuntimePoolConfig::default()) {
            Ok(pool) => {
                lamc::log_info!("PJRT runtime online ({} artifacts)", pool.manifest().artifacts.len());
                Some(pool)
            }
            Err(e) => {
                lamc::log_warn!("PJRT runtime unavailable ({e}); native route only");
                None
            }
        }
    } else {
        None
    };

    let mut config = LamcConfig {
        k,
        atom,
        seed,
        workers: args.get_usize("workers", 0)?,
        #[cfg(feature = "pjrt")]
        runtime,
        ..Default::default()
    };
    config.planner.p_thresh = args.get_f64("p-thresh", config.planner.p_thresh)?;
    config.merge.tau = args.get_f64("tau", config.merge.tau)?;

    let lamc = Lamc::new(config);
    let out = if partitioned { lamc.run(&ds.matrix)? } else { lamc.run_baseline(&ds.matrix)? };

    let scores = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
    println!("method      : {method}");
    println!("dataset     : {dataset} ({}x{}, {})", ds.matrix.rows(), ds.matrix.cols(), if ds.matrix.is_sparse() { "sparse" } else { "dense" });
    println!("plan        : {}x{} blocks of {}x{}, T_p={}", out.plan.m, out.plan.n, out.plan.phi, out.plan.psi, out.plan.t_p);
    println!("k (found)   : {}", out.k);
    println!("time        : {:.3} s", out.elapsed_s);
    println!("routes      : {}", out.stats);
    println!("NMI         : {:.4} (rows {:.4} / cols {:.4})", scores.nmi(), scores.row_nmi, scores.col_nmi);
    println!("ARI         : {:.4} (rows {:.4} / cols {:.4})", scores.ari(), scores.row_ari, scores.col_ari);
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    args.expect_flags(&["rows", "cols", "p-thresh", "row-frac", "col-frac", "workers"])?;
    let rows = args.get_usize("rows", 0)?;
    let cols = args.get_usize("cols", 0)?;
    anyhow::ensure!(rows > 0 && cols > 0, "--rows and --cols required");
    let mut cfg = PlannerConfig::default();
    cfg.p_thresh = args.get_f64("p-thresh", cfg.p_thresh)?;
    cfg.prior.row_fraction = args.get_f64("row-frac", cfg.prior.row_fraction)?;
    cfg.prior.col_fraction = args.get_f64("col-frac", cfg.prior.col_fraction)?;
    let workers = args.get_usize("workers", 0)?;
    if workers > 0 {
        cfg.workers = workers;
    }
    let p = plan(rows, cols, &cfg);
    println!("matrix       : {rows} x {cols}");
    println!("blocks       : {} x {} of {} x {}", p.m, p.n, p.phi, p.psi);
    println!("samplings    : T_p = {}", p.t_p);
    println!("certified P  : {:.6} (threshold {})", p.certified_probability, cfg.p_thresh);
    println!("total jobs   : {}", p.total_blocks());
    println!("est. cost    : {:.3e} (model units)", p.estimated_cost);
    Ok(())
}

fn cmd_datasets(args: &Args) -> Result<()> {
    args.expect_flags(&[])?;
    println!("{:<12} {:>8} {:>6}  {:<6} {:>4} {:>4}", "name", "rows", "cols", "kind", "k", "d");
    for s in data::datasets::SPECS {
        println!(
            "{:<12} {:>8} {:>6}  {:<6} {:>4} {:>4}",
            s.name, s.rows, s.cols, if s.sparse { "sparse" } else { "dense" }, s.row_clusters, s.col_clusters
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(args: &Args) -> Result<()> {
    args.expect_flags(&[])?;
    println!("this binary was built without the `pjrt` feature — no artifact runtime.");
    println!("rebuild with `cargo build --release --features pjrt` (requires the xla");
    println!("crate; see rust/Cargo.toml) to load AOT artifacts.");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(args: &Args) -> Result<()> {
    args.expect_flags(&[])?;
    let Some(path) = lamc::runtime::find_manifest() else {
        println!("no artifact manifest found — run `make artifacts`");
        return Ok(());
    };
    let manifest = Manifest::load(&path)?;
    println!("manifest: {path:?}");
    println!("{:<12} {:<12} {:>5} {:>5} {:>4} {:>4} {:>5}", "name", "kind", "phi", "psi", "rank", "kmax", "iters");
    for a in &manifest.artifacts {
        println!(
            "{:<12} {:<12} {:>5} {:>5} {:>4} {:>4} {:>5}  {}",
            a.name, a.kind, a.phi, a.psi, a.rank, a.kmax, a.iters,
            if a.path.exists() { "ok" } else { "MISSING" }
        );
    }
    Ok(())
}
