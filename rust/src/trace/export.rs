//! Span-tree exposures: the renderers behind `lamc profile` and
//! `lamc trace-export`.
//!
//! All three views consume the same flat `Vec<SpanRecord>` a journal
//! (or the `SPANS` wire verb) hands out:
//!
//! * [`render_tree`] — indented text tree for the terminal;
//! * [`critical_path_report`] — per-round slowest-child analysis
//!   (which worker gated each round, and how much of the round's
//!   wall-clock sat on it);
//! * [`chrome_trace_json`] — Chrome trace-event JSON (the Perfetto /
//!   `chrome://tracing` format), one track (`pid`/`tid`) per worker.

use std::collections::HashMap;
use std::fmt::Write as _;

use super::span::{SpanRecord, ROOT_SPAN};

/// Children-of index over a flat span sheet. A span whose parent id is
/// [`ROOT_SPAN`] — or refers to a span not present in the sheet (e.g.
/// dropped past `SPAN_CAPACITY`) — counts as a root.
fn index_children(spans: &[SpanRecord]) -> (Vec<&SpanRecord>, HashMap<u64, Vec<&SpanRecord>>) {
    let known: HashMap<u64, &SpanRecord> = spans.iter().map(|s| (s.id, s)).collect();
    let mut roots = Vec::new();
    let mut children: HashMap<u64, Vec<&SpanRecord>> = HashMap::new();
    for s in spans {
        if s.parent == ROOT_SPAN || !known.contains_key(&s.parent) {
            roots.push(s);
        } else {
            children.entry(s.parent).or_default().push(s);
        }
    }
    let by_time = |a: &&SpanRecord, b: &&SpanRecord| (a.start_us, a.id).cmp(&(b.start_us, b.id));
    roots.sort_by(by_time);
    for v in children.values_mut() {
        v.sort_by(by_time);
    }
    (roots, children)
}

fn secs(us: u64) -> f64 {
    us as f64 / 1e6
}

/// Render the sheet as an indented tree, one span per line:
///
/// ```text
/// job                            worker=0  start=0.000s  dur=0.412s
///   round-0                      worker=0  start=0.002s  dur=0.051s
///     scatter-3                  worker=1  start=0.002s  dur=0.049s
/// ```
pub fn render_tree(spans: &[SpanRecord]) -> String {
    let (roots, children) = index_children(spans);
    let mut out = String::new();
    let mut stack: Vec<(&SpanRecord, usize)> = roots.iter().rev().map(|s| (*s, 0)).collect();
    while let Some((s, depth)) = stack.pop() {
        let label = format!("{}{}", "  ".repeat(depth), s.name);
        let _ = writeln!(
            out,
            "{label:<30} worker={}  start={:.3}s  dur={:.3}s",
            s.worker,
            secs(s.start_us),
            secs(s.dur_us)
        );
        if let Some(kids) = children.get(&s.id) {
            for k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    out
}

/// Per-round critical-path analysis.
///
/// For every `round-<r>` span, the slowest direct child (a `scatter-*`
/// span on a routed job, a `gather`/`exec` span single-node) is the
/// round's critical path: nothing after the round barrier could start
/// before it finished. Reports which worker that child ran on and what
/// fraction of the round's wall-clock it covered — a low percentage
/// means the round was well balanced, ~100% with one worker repeatedly
/// named means that worker is the straggler.
pub fn critical_path_report(spans: &[SpanRecord]) -> String {
    let (_, children) = index_children(spans);
    let mut rounds: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.name.starts_with("round-")).collect();
    rounds.sort_by_key(|s| (s.start_us, s.id));
    let mut out = String::new();
    for round in rounds {
        let slowest = children
            .get(&round.id)
            .and_then(|kids| kids.iter().max_by_key(|k| (k.dur_us, k.id)));
        let Some(slowest) = slowest else {
            let _ = writeln!(out, "{}: no recorded children", round.name);
            continue;
        };
        let pct = if round.dur_us == 0 {
            100.0
        } else {
            100.0 * slowest.dur_us as f64 / round.dur_us as f64
        };
        let _ = writeln!(
            out,
            "{}: slowest worker {} ({}) — {:.3}s of {:.3}s ({:.1}% of round wall-clock)",
            round.name,
            slowest.worker,
            slowest.name,
            secs(slowest.dur_us),
            secs(round.dur_us),
            pct
        );
    }
    if out.is_empty() {
        out.push_str("no round spans recorded\n");
    }
    out
}

/// Prefetch-overlap summary line for `lamc profile`, from the `STATS`
/// counters: the fraction of chunk reads served by a prefetch that
/// landed before the consumer asked — i.e. I/O the spans never waited
/// on.
pub fn prefetch_overlap_line(prefetch_hits: u64, chunks_read: u64) -> String {
    format!(
        "prefetch overlap: {}/{} chunk reads hidden ({:.1}%)",
        prefetch_hits,
        chunks_read,
        100.0 * prefetch_hits as f64 / chunks_read.max(1) as f64
    )
}

/// Serialize the sheet as Chrome trace-event JSON (load in Perfetto or
/// `chrome://tracing`). Every span becomes one complete event
/// (`"ph":"X"`) with `ts`/`dur` in microseconds; `pid` and `tid` carry
/// the worker index so each worker renders as its own track. Span and
/// parent ids ride along in `args` for cross-referencing with
/// `lamc profile`.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"lamc\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{},\"args\":{{\"span_id\":{},\"parent\":{}}}}}",
            json_escape(&s.name),
            s.start_us,
            s.dur_us,
            s.worker,
            s.worker,
            s.id,
            s.parent
        );
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, parent: u64, name: &str, worker: u64, start_us: u64, dur_us: u64) -> SpanRecord {
        SpanRecord { id, parent, name: name.into(), worker, start_us, dur_us }
    }

    fn routed_sheet() -> Vec<SpanRecord> {
        vec![
            span(1, ROOT_SPAN, "job", 0, 0, 500_000),
            span(2, 1, "round-0", 0, 1_000, 400_000),
            span(3, 2, "scatter-0", 1, 2_000, 393_000),
            span(4, 2, "scatter-1", 2, 2_000, 120_000),
            span(5, 1, "merge", 0, 420_000, 60_000),
        ]
    }

    #[test]
    fn tree_renders_depth_first_in_start_order() {
        let txt = render_tree(&routed_sheet());
        let names: Vec<&str> =
            txt.lines().map(|l| l.split_whitespace().next().unwrap()).collect();
        assert_eq!(names, vec!["job", "round-0", "scatter-0", "scatter-1", "merge"]);
        assert!(txt.lines().nth(2).unwrap().starts_with("    scatter-0"), "indent = depth");
    }

    #[test]
    fn critical_path_names_the_slowest_worker() {
        let report = critical_path_report(&routed_sheet());
        assert!(report.contains("round-0: slowest worker 1"), "{report}");
        assert!(report.contains("0.393s of 0.400s"), "{report}");
        assert!(report.contains("98.2%"), "{report}");
    }

    #[test]
    fn critical_path_handles_empty_and_childless_rounds() {
        assert_eq!(critical_path_report(&[]), "no round spans recorded\n");
        let lonely = vec![span(1, ROOT_SPAN, "round-3", 0, 0, 10)];
        assert!(critical_path_report(&lonely).contains("round-3: no recorded children"));
    }

    #[test]
    fn chrome_export_is_schema_valid() {
        let sheet = routed_sheet();
        let json = chrome_trace_json(&sheet);
        // Parse with the crate's own flat-JSON reader to avoid a serde
        // dependency: pull out each event object and check the schema.
        let events: Vec<&str> = json
            .split("{\"name\":")
            .skip(1)
            .map(|chunk| chunk.split('}').next().unwrap())
            .collect();
        assert_eq!(events.len(), sheet.len());
        for (ev, s) in events.iter().zip(&sheet) {
            assert!(ev.contains("\"ph\":\"X\""), "every event is a complete event: {ev}");
            assert!(ev.contains(&format!("\"pid\":{}", s.worker)), "pid = worker id: {ev}");
            assert!(ev.contains(&format!("\"tid\":{}", s.worker)));
            assert!(ev.contains(&format!("\"dur\":{}", s.dur_us)), "dur is the span's (non-negative) duration");
        }
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("],\"displayTimeUnit\":\"ms\"}"));
    }

    #[test]
    fn chrome_export_escapes_names() {
        let sheet = vec![span(1, 0, "we\"ird", 0, 0, 1)];
        let json = chrome_trace_json(&sheet);
        assert!(json.contains("we\\\"ird"));
    }

    #[test]
    fn overlap_line_guards_division() {
        assert!(prefetch_overlap_line(0, 0).contains("0/0"));
        assert!(prefetch_overlap_line(3, 4).contains("75.0%"));
    }
}
