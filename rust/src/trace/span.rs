//! Hierarchical spans: the timing tree recorded alongside the event
//! journal.
//!
//! A span is one timed region of a job — the job itself, a sampling
//! round, a phase (`gather`/`exec`/`merge`), one scattered block on a
//! remote worker — with an id, a parent id and `(start_us, dur_us)`
//! measured on the owning journal's monotonic clock. Together a job's
//! spans form one tree rooted at the `job` span (parent
//! [`ROOT_SPAN`] = 0).
//!
//! **Cross-node anchoring.** Workers measure their spans on their *own*
//! clock, relative to the instant they received the request (`start_us`
//! from 0). The router re-anchors each returned sheet at the exchange
//! boundary: every worker span is re-timed as
//! `scatter.start_us + worker_relative_start`, clamped so it nests
//! inside the router-side scatter span. Clock skew between nodes can
//! therefore never reorder the tree — worker spans inherit the router's
//! timeline, keeping only their internal offsets.
//!
//! The wire form is one text line per span (the `SPANS` verb and the
//! span block piggybacked on `EXECB`/`GATHERB` replies):
//!
//! ```text
//! SPAN id=7 parent=3 name=exec worker=1 start_us=4100 dur_us=91000
//! ```

use anyhow::{bail, Context, Result};

/// The parent id of a tree root (no parent).
pub const ROOT_SPAN: u64 = 0;

/// One completed span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Journal-unique id, from 1 (0 is [`ROOT_SPAN`], never an id).
    pub id: u64,
    /// Enclosing span's id, or [`ROOT_SPAN`] for a tree root.
    pub parent: u64,
    /// Span name: `job`, `queue`, `round-<r>`, `gather`, `exec`,
    /// `merge`, `scatter-<job>` — a single token (no whitespace).
    pub name: String,
    /// Worker attribution: the router's worker index for remote spans,
    /// 0 for local/single-node spans.
    pub worker: u64,
    /// Microseconds since the owning journal's epoch.
    pub start_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
}

impl SpanRecord {
    /// Span end, saturating.
    pub fn end_us(&self) -> u64 {
        self.start_us.saturating_add(self.dur_us)
    }

    /// The body of a `SPAN` wire line (without the `SPAN ` prefix).
    pub fn to_wire(&self) -> String {
        format!(
            "id={} parent={} name={} worker={} start_us={} dur_us={}",
            self.id,
            self.parent,
            tokenize_name(&self.name),
            self.worker,
            self.start_us,
            self.dur_us
        )
    }

    /// Parse a wire line body (accepts an optional `SPAN ` prefix).
    pub fn from_wire(line: &str) -> Result<SpanRecord> {
        let body = line.trim().strip_prefix("SPAN ").unwrap_or(line.trim());
        let mut id = None;
        let mut parent = None;
        let mut name = None;
        let mut worker = None;
        let mut start_us = None;
        let mut dur_us = None;
        for token in body.split_whitespace() {
            let (k, v) = token
                .split_once('=')
                .with_context(|| format!("span field '{token}' is not key=value"))?;
            match k {
                "id" => id = Some(v.parse().with_context(|| format!("bad span id '{v}'"))?),
                "parent" => parent = Some(v.parse().with_context(|| format!("bad span parent '{v}'"))?),
                "name" => name = Some(v.to_string()),
                "worker" => worker = Some(v.parse().with_context(|| format!("bad span worker '{v}'"))?),
                "start_us" => start_us = Some(v.parse().with_context(|| format!("bad span start '{v}'"))?),
                "dur_us" => dur_us = Some(v.parse().with_context(|| format!("bad span dur '{v}'"))?),
                other => bail!("unknown span field '{other}'"),
            }
        }
        Ok(SpanRecord {
            id: id.context("span line missing id")?,
            parent: parent.context("span line missing parent")?,
            name: name.context("span line missing name")?,
            worker: worker.context("span line missing worker")?,
            start_us: start_us.context("span line missing start_us")?,
            dur_us: dur_us.context("span line missing dur_us")?,
        })
    }
}

/// Span names must survive the space-separated wire line.
fn tokenize_name(s: &str) -> String {
    s.chars().map(|c| if c.is_whitespace() { '_' } else { c }).collect()
}

/// Encode a sheet as the text payload of a span block: one `SPAN` line
/// per record, `\n`-joined with a trailing newline (empty for an empty
/// sheet).
pub fn encode_spans(spans: &[SpanRecord]) -> String {
    let mut out = String::new();
    for s in spans {
        out.push_str("SPAN ");
        out.push_str(&s.to_wire());
        out.push('\n');
    }
    out
}

/// Decode a span block produced by [`encode_spans`].
pub fn decode_spans(text: &str) -> Result<Vec<SpanRecord>> {
    text.lines().filter(|l| !l.trim().is_empty()).map(SpanRecord::from_wire).collect()
}

/// Re-anchor a worker-local span sheet under a router-side anchor span.
///
/// `sheet` is the worker's reply: local ids from 1, times relative to
/// the worker's receipt of the request, parent [`ROOT_SPAN`] marking
/// "attach at the exchange boundary". Each span gets a fresh globally
/// unique id from `fresh` (so worker-local ids can never collide with
/// router ids), its root parents become `anchor.id`, its `worker` field
/// is overwritten with `worker` (the router's index for the executing
/// node), and its times are re-based onto the router clock:
/// `anchor.start_us + relative start`, clamped so the span never
/// extends past `anchor`'s end. This is the clock-skew rule — the
/// worker's clock contributes only *offsets within the exchange*, never
/// absolute positions.
pub fn anchor_spans(
    sheet: &[SpanRecord],
    anchor: &SpanRecord,
    worker: u64,
    mut fresh: impl FnMut() -> u64,
) -> Vec<SpanRecord> {
    let mut remap = std::collections::HashMap::with_capacity(sheet.len());
    for s in sheet {
        remap.insert(s.id, fresh());
    }
    sheet
        .iter()
        .map(|s| {
            let start_us = anchor.start_us.saturating_add(s.start_us).min(anchor.end_us());
            let dur_us = s.dur_us.min(anchor.end_us().saturating_sub(start_us));
            SpanRecord {
                id: remap[&s.id],
                parent: match s.parent {
                    ROOT_SPAN => anchor.id,
                    p => remap.get(&p).copied().unwrap_or(anchor.id),
                },
                name: s.name.clone(),
                worker,
                start_us,
                dur_us,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, parent: u64, name: &str, start_us: u64, dur_us: u64) -> SpanRecord {
        SpanRecord { id, parent, name: name.into(), worker: 0, start_us, dur_us }
    }

    #[test]
    fn wire_round_trips() {
        let s = SpanRecord {
            id: 7,
            parent: 3,
            name: "exec".into(),
            worker: 2,
            start_us: 4100,
            dur_us: 91000,
        };
        assert_eq!(SpanRecord::from_wire(&s.to_wire()).unwrap(), s);
        assert_eq!(SpanRecord::from_wire(&format!("SPAN {}", s.to_wire())).unwrap(), s);
        let block = encode_spans(&[s.clone()]);
        assert_eq!(decode_spans(&block).unwrap(), vec![s]);
        assert!(decode_spans("").unwrap().is_empty());
    }

    #[test]
    fn wire_rejects_damage() {
        assert!(SpanRecord::from_wire("id=1 parent=0").is_err(), "missing fields");
        assert!(SpanRecord::from_wire("id=x parent=0 name=a worker=0 start_us=0 dur_us=0").is_err());
        assert!(
            SpanRecord::from_wire("id=1 parent=0 name=a worker=0 start_us=0 dur_us=0 evil=1")
                .is_err(),
            "unknown field"
        );
    }

    #[test]
    fn names_stay_single_tokens() {
        let s = SpanRecord {
            id: 1,
            parent: 0,
            name: "two words".into(),
            worker: 0,
            start_us: 0,
            dur_us: 1,
        };
        let back = SpanRecord::from_wire(&s.to_wire()).unwrap();
        assert_eq!(back.name, "two_words");
    }

    #[test]
    fn anchoring_rebases_reids_and_clamps() {
        // Worker sheet: a 2-span tree, ids 1..2, times relative to the
        // exchange, parent 0 at the boundary.
        let sheet =
            vec![span(1, ROOT_SPAN, "gather", 0, 400), span(2, 1, "exec", 400, 10_000)];
        let anchor = span(30, 20, "scatter-5", 1000, 5000); // ends at 6000
        let mut next = 100;
        let got = anchor_spans(&sheet, &anchor, 2, || {
            next += 1;
            next
        });
        assert_eq!(got.len(), 2);
        // Fresh ids, structure preserved, boundary parent = anchor id.
        assert_eq!(got[0].parent, 30);
        assert_eq!(got[1].parent, got[0].id);
        assert!(got.iter().all(|s| s.worker == 2), "worker overwritten by router index");
        // Times re-based onto the anchor's clock…
        assert_eq!(got[0].start_us, 1400);
        assert_eq!(got[0].dur_us, 400);
        // …and clamped inside it: 1000+400=1400 start, wanted end
        // 1400+10000 > 6000 so duration is cut to fit.
        assert_eq!(got[1].start_us, 2400);
        assert_eq!(got[1].end_us(), 6000, "span clamped to the anchor window");
    }

    #[test]
    fn anchoring_with_skewed_worker_clock_never_escapes_the_window() {
        // A worker claiming an absurd relative start (clock skew /
        // bogus sheet) still lands inside the anchor.
        let sheet = vec![span(1, ROOT_SPAN, "exec", 9_999_999, 77)];
        let anchor = span(8, 0, "scatter-0", 500, 100);
        let got = anchor_spans(&sheet, &anchor, 1, || 50);
        assert_eq!(got[0].start_us, 600, "clamped to the anchor end");
        assert_eq!(got[0].dur_us, 0);
    }
}
