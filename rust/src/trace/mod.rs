//! Job-lifecycle event journal: the service's observability backbone.
//!
//! Every job owns a [`Journal`] — a bounded in-memory ring of typed
//! [`Event`]s plus an optional JSONL spill file for post-mortems — and
//! hands [`Trace`] handles (cheap clones) down through the pipeline,
//! scheduler and shard router. Emission is **advisory**: a disabled
//! `Trace` is a no-op and an enabled one only appends to the journal,
//! so labels are byte-identical with tracing on or off (asserted by the
//! property harness).
//!
//! Readers page through a journal with a cursor ([`Journal::events_after`]):
//! `after=<seq>` returns every retained record with a larger sequence
//! number. Sequence numbers are monotonic per journal; when the ring
//! overflows, the oldest records are evicted and a reader whose cursor
//! has fallen behind receives a synthetic [`Event::Dropped`] record
//! covering the gap — consumers always know when they missed events.
//!
//! The wire shape (the `EVENTS`/`EVENTSB` protocol verbs, see
//! `docs/OBSERVABILITY.md`) and the JSONL spill both serialize through
//! the same flat field list, so a journal line round-trips losslessly.

use std::collections::VecDeque;
use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

pub mod export;
pub mod span;

pub use span::{SpanRecord, ROOT_SPAN};

/// Default bounded-ring capacity per job journal. Small jobs emit a
/// handful of events; a long routed run emits a few per round — 1024
/// keeps hours of history without letting a runaway job grow memory.
pub const DEFAULT_RING_CAPACITY: usize = 1024;

/// Upper bound on spans retained per journal. Spans are per-block, so a
/// huge routed job could otherwise grow the sheet without limit; past
/// the cap new spans are silently dropped (spans are advisory, like
/// events — the tree just loses its deepest leaves).
pub const SPAN_CAPACITY: usize = 1 << 16;

/// One typed lifecycle event. The field lists here are the wire
/// contract (`docs/OBSERVABILITY.md`): every future subsystem reports
/// through this enum rather than ad-hoc log lines.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Accepted into the service queue.
    JobQueued,
    /// A runner picked the job up.
    JobStarted,
    /// A sampling round began dispatching `jobs` block jobs.
    RoundStarted { round: u64, jobs: u64 },
    /// A sampling round finished: per-round time split and store-I/O
    /// delta (`IoCounters` flattened; zeros for in-memory inputs and
    /// for router-side rounds, where I/O happens on the workers).
    RoundCompleted {
        round: u64,
        jobs: u64,
        gather_s: f64,
        exec_s: f64,
        io_chunks: u64,
        io_bytes: u64,
        io_cache_hits: u64,
        prefetch_issued: u64,
        prefetch_hits: u64,
        prefetch_wasted_bytes: u64,
    },
    /// The scheduler asked the store to warm round `round`'s chunks.
    PrefetchWave { round: u64 },
    /// Hierarchical merge over `blocks` block results began.
    MergeStarted { blocks: u64 },
    /// Merge finished with `k` co-clusters after `merge_s` seconds.
    MergeCompleted { k: u64, merge_s: f64 },
    /// Terminal: result available.
    JobDone,
    /// Terminal: job failed with `error`.
    JobFailed { error: String },
    /// Router scattered block job `job` to worker `worker` (index into
    /// the router's worker list) owning row band `band`.
    BlockScattered { job: u64, worker: u64, band: u64 },
    /// Router is re-running block job `job` after losing its worker.
    WorkerRetry { job: u64, attempt: u64 },
    /// Worker `worker` stopped answering; its connection was dropped.
    WorkerLost { worker: u64 },
    /// Synthetic: `n` records were evicted from the bounded ring before
    /// the reader's cursor reached them.
    Dropped { n: u64 },
    /// `rows` new rows were sealed onto a served store, bumping its
    /// append generation to `generation` (matrix feed journals only).
    MatrixAppended { rows: u64, generation: u64 },
    /// Incremental job `job` published fresh labels (`k` co-clusters)
    /// covering the matrix at append generation `generation`.
    LabelsUpdated { job: u64, k: u64, generation: u64 },
}

/// Flat field value — the single representation behind both the
/// `key=value` wire lines and the JSONL spill.
#[derive(Clone, Debug, PartialEq)]
enum Field {
    U(u64),
    F(f64),
    S(String),
}

impl Event {
    /// Stable kind tag (the `kind=` field on the wire).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::JobQueued => "JobQueued",
            Event::JobStarted => "JobStarted",
            Event::RoundStarted { .. } => "RoundStarted",
            Event::RoundCompleted { .. } => "RoundCompleted",
            Event::PrefetchWave { .. } => "PrefetchWave",
            Event::MergeStarted { .. } => "MergeStarted",
            Event::MergeCompleted { .. } => "MergeCompleted",
            Event::JobDone => "JobDone",
            Event::JobFailed { .. } => "JobFailed",
            Event::BlockScattered { .. } => "BlockScattered",
            Event::WorkerRetry { .. } => "WorkerRetry",
            Event::WorkerLost { .. } => "WorkerLost",
            Event::Dropped { .. } => "Dropped",
            Event::MatrixAppended { .. } => "MatrixAppended",
            Event::LabelsUpdated { .. } => "LabelsUpdated",
        }
    }

    /// True for the two terminal states a watcher stops on.
    pub fn is_terminal(&self) -> bool {
        matches!(self, Event::JobDone | Event::JobFailed { .. })
    }

    fn fields(&self) -> Vec<(&'static str, Field)> {
        match self {
            Event::JobQueued | Event::JobStarted | Event::JobDone => vec![],
            Event::RoundStarted { round, jobs } => {
                vec![("round", Field::U(*round)), ("jobs", Field::U(*jobs))]
            }
            Event::RoundCompleted {
                round,
                jobs,
                gather_s,
                exec_s,
                io_chunks,
                io_bytes,
                io_cache_hits,
                prefetch_issued,
                prefetch_hits,
                prefetch_wasted_bytes,
            } => vec![
                ("round", Field::U(*round)),
                ("jobs", Field::U(*jobs)),
                ("gather_s", Field::F(*gather_s)),
                ("exec_s", Field::F(*exec_s)),
                ("io_chunks", Field::U(*io_chunks)),
                ("io_bytes", Field::U(*io_bytes)),
                ("io_cache_hits", Field::U(*io_cache_hits)),
                ("prefetch_issued", Field::U(*prefetch_issued)),
                ("prefetch_hits", Field::U(*prefetch_hits)),
                ("prefetch_wasted_bytes", Field::U(*prefetch_wasted_bytes)),
            ],
            Event::PrefetchWave { round } => vec![("round", Field::U(*round))],
            Event::MergeStarted { blocks } => vec![("blocks", Field::U(*blocks))],
            Event::MergeCompleted { k, merge_s } => {
                vec![("k", Field::U(*k)), ("merge_s", Field::F(*merge_s))]
            }
            Event::JobFailed { error } => vec![("error", Field::S(error.clone()))],
            Event::BlockScattered { job, worker, band } => vec![
                ("job", Field::U(*job)),
                ("worker", Field::U(*worker)),
                ("band", Field::U(*band)),
            ],
            Event::WorkerRetry { job, attempt } => {
                vec![("job", Field::U(*job)), ("attempt", Field::U(*attempt))]
            }
            Event::WorkerLost { worker } => vec![("worker", Field::U(*worker))],
            Event::Dropped { n } => vec![("n", Field::U(*n))],
            Event::MatrixAppended { rows, generation } => {
                vec![("rows", Field::U(*rows)), ("generation", Field::U(*generation))]
            }
            Event::LabelsUpdated { job, k, generation } => vec![
                ("job", Field::U(*job)),
                ("k", Field::U(*k)),
                ("generation", Field::U(*generation)),
            ],
        }
    }

    fn from_fields(kind: &str, get: &dyn Fn(&str) -> Result<Field>) -> Result<Event> {
        let u = |k: &str| -> Result<u64> {
            match get(k)? {
                Field::U(v) => Ok(v),
                Field::F(v) if v >= 0.0 && v.fract() == 0.0 => Ok(v as u64),
                other => bail!("event field '{k}': expected integer, got {other:?}"),
            }
        };
        let f = |k: &str| -> Result<f64> {
            match get(k)? {
                Field::F(v) => Ok(v),
                Field::U(v) => Ok(v as f64),
                other => bail!("event field '{k}': expected number, got {other:?}"),
            }
        };
        let s = |k: &str| -> Result<String> {
            match get(k)? {
                Field::S(v) => Ok(v),
                other => bail!("event field '{k}': expected string, got {other:?}"),
            }
        };
        Ok(match kind {
            "JobQueued" => Event::JobQueued,
            "JobStarted" => Event::JobStarted,
            "RoundStarted" => Event::RoundStarted { round: u("round")?, jobs: u("jobs")? },
            "RoundCompleted" => Event::RoundCompleted {
                round: u("round")?,
                jobs: u("jobs")?,
                gather_s: f("gather_s")?,
                exec_s: f("exec_s")?,
                io_chunks: u("io_chunks")?,
                io_bytes: u("io_bytes")?,
                io_cache_hits: u("io_cache_hits")?,
                prefetch_issued: u("prefetch_issued")?,
                prefetch_hits: u("prefetch_hits")?,
                prefetch_wasted_bytes: u("prefetch_wasted_bytes")?,
            },
            "PrefetchWave" => Event::PrefetchWave { round: u("round")? },
            "MergeStarted" => Event::MergeStarted { blocks: u("blocks")? },
            "MergeCompleted" => Event::MergeCompleted { k: u("k")?, merge_s: f("merge_s")? },
            "JobDone" => Event::JobDone,
            "JobFailed" => Event::JobFailed { error: s("error")? },
            "BlockScattered" => {
                Event::BlockScattered { job: u("job")?, worker: u("worker")?, band: u("band")? }
            }
            "WorkerRetry" => Event::WorkerRetry { job: u("job")?, attempt: u("attempt")? },
            "WorkerLost" => Event::WorkerLost { worker: u("worker")? },
            "Dropped" => Event::Dropped { n: u("n")? },
            "MatrixAppended" => {
                Event::MatrixAppended { rows: u("rows")?, generation: u("generation")? }
            }
            "LabelsUpdated" => Event::LabelsUpdated {
                job: u("job")?,
                k: u("k")?,
                generation: u("generation")?,
            },
            other => bail!("unknown event kind '{other}'"),
        })
    }
}

/// A sequenced, timestamped event as stored in the journal.
#[derive(Clone, Debug, PartialEq)]
pub struct EventRecord {
    /// Monotonic per-journal sequence number, from 0.
    pub seq: u64,
    /// Milliseconds since the journal was created.
    pub t_ms: u64,
    pub event: Event,
}

/// A single-line token: whitespace collapsed so the value survives the
/// space-separated `key=value` wire format. Only `JobFailed.error`
/// carries free text; the JSONL spill keeps the original string.
fn tokenize(s: &str) -> String {
    s.chars().map(|c| if c.is_whitespace() { '_' } else { c }).collect()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl EventRecord {
    /// Space-separated `key=value` form — the body of an `EVENT` line
    /// on the `EVENTS` protocol verb.
    pub fn to_wire(&self) -> String {
        let mut out = format!("seq={} t_ms={} kind={}", self.seq, self.t_ms, self.event.kind());
        for (k, v) in self.event.fields() {
            match v {
                Field::U(n) => out.push_str(&format!(" {k}={n}")),
                Field::F(x) => out.push_str(&format!(" {k}={x:?}")),
                Field::S(s) => out.push_str(&format!(" {k}={}", tokenize(&s))),
            }
        }
        out
    }

    /// One flat JSON object — a line of the JSONL spill.
    pub fn to_json(&self) -> String {
        let mut out =
            format!("{{\"seq\":{},\"t_ms\":{},\"kind\":\"{}\"", self.seq, self.t_ms, self.event.kind());
        for (k, v) in self.event.fields() {
            match v {
                Field::U(n) => out.push_str(&format!(",\"{k}\":{n}")),
                Field::F(x) => out.push_str(&format!(",\"{k}\":{x:?}")),
                Field::S(s) => out.push_str(&format!(",\"{k}\":\"{}\"", json_escape(&s))),
            }
        }
        out.push('}');
        out
    }

    /// Parse one JSONL spill line back into a record.
    pub fn from_json(line: &str) -> Result<EventRecord> {
        let fields = parse_flat_json(line)?;
        let get = |k: &str| -> Result<Field> {
            fields
                .iter()
                .find(|(key, _)| key == k)
                .map(|(_, v)| v.clone())
                .with_context(|| format!("event line missing field '{k}'"))
        };
        let seq = match get("seq")? {
            Field::U(v) => v,
            other => bail!("seq: expected integer, got {other:?}"),
        };
        let t_ms = match get("t_ms")? {
            Field::U(v) => v,
            other => bail!("t_ms: expected integer, got {other:?}"),
        };
        let kind = match get("kind")? {
            Field::S(v) => v,
            other => bail!("kind: expected string, got {other:?}"),
        };
        Ok(EventRecord { seq, t_ms, event: Event::from_fields(&kind, &get)? })
    }
}

/// Minimal flat-JSON-object parser (string / unsigned-int / float
/// values only) — enough for the journal's own output; not a general
/// JSON reader. The crate is dependency-free by design, so no serde.
fn parse_flat_json(s: &str) -> Result<Vec<(String, Field)>> {
    let mut chars = s.trim().chars().peekable();
    let mut out = Vec::new();
    let expect = |chars: &mut std::iter::Peekable<std::str::Chars>, want: char| -> Result<()> {
        while chars.peek().is_some_and(|c| c.is_whitespace()) {
            chars.next();
        }
        match chars.next() {
            Some(c) if c == want => Ok(()),
            other => bail!("expected '{want}', got {other:?}"),
        }
    };
    let parse_string = |chars: &mut std::iter::Peekable<std::str::Chars>| -> Result<String> {
        let mut v = String::new();
        loop {
            match chars.next() {
                Some('"') => return Ok(v),
                Some('\\') => match chars.next() {
                    Some('"') => v.push('"'),
                    Some('\\') => v.push('\\'),
                    Some('n') => v.push('\n'),
                    Some('r') => v.push('\r'),
                    Some('t') => v.push('\t'),
                    Some('u') => {
                        let hex: String = (0..4).filter_map(|_| chars.next()).collect();
                        let code = u32::from_str_radix(&hex, 16)
                            .with_context(|| format!("bad \\u escape '{hex}'"))?;
                        v.push(char::from_u32(code).context("bad \\u code point")?);
                    }
                    other => bail!("bad escape {other:?}"),
                },
                Some(c) => v.push(c),
                None => bail!("unterminated string"),
            }
        }
    };
    expect(&mut chars, '{')?;
    loop {
        while chars.peek().is_some_and(|c| c.is_whitespace()) {
            chars.next();
        }
        match chars.peek() {
            Some('}') => {
                chars.next();
                break;
            }
            Some(',') => {
                chars.next();
                continue;
            }
            Some('"') => {}
            other => bail!("expected key, got {other:?}"),
        }
        chars.next(); // opening quote
        let key = parse_string(&mut chars)?;
        expect(&mut chars, ':')?;
        while chars.peek().is_some_and(|c| c.is_whitespace()) {
            chars.next();
        }
        let value = match chars.peek() {
            Some('"') => {
                chars.next();
                Field::S(parse_string(&mut chars)?)
            }
            Some(c) if c.is_ascii_digit() || *c == '-' => {
                let mut lex = String::new();
                while chars
                    .peek()
                    .is_some_and(|c| c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E'))
                {
                    lex.push(chars.next().unwrap());
                }
                if lex.contains(['.', 'e', 'E']) {
                    Field::F(lex.parse().with_context(|| format!("bad number '{lex}'"))?)
                } else {
                    Field::U(lex.parse().with_context(|| format!("bad integer '{lex}'"))?)
                }
            }
            other => bail!("unsupported value start {other:?}"),
        };
        out.push((key, value));
    }
    Ok(out)
}

#[derive(Debug)]
struct Ring {
    records: VecDeque<EventRecord>,
    /// Next sequence number to assign.
    next_seq: u64,
    /// Total records evicted from the front of the ring.
    dropped: u64,
}

/// Per-job event journal: bounded ring + optional JSONL spill, plus the
/// job's hierarchical span sheet (see [`span::SpanRecord`]). The
/// journal's creation instant is the epoch every span's `start_us` is
/// measured from, so one clock anchors the whole tree.
#[derive(Debug)]
pub struct Journal {
    ring: Mutex<Ring>,
    capacity: usize,
    start: Instant,
    spill: Option<Mutex<File>>,
    spill_path: Option<PathBuf>,
    /// Completed spans, recorded in completion order (children usually
    /// land before their parents — readers sort by `start_us`).
    spans: Mutex<Vec<SpanRecord>>,
    /// Next span id to hand out; 0 is reserved as the no-parent root.
    next_span: AtomicU64,
}

impl Journal {
    pub fn new(capacity: usize) -> Journal {
        Journal {
            ring: Mutex::new(Ring { records: VecDeque::new(), next_seq: 0, dropped: 0 }),
            capacity: capacity.max(1),
            start: Instant::now(),
            spill: None,
            spill_path: None,
            spans: Mutex::new(Vec::new()),
            next_span: AtomicU64::new(1),
        }
    }

    /// A journal that also appends every record to `path` as JSONL
    /// (creating parent directories), for post-mortems of dead jobs.
    pub fn with_spill(capacity: usize, path: &Path) -> Result<Journal> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create journal dir {parent:?}"))?;
        }
        let file = File::options()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open journal spill {path:?}"))?;
        let mut j = Journal::new(capacity);
        j.spill = Some(Mutex::new(file));
        j.spill_path = Some(path.to_path_buf());
        Ok(j)
    }

    /// Where this journal spills JSONL, if anywhere.
    pub fn spill_path(&self) -> Option<&Path> {
        self.spill_path.as_deref()
    }

    /// Append an event; returns its sequence number.
    pub fn emit(&self, event: Event) -> u64 {
        let t_ms = self.start.elapsed().as_millis() as u64;
        let mut ring = self.ring.lock().unwrap();
        let seq = ring.next_seq;
        ring.next_seq += 1;
        let rec = EventRecord { seq, t_ms, event };
        if let Some(spill) = &self.spill {
            // Spill failures are swallowed: the journal is advisory and
            // must never fail a job over a full disk.
            let mut f = spill.lock().unwrap();
            let _ = writeln!(f, "{}", rec.to_json());
        }
        if ring.records.len() == self.capacity {
            ring.records.pop_front();
            ring.dropped += 1;
        }
        ring.records.push_back(rec);
        seq
    }

    /// Records with `seq > after`, capped at `max`. If the cursor has
    /// fallen behind the ring (records it never saw were evicted), the
    /// first returned record is a synthetic [`Event::Dropped`] covering
    /// the gap, sequenced just before the first retained record.
    pub fn events_after(&self, after: Option<u64>, max: usize) -> Vec<EventRecord> {
        let ring = self.ring.lock().unwrap();
        let cursor = after.map(|a| a + 1).unwrap_or(0);
        let mut out = Vec::new();
        if let Some(front) = ring.records.front() {
            if cursor < front.seq {
                out.push(EventRecord {
                    seq: front.seq - 1,
                    t_ms: front.t_ms,
                    event: Event::Dropped { n: front.seq - cursor },
                });
            }
        }
        for rec in ring.records.iter() {
            if out.len() >= max.max(1) {
                break;
            }
            if rec.seq >= cursor {
                out.push(rec.clone());
            }
        }
        out
    }

    /// Total records ever evicted from the ring.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().unwrap().dropped
    }

    /// The highest sequence number assigned so far, if any.
    pub fn last_seq(&self) -> Option<u64> {
        let ring = self.ring.lock().unwrap();
        ring.next_seq.checked_sub(1)
    }

    /// Microseconds since the journal was created — the span clock.
    pub fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Allocate a span id without recording anything yet. Parents use
    /// this so children can reference them before the parent's duration
    /// is known.
    pub fn reserve_span(&self) -> u64 {
        self.next_span.fetch_add(1, Ordering::Relaxed)
    }

    /// Record one completed span. Ids from [`Journal::reserve_span`] or
    /// re-assigned worker-local ids (see the shard router's stitcher).
    pub fn record_span(&self, record: SpanRecord) {
        let mut spans = self.spans.lock().unwrap();
        if spans.len() < SPAN_CAPACITY {
            spans.push(record);
        }
    }

    /// Bulk-record spans (the router's stitch path).
    pub fn record_spans(&self, records: impl IntoIterator<Item = SpanRecord>) {
        let mut spans = self.spans.lock().unwrap();
        for record in records {
            if spans.len() >= SPAN_CAPACITY {
                break;
            }
            spans.push(record);
        }
    }

    /// Snapshot of every recorded span, sorted by start time (ties by
    /// id, which allocation order makes monotonic per emitter).
    pub fn spans(&self) -> Vec<SpanRecord> {
        let mut out = self.spans.lock().unwrap().clone();
        out.sort_by_key(|s| (s.start_us, s.id));
        out
    }
}

/// Read a JSONL journal spill back into records (post-mortem path).
pub fn read_jsonl(path: &Path) -> Result<Vec<EventRecord>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read journal {path:?}"))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(EventRecord::from_json)
        .collect()
}

/// Cheap cloneable emission handle threaded through configs. Disabled
/// by default ([`Trace::default`]) — every emission site stays a no-op
/// unless a journal was attached. Besides events, a trace carries the
/// current *parent span id* ([`Trace::parent`]): a layer that opens a
/// span hands its children a [`Trace::child_of`] clone, so the span
/// tree nests without threading ids through every signature.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    journal: Option<Arc<Journal>>,
    parent: u64,
}

impl Trace {
    /// A trace writing into `journal`.
    pub fn to_journal(journal: Arc<Journal>) -> Trace {
        Trace { journal: Some(journal), parent: ROOT_SPAN }
    }

    /// The disabled (no-op) trace — same as `Trace::default()`.
    pub fn disabled() -> Trace {
        Trace { journal: None, parent: ROOT_SPAN }
    }

    pub fn enabled(&self) -> bool {
        self.journal.is_some()
    }

    /// Emit `event` if enabled; otherwise a no-op.
    pub fn emit(&self, event: Event) {
        if let Some(j) = &self.journal {
            j.emit(event);
        }
    }

    /// The backing journal, if enabled.
    pub fn journal(&self) -> Option<&Arc<Journal>> {
        self.journal.as_ref()
    }

    /// The span id new spans should parent under (0 = tree root).
    pub fn parent(&self) -> u64 {
        self.parent
    }

    /// A clone whose spans nest under `span` instead of this trace's
    /// current parent.
    pub fn child_of(&self, span: u64) -> Trace {
        Trace { journal: self.journal.clone(), parent: span }
    }

    /// Microseconds since the journal epoch (0 when disabled).
    pub fn now_us(&self) -> u64 {
        self.journal.as_ref().map_or(0, |j| j.now_us())
    }

    /// Allocate a span id (0 when disabled — every span op treats id 0
    /// as "tracing off" and becomes a no-op).
    pub fn reserve_span(&self) -> u64 {
        self.journal.as_ref().map_or(0, |j| j.reserve_span())
    }

    /// Record a completed span under a pre-reserved id. No-op when
    /// disabled or when `id` is 0 (a reservation made while disabled).
    pub fn record_span(&self, id: u64, parent: u64, name: &str, worker: u64, start_us: u64, dur_us: u64) {
        if id == 0 {
            return;
        }
        if let Some(j) = &self.journal {
            j.record_span(SpanRecord {
                id,
                parent,
                name: name.to_string(),
                worker,
                start_us,
                dur_us,
            });
        }
    }

    /// Reserve + record in one step, parented under [`Trace::parent`].
    /// Returns the new span's id (0 when disabled).
    pub fn add_span(&self, name: &str, worker: u64, start_us: u64, dur_us: u64) -> u64 {
        let id = self.reserve_span();
        self.record_span(id, self.parent, name, worker, start_us, dur_us);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lamc-trace-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("journal.jsonl")
    }

    fn sample_events() -> Vec<Event> {
        vec![
            Event::JobQueued,
            Event::JobStarted,
            Event::RoundStarted { round: 0, jobs: 4 },
            Event::PrefetchWave { round: 1 },
            Event::RoundCompleted {
                round: 0,
                jobs: 4,
                gather_s: 0.125,
                exec_s: 1.5,
                io_chunks: 7,
                io_bytes: 4096,
                io_cache_hits: 3,
                prefetch_issued: 2,
                prefetch_hits: 1,
                prefetch_wasted_bytes: 64,
            },
            Event::MergeStarted { blocks: 8 },
            Event::MergeCompleted { k: 3, merge_s: 0.001 },
            Event::BlockScattered { job: 2, worker: 1, band: 0 },
            Event::WorkerLost { worker: 1 },
            Event::WorkerRetry { job: 2, attempt: 1 },
            Event::MatrixAppended { rows: 40, generation: 2 },
            Event::LabelsUpdated { job: 5, k: 3, generation: 2 },
            Event::JobFailed { error: "worker 1 lost: connection reset".into() },
            Event::JobDone,
        ]
    }

    #[test]
    fn seqs_are_monotonic_and_ordered() {
        let j = Journal::new(64);
        for e in sample_events() {
            j.emit(e);
        }
        let recs = j.events_after(None, usize::MAX);
        assert_eq!(recs.len(), sample_events().len());
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.seq, i as u64, "dense monotonic seq");
        }
        for w in recs.windows(2) {
            assert!(w[0].t_ms <= w[1].t_ms, "timestamps never go backwards");
        }
        assert_eq!(j.last_seq(), Some(sample_events().len() as u64 - 1));
    }

    #[test]
    fn cursor_pages_without_overlap() {
        let j = Journal::new(64);
        for i in 0..10 {
            j.emit(Event::RoundStarted { round: i, jobs: 1 });
        }
        let first = j.events_after(None, 4);
        assert_eq!(first.len(), 4);
        let rest = j.events_after(Some(first.last().unwrap().seq), usize::MAX);
        assert_eq!(rest.len(), 6);
        assert_eq!(rest[0].seq, 4);
        assert!(j.events_after(Some(9), usize::MAX).is_empty(), "cursor at tail sees nothing");
    }

    #[test]
    fn overflow_marks_dropped_gap() {
        let j = Journal::new(4);
        for i in 0..10 {
            j.emit(Event::RoundStarted { round: i, jobs: 1 });
        }
        assert_eq!(j.dropped(), 6);
        let recs = j.events_after(None, usize::MAX);
        // Synthetic gap marker first, then the retained tail.
        assert_eq!(recs[0].event, Event::Dropped { n: 6 });
        assert_eq!(recs[0].seq, 5, "gap marker sequenced just before the first retained record");
        assert_eq!(recs[1].seq, 6);
        assert_eq!(recs.len(), 5);
        // A reader that already saw seq 7 gets no gap marker.
        let tail = j.events_after(Some(7), usize::MAX);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[0].seq, 8);
    }

    #[test]
    fn wire_and_json_forms_cover_every_field() {
        let rec = EventRecord {
            seq: 3,
            t_ms: 12,
            event: Event::JobFailed { error: "boom with spaces".into() },
        };
        let wire = rec.to_wire();
        assert!(wire.starts_with("seq=3 t_ms=12 kind=JobFailed"), "{wire}");
        assert!(wire.contains("error=boom_with_spaces"), "wire values stay single tokens: {wire}");
        assert!(rec.to_json().contains("\"error\":\"boom with spaces\""));
    }

    #[test]
    fn json_round_trips_every_event_kind() {
        for (i, e) in sample_events().into_iter().enumerate() {
            let rec = EventRecord { seq: i as u64, t_ms: 10 * i as u64, event: e };
            let back = EventRecord::from_json(&rec.to_json()).unwrap();
            assert_eq!(back, rec, "round-trip of {}", rec.to_json());
        }
    }

    #[test]
    fn json_rejects_damage() {
        assert!(EventRecord::from_json("{\"seq\":1}").is_err(), "missing fields");
        assert!(EventRecord::from_json("{\"seq\":1,\"t_ms\":2,\"kind\":\"NoSuchKind\"}").is_err());
        assert!(EventRecord::from_json("not json at all").is_err());
        assert!(
            EventRecord::from_json("{\"seq\":1,\"t_ms\":2,\"kind\":\"Dropped\"}").is_err(),
            "kind-specific field missing"
        );
    }

    #[test]
    fn jsonl_spill_round_trips() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let j = Journal::with_spill(4, &path).unwrap();
        let events = sample_events();
        for e in &events {
            j.emit(e.clone());
        }
        assert_eq!(j.spill_path(), Some(path.as_path()));
        // The spill keeps *everything*, even records the ring evicted.
        let recs = read_jsonl(&path).unwrap();
        assert_eq!(recs.len(), events.len());
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert_eq!(&r.event, &events[i]);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn disabled_trace_is_a_no_op() {
        let t = Trace::default();
        assert!(!t.enabled());
        t.emit(Event::JobDone); // must not panic
        assert!(t.journal().is_none());

        let j = Arc::new(Journal::new(8));
        let t = Trace::to_journal(Arc::clone(&j));
        assert!(t.enabled());
        t.emit(Event::JobDone);
        assert_eq!(j.events_after(None, 10).len(), 1);
    }
}
