//! Command-line argument parsing (dependency-free `clap` substitute).
//!
//! Grammar: `lamc <command> [--flag value]... [--switch]...`
//! Commands and flags are declared by the binary; this module handles
//! tokenizing, lookup, typed access, and usage errors.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

/// A bad invocation (unknown command/flag, missing value): the binary
/// prints usage to stderr and exits non-zero when it sees one of these,
/// instead of treating it like a runtime failure.
#[derive(Debug)]
pub struct UsageError(pub String);

impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for UsageError {}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `switch_names` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, switch_names: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| UsageError(format!("flag --{name} expects a value")))?;
                    out.flags.insert(name.to_string(), v);
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env(switch_names: &[&str]) -> Result<Self> {
        Self::parse(std::env::args().skip(1), switch_names)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{flag} = {v} is not an integer")),
        }
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{flag} = {v} is not a float")),
        }
    }

    pub fn get_u64(&self, flag: &str, default: u64) -> Result<u64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{flag} = {v} is not an integer")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error ([`UsageError`]) if any unknown flags were passed.
    pub fn expect_flags(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                let known = if known.is_empty() { "none".to_string() } else { known.join(", ") };
                return Err(UsageError(format!("unknown flag --{k} (known: {known})")).into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string), &["verbose", "sparse"]).unwrap()
    }

    #[test]
    fn full_grammar() {
        let a = parse("run --dataset classic4 --k=4 --verbose extra");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("dataset"), Some("classic4"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 4);
        assert!(a.has("verbose"));
        assert!(!a.has("sparse"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn defaults_and_types() {
        let a = parse("bench --p 0.95");
        assert_eq!(a.get_f64("p", 0.5).unwrap(), 0.95);
        assert_eq!(a.get_f64("missing", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_u64("seed", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["run".into(), "--k".into()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_type_is_error() {
        let a = parse("run --k nope");
        assert!(a.get_usize("k", 0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("run --k 3 --oops 1");
        assert!(a.expect_flags(&["k"]).is_err());
        assert!(a.expect_flags(&["k", "oops"]).is_ok());
    }

    #[test]
    fn usage_errors_are_typed() {
        let a = parse("run --k 3 --oops 1");
        let err = a.expect_flags(&["k"]).unwrap_err();
        assert!(err.is::<UsageError>(), "unknown flag must be a UsageError");
        let err = Args::parse(["run".into(), "--k".into()], &[]).unwrap_err();
        assert!(err.is::<UsageError>(), "missing value must be a UsageError");
    }
}
