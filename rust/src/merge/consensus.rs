//! Final label extraction from merged co-clusters (paper §IV-D output
//! stage: one row/column labeling from the merged consensus set, as
//! scored in Table III).

use super::cocluster_set::Cocluster;
use super::hierarchical::{merge_coclusters, MergeConfig};

/// Cross-node reduce over partial co-cluster sets: the shard router's
/// aggregation step.
///
/// Each worker returns the atom co-clusters of the block jobs it
/// executed; the router concatenates those partial sets **in flat job
/// order** (rounds, then grid order within a round — the same order
/// `pipeline::Lamc::run` flat-maps its in-process results) and runs the
/// one global hierarchical merge. Because the merge consumes exactly
/// the sequence the single-node run would have built, the merged set —
/// and therefore `extract_labels` output — is byte-identical to the
/// single-node run. That equality is the distributed determinism
/// guarantee, and `tests/property_store_layouts.rs` proves it per
/// seeded configuration rather than asserting it in prose.
pub fn reduce_partial_sets(partials: Vec<Vec<Cocluster>>, cfg: &MergeConfig) -> Vec<Cocluster> {
    merge_coclusters(partials.into_iter().flatten().collect(), cfg)
}

/// Assign every row/column id a final cluster label by maximum vote.
///
/// Each merged co-cluster becomes one label. An id belonging to several
/// co-clusters takes the one where its vote mass (normalized by cluster
/// weight, tie-broken by cluster area) is largest. Ids covered by no
/// co-cluster get the label of the largest cluster (a deliberate,
/// documented fallback: under the Theorem-1 guarantee such ids are rare
/// noise, and NMI/ARI penalize them the same wherever they go).
///
/// Returns `(row_labels, col_labels, k)`.
pub fn extract_labels(clusters: &[Cocluster], rows: usize, cols: usize) -> (Vec<usize>, Vec<usize>, usize) {
    let k = clusters.len().max(1);
    let mut row_best = vec![(f32::MIN, 0usize); rows];
    let mut row_set = vec![false; rows];
    let mut col_best = vec![(f32::MIN, 0usize); cols];
    let mut col_set = vec![false; cols];

    for (label, c) in clusters.iter().enumerate() {
        let norm = 1.0 / c.weight.max(1.0);
        for (&id, &v) in c.rows.iter().zip(&c.row_votes) {
            let id = id as usize;
            if id >= rows {
                continue;
            }
            let score = v * norm;
            if !row_set[id] || score > row_best[id].0 {
                row_best[id] = (score, label);
                row_set[id] = true;
            }
        }
        for (&id, &v) in c.cols.iter().zip(&c.col_votes) {
            let id = id as usize;
            if id >= cols {
                continue;
            }
            let score = v * norm;
            if !col_set[id] || score > col_best[id].0 {
                col_best[id] = (score, label);
                col_set[id] = true;
            }
        }
    }

    // Fallback for uncovered ids: the largest cluster (label of max area),
    // or 0 when there are no clusters at all.
    let fallback = clusters
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| c.area())
        .map(|(i, _)| i)
        .unwrap_or(0);

    let row_labels = row_best
        .iter()
        .zip(&row_set)
        .map(|(&(_, l), &set)| if set { l } else { fallback })
        .collect();
    let col_labels = col_best
        .iter()
        .zip(&col_set)
        .map(|(&(_, l), &set)| if set { l } else { fallback })
        .collect();
    (row_labels, col_labels, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn atom(rows: &[u32], cols: &[u32]) -> Cocluster {
        Cocluster::atom(rows.to_vec(), cols.to_vec(), 0.0)
    }

    #[test]
    fn disjoint_clusters_label_directly() {
        let clusters = vec![atom(&[0, 1], &[0]), atom(&[2, 3], &[1])];
        let (r, c, k) = extract_labels(&clusters, 4, 2);
        assert_eq!(k, 2);
        assert_eq!(r, vec![0, 0, 1, 1]);
        assert_eq!(c, vec![0, 1]);
    }

    #[test]
    fn overlapping_id_takes_higher_vote() {
        let mut a = atom(&[0, 1], &[0]);
        a.weight = 2.0;
        a.row_votes = vec![2.0, 0.5]; // id 1 weak in a
        let b = atom(&[1, 2], &[1]); // id 1 full vote in b
        let (r, _, _) = extract_labels(&[a, b], 3, 2);
        assert_eq!(r[0], 0);
        assert_eq!(r[1], 1, "weakly-voted id should defect to cluster b");
        assert_eq!(r[2], 1);
    }

    #[test]
    fn uncovered_ids_fall_back_to_largest() {
        let clusters = vec![atom(&[0], &[0]), atom(&[1, 2, 3], &[1, 2])];
        let (r, c, _) = extract_labels(&clusters, 5, 4);
        assert_eq!(r[4], 1, "uncovered row → largest cluster");
        assert_eq!(c[3], 1, "uncovered col → largest cluster");
    }

    #[test]
    fn empty_cluster_list_is_single_cluster() {
        let (r, c, k) = extract_labels(&[], 3, 2);
        assert_eq!(k, 1);
        assert_eq!(r, vec![0, 0, 0]);
        assert_eq!(c, vec![0, 0]);
    }

    #[test]
    fn partial_set_reduce_equals_single_concatenated_merge() {
        // Twelve atoms split across "workers" at several different job
        // boundaries must merge to the identical sequence — the router
        // only controls the split, never the flat order.
        let atoms: Vec<Cocluster> = (0..12u32)
            .map(|i| {
                let base = (i % 4) * 10;
                Cocluster::atom(
                    vec![base, base + 1, base + i % 3],
                    vec![base + 2, base + 3],
                    -(i as f64),
                )
            })
            .collect();
        let cfg = MergeConfig::default();
        let whole = merge_coclusters(atoms.clone(), &cfg);
        for split in [1usize, 3, 5, 12] {
            let partials: Vec<Vec<Cocluster>> =
                atoms.chunks(split).map(|c| c.to_vec()).collect();
            let reduced = reduce_partial_sets(partials, &cfg);
            assert_eq!(reduced, whole, "split={split} changed the merge");
        }
        // Empty partial sets (a worker whose jobs all produced no
        // atoms) are transparent.
        let padded = vec![vec![], atoms.clone(), vec![]];
        assert_eq!(reduce_partial_sets(padded, &cfg), whole);
    }

    #[test]
    fn labels_always_in_range() {
        let clusters = vec![atom(&[0, 9], &[0]), atom(&[5], &[1, 3])];
        let (r, c, k) = extract_labels(&clusters, 10, 4);
        assert!(r.iter().all(|&l| l < k));
        assert!(c.iter().all(|&l| l < k));
    }
}
