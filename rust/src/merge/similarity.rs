//! Co-cluster similarity + minhash bucketing for sub-quadratic merging
//! (paper §IV-D: the similarity criterion deciding which co-clusters
//! from different submatrices/samplings refer to the same structure).

use super::cocluster_set::Cocluster;

/// Jaccard similarity of two sorted id lists.
pub fn jaccard(a: &[u32], b: &[u32]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let mut inter = 0usize;
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

/// Paper-aligned pair similarity: mean of row-set and column-set Jaccard.
pub fn pair_similarity(a: &Cocluster, b: &Cocluster) -> f64 {
    0.5 * (jaccard(&a.rows, &b.rows) + jaccard(&a.cols, &b.cols))
}

/// Minhash signature of a row-id set (for LSH bucketing). `H` hashes.
pub fn minhash_signature<const H: usize>(ids: &[u32], seed: u64) -> [u64; H] {
    let mut sig = [u64::MAX; H];
    for &id in ids {
        for (h, slot) in sig.iter_mut().enumerate() {
            // SplitMix-style per-hash mixing; cheap and adequate for
            // bucketing (not cryptographic).
            let mut z = (id as u64).wrapping_add(seed).wrapping_add((h as u64) << 32).wrapping_mul(0x9E3779B97F4A7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z ^= z >> 27;
            if z < *slot {
                *slot = z;
            }
        }
    }
    sig
}

/// Bucket key: band of the minhash signature. Co-clusters sharing a band
/// key are candidate merge pairs.
pub fn band_keys<const H: usize>(sig: &[u64; H], bands: usize) -> Vec<u64> {
    assert!(bands > 0 && H % bands == 0, "H must divide into bands");
    let per = H / bands;
    (0..bands)
        .map(|b| {
            let mut acc = 0xcbf29ce484222325u64; // FNV offset
            for i in 0..per {
                acc = (acc ^ sig[b * per + i]).wrapping_mul(0x100000001b3);
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn jaccard_basics() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 0.0);
        assert!((jaccard(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert_eq!(jaccard(&[], &[]), 1.0);
        assert_eq!(jaccard(&[1], &[]), 0.0);
    }

    #[test]
    fn pair_similarity_averages() {
        let a = Cocluster::atom(vec![1, 2], vec![1, 2], 0.0);
        let b = Cocluster::atom(vec![1, 2], vec![3, 4], 0.0);
        assert!((pair_similarity(&a, &b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn minhash_identical_sets_identical_sigs() {
        let a = minhash_signature::<16>(&[5, 9, 100], 7);
        let b = minhash_signature::<16>(&[100, 5, 9], 7);
        assert_eq!(a, b);
    }

    #[test]
    fn minhash_similarity_estimates_jaccard() {
        let mut rng = Xoshiro256::seed_from(501);
        let base: Vec<u32> = (0..400).map(|_| rng.next_below(10_000) as u32).collect();
        let mut near = base.clone();
        near.truncate(360);
        near.extend((0..40).map(|_| rng.next_below(10_000) as u32 + 20_000));
        let mut a = base.clone();
        a.sort_unstable();
        a.dedup();
        let mut b = near;
        b.sort_unstable();
        b.dedup();
        let true_j = jaccard(&a, &b);
        const H: usize = 64;
        let sa = minhash_signature::<H>(&a, 7);
        let sb = minhash_signature::<H>(&b, 7);
        let est = sa.iter().zip(&sb).filter(|(x, y)| x == y).count() as f64 / H as f64;
        assert!((est - true_j).abs() < 0.2, "est {est} true {true_j}");
    }

    #[test]
    fn band_keys_collide_for_similar_sets() {
        let ids: Vec<u32> = (0..100).collect();
        let mut near = ids.clone();
        near[99] = 500;
        let sa = minhash_signature::<16>(&ids, 3);
        let sb = minhash_signature::<16>(&near, 3);
        let ka = band_keys::<16>(&sa, 8);
        let kb = band_keys::<16>(&sb, 8);
        let shared = ka.iter().zip(&kb).filter(|(x, y)| x == y).count();
        assert!(shared >= 4, "similar sets should share bands, got {shared}");
    }
}
