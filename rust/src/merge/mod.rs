//! Hierarchical co-cluster merging (paper §IV-D).
//!
//! The paper specifies the merging stage only qualitatively ("iteratively
//! combines the co-clusters from each submatrix … within a pre-fixed
//! number of iterations"). The concrete design here — documented and
//! ablated per DESIGN.md §5 — is consensus-style agglomeration:
//!
//! 1. every block job yields [`Cocluster`]s over *global* ids;
//! 2. levels of pairwise agglomeration merge any two co-clusters whose
//!    row/col Jaccard similarity reaches `τ`, accumulating per-id votes;
//! 3. ids are pruned from a merged co-cluster when their vote share
//!    drops below `min_vote` (removes per-sampling noise);
//! 4. final labels are extracted by maximum vote ([`consensus`]).
//!
//! Levels terminate after `⌈log2 T_p⌉ + 2` rounds at the latest — the
//! "pre-fixed number of iterations" the paper promises.

pub mod cocluster_set;
pub mod hierarchical;
pub mod similarity;
pub mod consensus;

pub use cocluster_set::Cocluster;
pub use consensus::{extract_labels, reduce_partial_sets};
pub use hierarchical::{merge_coclusters, MergeConfig};
pub use similarity::{jaccard, pair_similarity};
