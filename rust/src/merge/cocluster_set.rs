//! Co-cluster value type used by the merging stage (paper §IV-D: the
//! units the hierarchical merge combines, carrying per-id vote mass).

/// A co-cluster over global indices, with per-id vote mass accumulated
/// across merges. Freshly-detected atoms have vote 1.0 on every member.
#[derive(Clone, Debug, PartialEq)]
pub struct Cocluster {
    /// Sorted global row ids.
    pub rows: Vec<u32>,
    /// Vote mass per row id (aligned with `rows`).
    pub row_votes: Vec<f32>,
    /// Sorted global column ids.
    pub cols: Vec<u32>,
    /// Vote mass per column id (aligned with `cols`).
    pub col_votes: Vec<f32>,
    /// Number of atom co-clusters merged into this one.
    pub weight: f32,
    /// Best (lowest) atom objective among members — a quality hint.
    pub quality: f64,
}

impl Cocluster {
    /// Build an atom co-cluster (vote 1 everywhere). Ids are sorted and
    /// deduplicated defensively.
    pub fn atom(mut rows: Vec<u32>, mut cols: Vec<u32>, quality: f64) -> Self {
        rows.sort_unstable();
        rows.dedup();
        cols.sort_unstable();
        cols.dedup();
        let row_votes = vec![1.0; rows.len()];
        let col_votes = vec![1.0; cols.len()];
        Self { rows, row_votes, cols, col_votes, weight: 1.0, quality }
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty() || self.cols.is_empty()
    }

    /// Area = |rows| · |cols| (used for tie-breaking and pruning).
    pub fn area(&self) -> usize {
        self.rows.len() * self.cols.len()
    }

    /// Merge two co-clusters: union of ids with vote accumulation.
    pub fn merge(&self, other: &Cocluster) -> Cocluster {
        let (rows, row_votes) = merge_voted(&self.rows, &self.row_votes, &other.rows, &other.row_votes);
        let (cols, col_votes) = merge_voted(&self.cols, &self.col_votes, &other.cols, &other.col_votes);
        Cocluster {
            rows,
            row_votes,
            cols,
            col_votes,
            weight: self.weight + other.weight,
            quality: self.quality.min(other.quality),
        }
    }

    /// Drop ids whose vote share is below `min_vote` of the *strongest
    /// vote on their side*. Keeps the co-cluster's consensus core.
    ///
    /// The per-side normalization matters: when co-clusters from blocks
    /// in the same grid row merge, their row votes stack but their
    /// column sets are disjoint by construction (each column id can vote
    /// at most once per round on that side) — normalizing against the
    /// total weight would wrongly purge every column.
    pub fn prune(&mut self, min_vote: f32) {
        let row_max = self.row_votes.iter().cloned().fold(0.0f32, f32::max);
        let cut = min_vote * row_max;
        let keep: Vec<usize> = (0..self.rows.len()).filter(|&i| self.row_votes[i] >= cut).collect();
        self.rows = keep.iter().map(|&i| self.rows[i]).collect();
        self.row_votes = keep.iter().map(|&i| self.row_votes[i]).collect();
        let col_max = self.col_votes.iter().cloned().fold(0.0f32, f32::max);
        let cut = min_vote * col_max;
        let keep: Vec<usize> = (0..self.cols.len()).filter(|&i| self.col_votes[i] >= cut).collect();
        self.cols = keep.iter().map(|&i| self.cols[i]).collect();
        self.col_votes = keep.iter().map(|&i| self.col_votes[i]).collect();
    }
}

/// Merge-join two sorted (ids, votes) lists, summing votes on overlap.
fn merge_voted(a_ids: &[u32], a_votes: &[f32], b_ids: &[u32], b_votes: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let mut ids = Vec::with_capacity(a_ids.len() + b_ids.len());
    let mut votes = Vec::with_capacity(a_ids.len() + b_ids.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_ids.len() && j < b_ids.len() {
        match a_ids[i].cmp(&b_ids[j]) {
            std::cmp::Ordering::Less => {
                ids.push(a_ids[i]);
                votes.push(a_votes[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                ids.push(b_ids[j]);
                votes.push(b_votes[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                ids.push(a_ids[i]);
                votes.push(a_votes[i] + b_votes[j]);
                i += 1;
                j += 1;
            }
        }
    }
    ids.extend_from_slice(&a_ids[i..]);
    votes.extend_from_slice(&a_votes[i..]);
    ids.extend_from_slice(&b_ids[j..]);
    votes.extend_from_slice(&b_votes[j..]);
    (ids, votes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atom_sorts_and_dedups() {
        let c = Cocluster::atom(vec![3, 1, 3, 2], vec![9, 9, 0], 0.5);
        assert_eq!(c.rows, vec![1, 2, 3]);
        assert_eq!(c.cols, vec![0, 9]);
        assert_eq!(c.weight, 1.0);
        assert_eq!(c.row_votes, vec![1.0; 3]);
    }

    #[test]
    fn merge_unions_and_accumulates() {
        let a = Cocluster::atom(vec![1, 2, 3], vec![0, 1], 0.2);
        let b = Cocluster::atom(vec![2, 3, 4], vec![1, 2], 0.1);
        let m = a.merge(&b);
        assert_eq!(m.rows, vec![1, 2, 3, 4]);
        assert_eq!(m.row_votes, vec![1.0, 2.0, 2.0, 1.0]);
        assert_eq!(m.cols, vec![0, 1, 2]);
        assert_eq!(m.col_votes, vec![1.0, 2.0, 1.0]);
        assert_eq!(m.weight, 2.0);
        assert_eq!(m.quality, 0.1);
    }

    #[test]
    fn merge_is_commutative() {
        let a = Cocluster::atom(vec![1, 5], vec![2], 0.0);
        let b = Cocluster::atom(vec![5, 9], vec![2, 3], 0.0);
        assert_eq!(a.merge(&b), b.merge(&a));
    }

    #[test]
    fn prune_keeps_consensus_core() {
        let a = Cocluster::atom(vec![1, 2, 3], vec![0], 0.0);
        let b = Cocluster::atom(vec![2, 3, 4], vec![0], 0.0);
        let c = Cocluster::atom(vec![2, 3, 5], vec![0], 0.0);
        let mut m = a.merge(&b).merge(&c);
        m.prune(0.6); // need vote ≥ 1.8 of weight 3
        assert_eq!(m.rows, vec![2, 3]);
        assert_eq!(m.cols, vec![0]);
    }

    #[test]
    fn area_and_empty() {
        let c = Cocluster::atom(vec![1, 2], vec![7, 8, 9], 0.0);
        assert_eq!(c.area(), 6);
        assert!(!c.is_empty());
        let mut e = c.clone();
        e.prune(10.0);
        assert!(e.is_empty());
    }
}
