//! Hierarchical agglomeration of atom co-clusters (paper §IV-D: the
//! hierarchical co-cluster merging algorithm — pairwise agglomeration
//! levels within a pre-fixed iteration bound).

use super::cocluster_set::Cocluster;
use super::similarity::{band_keys, minhash_signature, pair_similarity};

#[derive(Clone, Debug)]
pub struct MergeConfig {
    /// Similarity threshold τ: merge a pair when mean row/col Jaccard ≥ τ.
    pub tau: f64,
    /// Hard cap on agglomeration levels (the paper's "pre-fixed number of
    /// iterations"). 0 = auto: `ceil(log2(#clusters)) + 2`.
    pub max_levels: usize,
    /// Vote share below which an id is pruned from a merged co-cluster.
    pub min_vote: f32,
    /// Above this cluster count, candidate pairs come from minhash LSH
    /// buckets instead of all-pairs.
    pub lsh_threshold: usize,
    /// Drop final co-clusters smaller than this many rows or cols.
    pub min_size: usize,
}

impl Default for MergeConfig {
    fn default() -> Self {
        Self { tau: 0.35, max_levels: 0, min_vote: 0.34, lsh_threshold: 512, min_size: 2 }
    }
}

/// One agglomeration level: find mergeable pairs, union them.
/// Returns (clusters, merged_any).
fn level(mut clusters: Vec<Cocluster>, cfg: &MergeConfig) -> (Vec<Cocluster>, bool) {
    let n = clusters.len();
    if n < 2 {
        return (clusters, false);
    }
    // Candidate pair generation.
    let candidate_pairs: Vec<(usize, usize)> = if n <= cfg.lsh_threshold {
        (0..n).flat_map(|i| ((i + 1)..n).map(move |j| (i, j))).collect()
    } else {
        const H: usize = 16;
        const BANDS: usize = 8;
        let mut buckets: std::collections::HashMap<(usize, u64), Vec<usize>> = std::collections::HashMap::new();
        for (idx, c) in clusters.iter().enumerate() {
            let sig = minhash_signature::<H>(&c.rows, 0xC0C1);
            for (b, key) in band_keys::<H>(&sig, BANDS).into_iter().enumerate() {
                buckets.entry((b, key)).or_default().push(idx);
            }
        }
        let mut pairs = std::collections::HashSet::new();
        for members in buckets.values() {
            if members.len() < 2 || members.len() > 64 {
                continue; // skip degenerate mega-buckets
            }
            for (a, &i) in members.iter().enumerate() {
                for &j in &members[a + 1..] {
                    pairs.insert((i.min(j), i.max(j)));
                }
            }
        }
        pairs.into_iter().collect()
    };

    // Score pairs, sort by similarity descending, greedily union.
    let mut scored: Vec<(f64, usize, usize)> = candidate_pairs
        .into_iter()
        .filter_map(|(i, j)| {
            let s = pair_similarity(&clusters[i], &clusters[j]);
            (s >= cfg.tau).then_some((s, i, j))
        })
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    // Greedy matching: each cluster merges at most once per level — this
    // is what makes the process hierarchical (binary merge tree) and
    // bounds the level count logarithmically.
    let mut taken = vec![false; n];
    let mut merged: Vec<Cocluster> = Vec::new();
    let mut any = false;
    for (_, i, j) in scored {
        if taken[i] || taken[j] {
            continue;
        }
        taken[i] = true;
        taken[j] = true;
        merged.push(clusters[i].merge(&clusters[j]));
        any = true;
    }
    for (idx, c) in clusters.drain(..).enumerate() {
        if !taken[idx] {
            merged.push(c);
        }
    }
    (merged, any)
}

/// Merge atom co-clusters into the final consensus set.
pub fn merge_coclusters(atoms: Vec<Cocluster>, cfg: &MergeConfig) -> Vec<Cocluster> {
    let mut clusters: Vec<Cocluster> = atoms.into_iter().filter(|c| !c.is_empty()).collect();
    let max_levels = if cfg.max_levels == 0 {
        ((clusters.len().max(2) as f64).log2().ceil() as usize) + 2
    } else {
        cfg.max_levels
    };
    for _ in 0..max_levels {
        let (next, merged_any) = level(clusters, cfg);
        clusters = next;
        if !merged_any {
            break;
        }
    }
    // Consensus pruning + minimum-size filter.
    for c in &mut clusters {
        c.prune(cfg.min_vote);
    }
    clusters.retain(|c| c.rows.len() >= cfg.min_size && c.cols.len() >= cfg.min_size);
    // Deterministic order: by area descending then ids.
    clusters.sort_by(|a, b| b.area().cmp(&a.area()).then_with(|| a.rows.cmp(&b.rows)));
    clusters
}

#[cfg(test)]
mod tests {
    use super::*;

    fn atom(rows: &[u32], cols: &[u32]) -> Cocluster {
        Cocluster::atom(rows.to_vec(), cols.to_vec(), 0.0)
    }

    #[test]
    fn identical_atoms_collapse_to_one() {
        let atoms = vec![atom(&[1, 2, 3], &[0, 1]); 5];
        let out = merge_coclusters(atoms, &MergeConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].weight, 5.0);
        assert_eq!(out[0].rows, vec![1, 2, 3]);
    }

    #[test]
    fn disjoint_atoms_stay_separate() {
        let atoms = vec![atom(&[1, 2], &[0, 1]), atom(&[10, 11], &[5, 6])];
        let out = merge_coclusters(atoms, &MergeConfig::default());
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn noisy_views_of_same_cocluster_merge() {
        // Three samplings saw overlapping fragments of rows 0..20.
        let atoms = vec![
            atom(&(0..18).collect::<Vec<u32>>(), &[0, 1, 2, 3]),
            atom(&(2..20).collect::<Vec<u32>>(), &[0, 1, 2, 4]),
            atom(&(1..19).collect::<Vec<u32>>(), &[0, 1, 3, 4]),
        ];
        let out = merge_coclusters(atoms, &MergeConfig::default());
        assert_eq!(out.len(), 1, "{out:?}");
        // Consensus core keeps the heavily-voted middle ids.
        assert!(out[0].rows.contains(&10));
        assert!(out[0].cols.contains(&0) && out[0].cols.contains(&1));
    }

    #[test]
    fn threshold_one_only_merges_identical() {
        let atoms = vec![
            atom(&[1, 2, 3], &[0]),
            atom(&[1, 2, 3], &[0]),
            atom(&[1, 2, 4], &[0]),
        ];
        let cfg = MergeConfig { tau: 1.0, min_size: 1, ..Default::default() };
        let out = merge_coclusters(atoms, &cfg);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn min_size_filter_drops_fragments() {
        let atoms = vec![atom(&[1], &[0]), atom(&[5, 6, 7], &[1, 2, 3])];
        let out = merge_coclusters(atoms, &MergeConfig::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rows, vec![5, 6, 7]);
    }

    #[test]
    fn terminates_on_chain_topology() {
        // A chain a~b~c~d where ends are dissimilar: greedy binary
        // merging must still terminate within the level cap.
        let atoms = vec![
            atom(&(0..10).collect::<Vec<u32>>(), &[0, 1]),
            atom(&(4..14).collect::<Vec<u32>>(), &[0, 1]),
            atom(&(8..18).collect::<Vec<u32>>(), &[0, 1]),
            atom(&(12..22).collect::<Vec<u32>>(), &[0, 1]),
        ];
        let out = merge_coclusters(atoms, &MergeConfig { tau: 0.3, ..Default::default() });
        assert!(!out.is_empty() && out.len() <= 2, "{}", out.len());
    }

    #[test]
    fn lsh_path_matches_allpairs_semantics() {
        // Build many copies of two distinct co-clusters; force the LSH
        // path with a tiny threshold and check both survive as exactly
        // two merged clusters.
        let mut atoms = Vec::new();
        for _ in 0..30 {
            atoms.push(atom(&(0..40).collect::<Vec<u32>>(), &(0..10).collect::<Vec<u32>>()));
            atoms.push(atom(&(100..140).collect::<Vec<u32>>(), &(50..60).collect::<Vec<u32>>()));
        }
        let cfg = MergeConfig { lsh_threshold: 4, ..Default::default() };
        let out = merge_coclusters(atoms, &cfg);
        assert_eq!(out.len(), 2, "{:?}", out.iter().map(|c| c.weight).collect::<Vec<_>>());
        assert_eq!(out[0].weight + out[1].weight, 60.0);
    }
}
