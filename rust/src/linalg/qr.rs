//! Thin QR factorization via Householder reflections.
//!
//! Used to re-orthonormalize the sketch between subspace-iteration steps
//! in [`super::svd`]; numerically stabler than Gram–Schmidt for the
//! ill-conditioned sketches produced by power iterations on matrices with
//! fast-decaying spectra.

use crate::matrix::DenseMatrix;

/// Thin QR: returns `Q` (m×k, orthonormal columns) and `R` (k×k, upper
/// triangular) with `A = Q·R`. Requires `m ≥ k`.
pub fn qr_thin(a: &DenseMatrix) -> (DenseMatrix, DenseMatrix) {
    let (m, k) = (a.rows(), a.cols());
    assert!(m >= k, "qr_thin requires tall matrix, got {m}x{k}");
    // Work in f64 for stability; sketches are small (k ≤ ~32).
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut taus = Vec::with_capacity(k);
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(k);

    for j in 0..k {
        // Householder vector for column j, rows j..m.
        let mut norm2 = 0.0f64;
        for i in j..m {
            let x = w[i * k + j];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        let x0 = w[j * k + j];
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0f64; m - j];
        v[0] = x0 - alpha;
        for i in (j + 1)..m {
            v[i - j] = w[i * k + j];
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        let tau = if vnorm2 <= f64::EPSILON { 0.0 } else { 2.0 / vnorm2 };
        // Apply H = I - tau v vᵀ to trailing columns j..k.
        if tau != 0.0 {
            for c in j..k {
                let mut dot = 0.0f64;
                for i in j..m {
                    dot += v[i - j] * w[i * k + c];
                }
                let f = tau * dot;
                for i in j..m {
                    w[i * k + c] -= f * v[i - j];
                }
            }
        }
        taus.push(tau);
        vs.push(v);
    }

    // R = upper triangle of transformed w.
    let mut r = DenseMatrix::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            r.set(i, j, w[i * k + j] as f32);
        }
    }

    // Q = H_0 H_1 ... H_{k-1} · [I_k; 0]: apply reflectors in reverse to
    // the thin identity.
    let mut q = vec![0.0f64; m * k];
    for j in 0..k {
        q[j * k + j] = 1.0;
    }
    for j in (0..k).rev() {
        let tau = taus[j];
        if tau == 0.0 {
            continue;
        }
        let v = &vs[j];
        for c in 0..k {
            let mut dot = 0.0f64;
            for i in j..m {
                dot += v[i - j] * q[i * k + c];
            }
            let f = tau * dot;
            for i in j..m {
                q[i * k + c] -= f * v[i - j];
            }
        }
    }
    let q = DenseMatrix::from_vec(m, k, q.into_iter().map(|x| x as f32).collect());
    (q, r)
}

/// Orthonormality defect `‖QᵀQ - I‖_max` (test/diagnostic helper).
pub fn orthonormality_defect(q: &DenseMatrix) -> f64 {
    let g = super::matmul::matmul_at_b(q, q);
    let k = q.cols();
    let mut worst = 0.0f64;
    for i in 0..k {
        for j in 0..k {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.get(i, j) as f64 - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::rng::Xoshiro256;

    #[test]
    fn reconstructs_input() {
        let mut rng = Xoshiro256::seed_from(51);
        let a = DenseMatrix::randn(40, 8, &mut rng);
        let (q, r) = qr_thin(&a);
        let back = matmul(&q, &r);
        assert!(back.max_abs_diff(&a) < 1e-4, "defect {}", back.max_abs_diff(&a));
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Xoshiro256::seed_from(52);
        let a = DenseMatrix::randn(100, 12, &mut rng);
        let (q, _) = qr_thin(&a);
        assert!(orthonormality_defect(&q) < 1e-5);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Xoshiro256::seed_from(53);
        let a = DenseMatrix::randn(30, 6, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn handles_rank_deficient_columns() {
        // Two identical columns: QR must not produce NaNs.
        let mut rng = Xoshiro256::seed_from(54);
        let base = DenseMatrix::randn(20, 1, &mut rng);
        let mut cols = DenseMatrix::zeros(20, 2);
        for i in 0..20 {
            cols.set(i, 0, base.get(i, 0));
            cols.set(i, 1, base.get(i, 0));
        }
        let (q, r) = qr_thin(&cols);
        assert!(q.data().iter().all(|x| x.is_finite()));
        assert!(r.data().iter().all(|x| x.is_finite()));
        // Reconstruction still holds.
        assert!(matmul(&q, &r).max_abs_diff(&cols) < 1e-4);
    }

    #[test]
    fn square_orthogonal_input_gives_identity_r_scale() {
        let e = DenseMatrix::eye(5);
        let (q, r) = qr_thin(&e);
        assert!(orthonormality_defect(&q) < 1e-6);
        for i in 0..5 {
            assert!((r.get(i, i).abs() - 1.0).abs() < 1e-5);
        }
    }
}
