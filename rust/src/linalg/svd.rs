//! Randomized truncated SVD via subspace (power) iteration.
//!
//! Halko–Martinsson–Tropp structure: sketch `Y = A·G`, a few power
//! iterations with QR re-orthonormalization, then solve the small
//! projected problem `B = QᵀA` by Jacobi SVD of `B·Bᵀ` (k×k). This gives
//! the top-`k` singular triplets to the accuracy spectral co-clustering
//! needs (embeddings, not high-precision factorizations).

use crate::matrix::{ops, DenseMatrix, Matrix};
use crate::rng::Xoshiro256;

use super::matmul::{matmul, matmul_at_b};
use super::qr::qr_thin;

#[derive(Clone, Debug)]
pub struct SvdResult {
    /// Left singular vectors, m×k (columns ordered by decreasing σ).
    pub u: DenseMatrix,
    /// Singular values, length k, decreasing.
    pub s: Vec<f32>,
    /// Right singular vectors, n×k.
    pub v: DenseMatrix,
}

/// Jacobi eigendecomposition of a small symmetric matrix (f64, in place).
/// Returns (eigenvalues, eigenvectors as columns), unordered.
fn jacobi_eigh(a: &mut Vec<f64>, n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _sweep in 0..64 {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for i in 0..n {
                    let aip = a[i * n + p];
                    let aiq = a[i * n + q];
                    a[i * n + p] = c * aip - s * aiq;
                    a[i * n + q] = s * aip + c * aiq;
                }
                for j in 0..n {
                    let apj = a[p * n + j];
                    let aqj = a[q * n + j];
                    a[p * n + j] = c * apj - s * aqj;
                    a[q * n + j] = s * apj + c * aqj;
                }
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }
    let evals = (0..n).map(|i| a[i * n + i]).collect();
    (evals, v)
}

/// Randomized truncated SVD of `a` (either storage format).
///
/// * `k` — number of singular triplets to return.
/// * `oversample` — extra sketch columns (HMT recommend 5–10).
/// * `power_iters` — power iterations `q`; 2–4 suffices for the spectral
///   gaps in co-clustering workloads.
pub fn randomized_svd(
    a: &Matrix,
    k: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Xoshiro256,
) -> SvdResult {
    let (m, n) = (a.rows(), a.cols());
    let l = (k + oversample).min(m.min(n));
    assert!(k <= l, "k={k} exceeds sketch width possible for {m}x{n}");

    // Sketch the range of A.
    let g = DenseMatrix::randn(n, l, rng);
    let mut y = ops::matmul_dense(a, &g); // m×l
    let (mut q, _) = qr_thin(&y);
    for _ in 0..power_iters {
        let z = ops::matmul_transpose_dense(a, &q); // n×l
        let (qz, _) = qr_thin(&z);
        y = ops::matmul_dense(a, &qz); // m×l
        let (qy, _) = qr_thin(&y);
        q = qy;
    }

    // Projected matrix B = Qᵀ A  (l×n): small eigenproblem on B Bᵀ (l×l).
    let bt = ops::matmul_transpose_dense(a, &q); // n×l == Bᵀ
    let mut bbt: Vec<f64> = {
        let g = matmul_at_b(&bt, &bt); // l×l = B·Bᵀ
        g.data().iter().map(|&x| x as f64).collect()
    };
    let (evals, evecs) = jacobi_eigh(&mut bbt, l);

    // Order by decreasing eigenvalue, keep top-k.
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&i, &j| evals[j].partial_cmp(&evals[i]).unwrap());
    let order = &order[..k];

    let mut s = Vec::with_capacity(k);
    let mut w = DenseMatrix::zeros(l, k); // eigenvectors of BBᵀ, top-k as columns
    for (col, &idx) in order.iter().enumerate() {
        s.push(evals[idx].max(0.0).sqrt() as f32);
        for i in 0..l {
            w.set(i, col, evecs[i * l + idx] as f32);
        }
    }

    // U = Q·W (m×k); V = Bᵀ·W·Σ⁻¹ (n×k).
    let u = matmul(&q, &w);
    let mut v = matmul(&bt, &w);
    for j in 0..k {
        let inv = if s[j] > 1e-12 { 1.0 / s[j] } else { 0.0 };
        for i in 0..n {
            v.set(i, j, v.get(i, j) * inv);
        }
    }
    SvdResult { u, s, v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_defect;

    /// Build a rank-r matrix with known singular values.
    fn low_rank(m: usize, n: usize, sigmas: &[f32], rng: &mut Xoshiro256) -> DenseMatrix {
        let r = sigmas.len();
        let (qu, _) = qr_thin(&DenseMatrix::randn(m, r, rng));
        let (qv, _) = qr_thin(&DenseMatrix::randn(n, r, rng));
        let mut scaled = qu.clone();
        for j in 0..r {
            for i in 0..m {
                scaled.set(i, j, scaled.get(i, j) * sigmas[j]);
            }
        }
        matmul(&scaled, &qv.transpose())
    }

    #[test]
    fn recovers_singular_values() {
        let mut rng = Xoshiro256::seed_from(61);
        let a = low_rank(60, 45, &[10.0, 5.0, 2.0, 1.0], &mut rng);
        let out = randomized_svd(&Matrix::Dense(a), 4, 6, 3, &mut rng);
        let want = [10.0, 5.0, 2.0, 1.0];
        for (got, want) in out.s.iter().zip(want) {
            assert!((got - want).abs() < 0.05, "got {got} want {want}");
        }
    }

    #[test]
    fn factors_are_orthonormal() {
        let mut rng = Xoshiro256::seed_from(62);
        let a = low_rank(80, 50, &[8.0, 4.0, 2.0], &mut rng);
        let out = randomized_svd(&Matrix::Dense(a), 3, 5, 3, &mut rng);
        assert!(orthonormality_defect(&out.u) < 1e-3);
        assert!(orthonormality_defect(&out.v) < 1e-3);
    }

    #[test]
    fn reconstruction_error_small_for_exact_rank() {
        let mut rng = Xoshiro256::seed_from(63);
        let a = low_rank(50, 40, &[6.0, 3.0], &mut rng);
        let out = randomized_svd(&Matrix::Dense(a.clone()), 2, 6, 3, &mut rng);
        // A ≈ U Σ Vᵀ
        let mut us = out.u.clone();
        for j in 0..2 {
            for i in 0..50 {
                us.set(i, j, us.get(i, j) * out.s[j]);
            }
        }
        let back = matmul(&us, &out.v.transpose());
        let err = back.max_abs_diff(&a);
        assert!(err < 1e-2, "reconstruction err {err}");
    }

    #[test]
    fn sparse_and_dense_agree() {
        let mut rng = Xoshiro256::seed_from(64);
        let a = low_rank(40, 30, &[5.0, 2.5, 1.0], &mut rng);
        let s = crate::matrix::CsrMatrix::from_dense(&a);
        let mut rng1 = Xoshiro256::seed_from(99);
        let mut rng2 = Xoshiro256::seed_from(99);
        let out_d = randomized_svd(&Matrix::Dense(a), 3, 5, 3, &mut rng1);
        let out_s = randomized_svd(&Matrix::Sparse(s), 3, 5, 3, &mut rng2);
        for j in 0..3 {
            assert!((out_d.s[j] - out_s.s[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn handles_k_larger_than_rank() {
        let mut rng = Xoshiro256::seed_from(65);
        let a = low_rank(30, 30, &[4.0], &mut rng);
        let out = randomized_svd(&Matrix::Dense(a), 3, 4, 2, &mut rng);
        assert!((out.s[0] - 4.0).abs() < 0.05);
        assert!(out.s[1] < 0.05);
        assert!(out.s.iter().all(|x| x.is_finite()));
    }
}
