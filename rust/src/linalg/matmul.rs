//! Blocked, multi-threaded dense GEMM.
//!
//! `C = A·B` with i-k-j loop order (streams B rows, accumulates into a
//! C row tile held in cache) plus row-band threading via `std::thread::scope`.
//! This is the native-route hot path for dense workloads; the PJRT route
//! offloads the same contraction to the compiled XLA artifact instead.

use crate::matrix::DenseMatrix;

/// Rows per parallel band. Bands are independent, so scoped threads write
/// disjoint slices of C without synchronization.
const BAND: usize = 64;

/// Parse a `LAMC_THREADS` value: a positive integer (0 clamps to 1),
/// `None` for anything unparsable.
fn parse_threads(raw: &str) -> Option<usize> {
    raw.trim().parse::<usize>().ok().map(|n| n.max(1))
}

/// Number of worker threads for the linalg layer. Defaults to available
/// parallelism, clamped to 8 (diminishing returns on this memory-bound
/// kernel beyond that), overridable via `LAMC_THREADS`.
///
/// Resolved **once** per process: this sits on the per-GEMM hot path,
/// where re-reading and re-parsing the environment on every call was
/// measurable overhead — and an unparsable value was silently ignored.
/// Now it warns once (same pattern as `LAMC_LOG`) and falls back to
/// auto.
pub fn matmul_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(s) = std::env::var("LAMC_THREADS") {
            if let Some(n) = parse_threads(&s) {
                return n;
            }
            // Init runs once, so this warning cannot repeat.
            eprintln!(
                "lamc: unrecognized LAMC_THREADS='{s}' (want a positive integer); using auto"
            );
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
    })
}

/// Single-band kernel: C[band] += A[band] · B with a K-blocked i-k-j
/// order: the active B panel (KT rows of B) stays cache-resident across
/// the whole row band instead of being evicted between consecutive A
/// rows (perf log: EXPERIMENTS.md §Perf L3-1).
fn gemm_band(a_band: &[f32], b: &DenseMatrix, c_band: &mut [f32], k_dim: usize, n_dim: usize) {
    const KT: usize = 256; // B panel: 256 rows × N cols (≈1 MB at N=1024)
    let rows = a_band.len() / k_dim;
    for kb in (0..k_dim).step_by(KT) {
        let k_hi = (kb + KT).min(k_dim);
        for i in 0..rows {
            let a_row = &a_band[i * k_dim + kb..i * k_dim + k_hi];
            let c_row = &mut c_band[i * n_dim..(i + 1) * n_dim];
            for (dk, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue; // free sparsity win on padded blocks
                }
                let b_row = b.row(kb + dk);
                // Autovectorizes: contiguous fused multiply-adds.
                for j in 0..n_dim {
                    c_row[j] += aik * b_row[j];
                }
            }
        }
    }
}

/// `C = A · B`.
pub fn matmul(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch: {}x{} · {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = DenseMatrix::zeros(m, n);
    let threads = matmul_threads();
    // Small problems: skip thread setup.
    if m * k * n < 64 * 64 * 64 || threads == 1 {
        gemm_band(a.data(), b, c.data_mut(), k, n);
        return c;
    }
    let bands: Vec<(usize, usize)> = (0..m)
        .step_by(BAND)
        .map(|lo| (lo, (lo + BAND).min(m)))
        .collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let c_ptr = SendPtr(c.data_mut().as_mut_ptr());
    std::thread::scope(|scope| {
        for _ in 0..threads.min(bands.len()) {
            let bands = &bands;
            let next = &next;
            let c_ptr = &c_ptr;
            scope.spawn(move || loop {
                let idx = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if idx >= bands.len() {
                    break;
                }
                let (lo, hi) = bands[idx];
                let a_band = &a.data()[lo * k..hi * k];
                // SAFETY: bands are disjoint row ranges of C.
                let c_band = unsafe {
                    std::slice::from_raw_parts_mut(c_ptr.0.add(lo * n), (hi - lo) * n)
                };
                gemm_band(a_band, b, c_band, k, n);
            });
        }
    });
    c
}

/// `C = Aᵀ · B` without materializing Aᵀ (A is m×k ⇒ C is k×n, B m×n).
pub fn matmul_at_b(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    matmul_at_b_with_threads(a, b, matmul_threads())
}

fn matmul_at_b_with_threads(a: &DenseMatrix, b: &DenseMatrix, threads: usize) -> DenseMatrix {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if m * k * n < 64 * 64 * 64 || threads == 1 {
        let mut c = DenseMatrix::zeros(k, n);
        for i in 0..m {
            let a_row = a.row(i);
            let b_row = b.row(i);
            for (t, &ait) in a_row.iter().enumerate() {
                if ait == 0.0 {
                    continue;
                }
                let c_row = c.row_mut(t);
                for j in 0..n {
                    c_row[j] += ait * b_row[j];
                }
            }
        }
        return c;
    }
    // Parallelize over input row bands with per-thread accumulators, then
    // reduce. k and n are small (sketch widths) in our workloads, so the
    // accumulator copies are cheap relative to streaming A.
    let bands: Vec<(usize, usize)> = (0..m)
        .step_by(BAND * 4)
        .map(|lo| (lo, (lo + BAND * 4).min(m)))
        .collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let locals = std::sync::Mutex::new(Vec::with_capacity(threads));
    std::thread::scope(|scope| {
        for _ in 0..threads.min(bands.len()) {
            let bands = &bands;
            let next = &next;
            let locals = &locals;
            scope.spawn(move || {
                let mut local = DenseMatrix::zeros(k, n);
                loop {
                    let idx = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if idx >= bands.len() {
                        break;
                    }
                    let (lo, hi) = bands[idx];
                    for i in lo..hi {
                        let a_row = a.row(i);
                        let b_row = b.row(i);
                        for (t, &ait) in a_row.iter().enumerate() {
                            if ait == 0.0 {
                                continue;
                            }
                            let c_row = local.row_mut(t);
                            for j in 0..n {
                                c_row[j] += ait * b_row[j];
                            }
                        }
                    }
                }
                // One push per thread — the lock is held for a Vec
                // append, never for a k×n add.
                locals.lock().unwrap().push(local);
            });
        }
    });
    // Reduce the per-thread partials over disjoint row stripes of C in
    // parallel, instead of the old serial element-wise adds under one
    // mutex (each thread blocked on the lock while another added its
    // whole k×n accumulator).
    let locals = locals.into_inner().unwrap();
    let mut c = DenseMatrix::zeros(k, n);
    let reducers = threads.min(locals.len().max(1)).min(k.max(1));
    let stripe = k.div_ceil(reducers.max(1));
    let c_ptr = SendPtr(c.data_mut().as_mut_ptr());
    std::thread::scope(|scope| {
        for t in 0..reducers {
            let locals = &locals;
            let c_ptr = &c_ptr;
            scope.spawn(move || {
                let lo = t * stripe;
                let hi = ((t + 1) * stripe).min(k);
                if lo >= hi {
                    return;
                }
                // SAFETY: stripes are disjoint row ranges of C.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut(c_ptr.0.add(lo * n), (hi - lo) * n)
                };
                for local in locals {
                    for (d, s) in dst.iter_mut().zip(&local.data()[lo * n..hi * n]) {
                        *d += s;
                    }
                }
            });
        }
    });
    c
}

/// Raw mutable pointer wrapper that is Sync for scoped disjoint writes.
struct SendPtr(*mut f32);
unsafe impl Sync for SendPtr {}
unsafe impl Send for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn naive(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
        let mut c = DenseMatrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0.0f64;
                for k in 0..a.cols() {
                    acc += a.get(i, k) as f64 * b.get(k, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn small_matches_naive() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = DenseMatrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        assert_eq!(matmul(&a, &b).data(), naive(&a, &b).data());
    }

    #[test]
    fn random_rect_matches_naive() {
        let mut rng = Xoshiro256::seed_from(41);
        let a = DenseMatrix::randn(33, 47, &mut rng);
        let b = DenseMatrix::randn(47, 29, &mut rng);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
    }

    #[test]
    fn large_threaded_matches_naive() {
        let mut rng = Xoshiro256::seed_from(42);
        let a = DenseMatrix::randn(150, 120, &mut rng);
        let b = DenseMatrix::randn(120, 90, &mut rng);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-2);
    }

    #[test]
    fn at_b_matches_transpose_then_mul() {
        let mut rng = Xoshiro256::seed_from(43);
        let a = DenseMatrix::randn(70, 20, &mut rng);
        let b = DenseMatrix::randn(70, 15, &mut rng);
        let fast = matmul_at_b(&a, &b);
        let slow = matmul(&a.transpose(), &b);
        assert!(fast.max_abs_diff(&slow) < 1e-3);
    }

    #[test]
    fn at_b_threaded_path() {
        let mut rng = Xoshiro256::seed_from(44);
        let a = DenseMatrix::randn(600, 32, &mut rng);
        let b = DenseMatrix::randn(600, 24, &mut rng);
        let fast = matmul_at_b(&a, &b);
        let slow = matmul(&a.transpose(), &b);
        assert!(fast.max_abs_diff(&slow) < 1e-2);
    }

    #[test]
    fn at_b_striped_reduction_matches_oracle_at_every_thread_count() {
        // The parallel stripe reduction must agree with the transpose-
        // then-mul oracle whatever the pool size — including counts
        // that leave reducer stripes empty (threads > k).
        let mut rng = Xoshiro256::seed_from(46);
        let a = DenseMatrix::randn(700, 21, &mut rng);
        let b = DenseMatrix::randn(700, 17, &mut rng);
        let slow = matmul(&a.transpose(), &b);
        for threads in [1, 4, 8] {
            let fast = matmul_at_b_with_threads(&a, &b, threads);
            assert!(
                fast.max_abs_diff(&slow) < 1e-2,
                "threads={threads} diverged from the oracle"
            );
        }
    }

    #[test]
    fn parse_threads_accepts_integers_and_rejects_junk() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 6 "), Some(6), "surrounding whitespace is fine");
        assert_eq!(parse_threads("0"), Some(1), "zero clamps to one thread");
        assert_eq!(parse_threads("banana"), None);
        assert_eq!(parse_threads("-2"), None);
        assert_eq!(parse_threads(""), None);
    }

    #[test]
    fn matmul_threads_is_cached_and_positive() {
        let first = matmul_threads();
        assert!(first >= 1);
        // OnceLock: the resolved count never changes within a process.
        assert_eq!(matmul_threads(), first);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Xoshiro256::seed_from(45);
        let a = DenseMatrix::randn(20, 20, &mut rng);
        let e = DenseMatrix::eye(20);
        assert!(matmul(&a, &e).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&e, &a).max_abs_diff(&a) < 1e-6);
    }
}
