//! Dense linear algebra substrate.
//!
//! The offline image carries no BLAS/LAPACK bindings, so LAMC implements
//! the operations its algorithms need: a blocked, multi-threaded GEMM,
//! Householder QR, and a randomized truncated SVD built on subspace
//! iteration (Halko–Martinsson–Tropp). Everything accumulates in `f32`
//! with blocked summation, which is adequate for the spectral embeddings
//! used here (verified against f64 oracles in the test suites).

pub mod jacobi_svd;
pub mod matmul;
pub mod qr;
pub mod svd;

pub use jacobi_svd::jacobi_svd;
pub use matmul::{matmul, matmul_at_b, matmul_threads};
pub use qr::qr_thin;
pub use svd::{randomized_svd, SvdResult};

/// Euclidean norm of a vector slice (f64 accumulation).
pub fn norm2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Dot product with f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_and_dot() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]) - 32.0).abs() < 1e-12);
    }
}
