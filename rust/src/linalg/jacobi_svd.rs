//! Exact SVD via one-sided Jacobi rotations.
//!
//! This is the *baseline-faithful* factorization: classical spectral
//! co-clustering (Dhillon 2001, as benchmarked in the paper's Table II)
//! computes a full exact SVD of the normalized matrix, whose
//! `O(M·N·min(M,N))`-per-sweep cost is precisely why full-matrix SCC
//! cannot scale and why LAMC partitions. The production path uses
//! [`super::svd::randomized_svd`]; this exact path exists so the
//! benches compare against the method the paper actually measured.

use crate::matrix::DenseMatrix;

use super::svd::SvdResult;

/// Exact thin SVD of `a` (m×n). Returns all `min(m,n)` triplets ordered
/// by decreasing singular value. For `m < n` the transpose is factored
/// and factors are swapped back.
pub fn jacobi_svd(a: &DenseMatrix, max_sweeps: usize, tol: f64) -> SvdResult {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        let t = jacobi_svd(&a.transpose(), max_sweeps, tol);
        return SvdResult { u: t.v, s: t.s, v: t.u };
    }
    // Work on columns of W = A (f64), rotating pairs until orthogonal:
    // afterwards W = U Σ and V accumulates the rotations.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for j in 0..n {
        v[j * n + j] = 1.0;
    }

    let col_dot = |w: &[f64], p: usize, q: usize| -> f64 {
        let mut acc = 0.0;
        for i in 0..m {
            acc += w[i * n + p] * w[i * n + q];
        }
        acc
    };

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = col_dot(&w, p, q);
                let app = col_dot(&w, p, p);
                let aqq = col_dot(&w, q, q);
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wip = w[i * n + p];
                    let wiq = w[i * n + q];
                    w[i * n + p] = c * wip - s * wiq;
                    w[i * n + q] = s * wip + c * wiq;
                }
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
        if off < tol {
            break;
        }
    }

    // Extract Σ (column norms), U (normalized columns), sort descending.
    let mut sigma: Vec<f64> = (0..n).map(|j| col_dot(&w, j, j).sqrt()).collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| sigma[j].partial_cmp(&sigma[i]).unwrap());
    sigma = order.iter().map(|&j| sigma[j]).collect();

    let mut u = DenseMatrix::zeros(m, n);
    let mut vv = DenseMatrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        let s = sigma[new_j];
        let inv = if s > 1e-300 { 1.0 / s } else { 0.0 };
        for i in 0..m {
            u.set(i, new_j, (w[i * n + old_j] * inv) as f32);
        }
        for i in 0..n {
            vv.set(i, new_j, v[i * n + old_j] as f32);
        }
    }
    SvdResult { u, s: sigma.iter().map(|&x| x as f32).collect(), v: vv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::linalg::qr::orthonormality_defect;
    use crate::rng::Xoshiro256;

    #[test]
    fn reconstructs_random_matrix() {
        let mut rng = Xoshiro256::seed_from(901);
        let a = DenseMatrix::randn(20, 12, &mut rng);
        let svd = jacobi_svd(&a, 30, 1e-12);
        let mut us = svd.u.clone();
        for j in 0..12 {
            for i in 0..20 {
                us.set(i, j, us.get(i, j) * svd.s[j]);
            }
        }
        let back = matmul(&us, &svd.v.transpose());
        assert!(back.max_abs_diff(&a) < 1e-4, "err {}", back.max_abs_diff(&a));
    }

    #[test]
    fn factors_orthonormal() {
        let mut rng = Xoshiro256::seed_from(902);
        let a = DenseMatrix::randn(30, 10, &mut rng);
        let svd = jacobi_svd(&a, 30, 1e-12);
        assert!(orthonormality_defect(&svd.u) < 1e-5);
        assert!(orthonormality_defect(&svd.v) < 1e-5);
    }

    #[test]
    fn singular_values_sorted_and_match_randomized() {
        let mut rng = Xoshiro256::seed_from(903);
        let a = DenseMatrix::randn(40, 15, &mut rng);
        let exact = jacobi_svd(&a, 40, 1e-12);
        assert!(exact.s.windows(2).all(|w| w[0] >= w[1]));
        let rnd = crate::linalg::randomized_svd(
            &crate::matrix::Matrix::Dense(a),
            5,
            8,
            4,
            &mut rng,
        );
        for j in 0..5 {
            assert!((exact.s[j] - rnd.s[j]).abs() < 0.05, "σ{j}: {} vs {}", exact.s[j], rnd.s[j]);
        }
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let mut rng = Xoshiro256::seed_from(904);
        let a = DenseMatrix::randn(8, 25, &mut rng);
        let svd = jacobi_svd(&a, 30, 1e-12);
        assert_eq!(svd.u.rows(), 8);
        assert_eq!(svd.v.rows(), 25);
        assert!(orthonormality_defect(&svd.u) < 1e-5);
    }

    #[test]
    fn known_diagonal_case() {
        let a = DenseMatrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0], vec![0.0, 0.0]]);
        let svd = jacobi_svd(&a, 20, 1e-14);
        assert!((svd.s[0] - 4.0).abs() < 1e-6);
        assert!((svd.s[1] - 3.0).abs() < 1e-6);
    }
}
