//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry in this image does not carry the `rand`
//! facade, so LAMC ships its own small, well-tested PRNG stack:
//! [`SplitMix64`] for seeding and [`Xoshiro256`] (xoshiro256**) as the
//! workhorse generator, plus the handful of distributions the library
//! needs (uniform ints/floats, standard normal, shuffles, sampling
//! without replacement).
//!
//! Every stochastic component in LAMC (partition sampler, k-means init,
//! synthetic data generators, property tests) takes an explicit seed so
//! experiments are reproducible end to end.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014). Passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Mix one word into a running SplitMix64 hash chain. This is the
/// shared primitive behind content fingerprints and canonical config
/// hashes (`Matrix::fingerprint`, the service cache key): any change to
/// the absorption scheme must happen here so the two halves of a cache
/// key can never silently diverge.
#[inline]
pub fn mix64(state: u64, word: u64) -> u64 {
    SplitMix64::new(state ^ word).next_u64()
}

/// Hash a string into the chain, length-prefixed so adjacent fields
/// cannot alias (`"ab" + "c"` vs `"a" + "bc"`).
pub fn mix64_str(state: u64, s: &str) -> u64 {
    let mut h = mix64(state, s.len() as u64);
    for b in s.as_bytes() {
        h = mix64(h, *b as u64);
    }
    h
}

/// xoshiro256** — fast, high-quality 256-bit-state generator.
///
/// Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
/// generators" (TOMS 2021).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the authors' recommendation (never all-zero).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Self {
        Self::seed_from(self.next_u64() ^ 0xA3EC647659359ACD)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "next_below bound must be positive");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as usize;
            }
            // Rejection zone for exact uniformity.
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (polar form avoided: trig is fine here).
    pub fn next_normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.next_range(i, n - 1);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Weighted index sampling proportional to non-negative `weights`.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.next_below(weights.len());
        }
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // First output for seed 0 is the finalizer of 0x9E3779B97F4A7C15.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
    }

    #[test]
    fn mix64_chain_separates_inputs() {
        assert_eq!(mix64(1, 2), mix64(1, 2), "deterministic");
        assert_ne!(mix64(1, 2), mix64(2, 1), "order matters");
        assert_ne!(mix64_str(0, "ab"), mix64_str(0, "a"), "length-prefixed");
        // Field boundaries cannot alias.
        assert_ne!(mix64_str(mix64_str(0, "ab"), "c"), mix64_str(mix64_str(0, "a"), "bc"));
    }

    #[test]
    fn xoshiro_streams_differ_by_seed() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_centered() {
        let mut r = Xoshiro256::seed_from(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.next_below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Xoshiro256::seed_from(7);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from(8);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut r = Xoshiro256::seed_from(9);
        let w = [0.0, 10.0, 0.0, 1.0];
        let mut counts = [0usize; 4];
        for _ in 0..11_000 {
            counts[r.sample_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        assert!(counts[1] > 8 * counts[3], "{counts:?}");
    }

    #[test]
    fn split_gives_independent_streams() {
        let mut parent = Xoshiro256::seed_from(10);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
