//! Spectral Co-Clustering (Dhillon, KDD 2001).
//!
//! Steps (paper §IV-C.2): normalize `A_n = D1^{-1/2} A D2^{-1/2}`, take
//! the `l = ⌈log2 k⌉ + 1`-ish top singular subspace (skipping the trivial
//! first pair), form `Z = [D1^{-1/2} Û ; D2^{-1/2} V̂]`, and k-means the
//! rows of `Z`. Rows land in row clusters, columns in column clusters,
//! from the same k-means run — that coupling is what makes it a
//! *co*-clustering.
//!
//! This is the native (pure-Rust) route; the PJRT route executes the
//! same computation from the AOT-compiled JAX artifact (see
//! `python/compile/model.py` and the `runtime` module behind the
//! `pjrt` cargo feature).

use crate::matrix::{ops, Matrix};
use crate::linalg::randomized_svd;
use crate::rng::Xoshiro256;

use super::kmeans::{kmeans, KmeansConfig};
use super::{AtomCocluster, CoclusterResult};

/// Which SVD backs the spectral embedding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvdMethod {
    /// Randomized subspace iteration (production default — near-linear).
    Randomized,
    /// Exact one-sided Jacobi SVD. This is what classical SCC (the
    /// paper's baseline) pays for: `O(M·N·min(M,N))` per sweep. Used by
    /// the Table II benches to reproduce the baseline's scaling wall.
    ExactJacobi,
}

#[derive(Clone, Debug)]
pub struct SpectralConfig {
    /// Singular vectors kept for the embedding (excluding the trivial
    /// first pair). 0 = auto: `ceil(log2 k)` per Dhillon, min 2.
    pub embed_dim: usize,
    /// Randomized-SVD oversampling.
    pub oversample: usize,
    /// Randomized-SVD power iterations.
    pub power_iters: usize,
    pub svd: SvdMethod,
    pub kmeans: KmeansConfig,
}

impl Default for SpectralConfig {
    fn default() -> Self {
        Self {
            embed_dim: 0,
            oversample: 6,
            power_iters: 3,
            svd: SvdMethod::Randomized,
            kmeans: KmeansConfig::default(),
        }
    }
}

impl SpectralConfig {
    /// Paper-faithful classical SCC (exact SVD).
    pub fn exact() -> Self {
        Self { svd: SvdMethod::ExactJacobi, ..Default::default() }
    }
}

/// Spectral co-clusterer over either storage format.
#[derive(Clone, Debug, Default)]
pub struct SpectralCocluster {
    pub config: SpectralConfig,
}

impl SpectralCocluster {
    pub fn new(config: SpectralConfig) -> Self {
        Self { config }
    }

    fn effective_dim(&self, k: usize, m: usize, n: usize) -> usize {
        let auto = ((k as f64).log2().ceil() as usize).max(2);
        let want = if self.config.embed_dim == 0 { auto } else { self.config.embed_dim };
        want.min(m.min(n).saturating_sub(1)).max(1)
    }
}

impl AtomCocluster for SpectralCocluster {
    fn name(&self) -> &'static str {
        "scc"
    }

    /// Run SCC. Degenerate inputs (all-zero, tiny) fall back to
    /// single-cluster labelings rather than panicking — partition blocks
    /// can legitimately be empty under aggressive sparsity.
    fn cocluster(&self, a: &Matrix, k: usize, rng: &mut Xoshiro256) -> CoclusterResult {
        let (m, n) = (a.rows(), a.cols());
        assert!(k >= 1);
        if m == 0 || n == 0 || a.frobenius() < 1e-12 || k == 1 || m.min(n) < 2 {
            return CoclusterResult {
                row_labels: vec![0; m],
                col_labels: vec![0; n],
                k: 1,
                objective: 0.0,
            };
        }
        let l = self.effective_dim(k, m, n);
        let (an, r_scale, c_scale) = ops::bipartite_normalize(a);
        // l+1 to skip the trivial leading pair (σ₁=1, degree vectors).
        let want = (l + 1).min(m.min(n));
        let svd = match self.config.svd {
            crate::cocluster::scc::SvdMethod::Randomized => {
                randomized_svd(&an, want, self.config.oversample, self.config.power_iters, rng)
            }
            crate::cocluster::scc::SvdMethod::ExactJacobi => {
                // Classical SCC densifies the normalized matrix and pays
                // for the full factorization — the paper's baseline cost.
                let full = crate::linalg::jacobi_svd(&an.to_dense(), 30, 1e-10);
                let mut u = crate::matrix::DenseMatrix::zeros(m, want);
                let mut v = crate::matrix::DenseMatrix::zeros(n, want);
                for j in 0..want {
                    for i in 0..m {
                        u.set(i, j, full.u.get(i, j));
                    }
                    for i in 0..n {
                        v.set(i, j, full.v.get(i, j));
                    }
                }
                crate::linalg::SvdResult { u, s: full.s[..want].to_vec(), v }
            }
        };

        // Drop the first singular pair, rescale by D^{-1/2}.
        let kept = svd.s.len() - 1;
        let mut z = crate::matrix::DenseMatrix::zeros(m + n, kept.max(1));
        for i in 0..m {
            for j in 0..kept {
                z.set(i, j, svd.u.get(i, j + 1) * r_scale[i]);
            }
        }
        for i in 0..n {
            for j in 0..kept {
                z.set(m + i, j, svd.v.get(i, j + 1) * c_scale[i]);
            }
        }

        let k_eff = k.min(m + n);
        let km = kmeans(&z, &KmeansConfig { k: k_eff, ..self.config.kmeans.clone() }, rng);
        CoclusterResult {
            row_labels: km.labels[..m].to_vec(),
            col_labels: km.labels[m..].to_vec(),
            k: k_eff,
            objective: km.inertia,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{planted_dense, planted_sparse, PlantedConfig};
    use crate::metrics::score_coclustering;

    #[test]
    fn recovers_planted_dense_coclusters() {
        let cfg = PlantedConfig { rows: 160, cols: 140, row_clusters: 3, col_clusters: 3, noise: 0.15, signal: 1.5, seed: 101, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut rng = Xoshiro256::seed_from(11);
        let out = SpectralCocluster::default().cocluster(&ds.matrix, 3, &mut rng);
        out.validate(160, 140).unwrap();
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        assert!(s.nmi() > 0.9, "nmi {}", s.nmi());
        assert!(s.ari() > 0.85, "ari {}", s.ari());
    }

    #[test]
    fn recovers_planted_sparse_coclusters() {
        let cfg = PlantedConfig { rows: 400, cols: 300, row_clusters: 4, col_clusters: 4, density: 0.06, signal: 3.0, seed: 102, ..Default::default() };
        let ds = planted_sparse(&cfg);
        let mut rng = Xoshiro256::seed_from(12);
        let out = SpectralCocluster::default().cocluster(&ds.matrix, 4, &mut rng);
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        assert!(s.nmi() > 0.7, "nmi {}", s.nmi());
    }

    #[test]
    fn degenerate_zero_matrix_single_cluster() {
        let a = Matrix::Dense(crate::matrix::DenseMatrix::zeros(5, 4));
        let mut rng = Xoshiro256::seed_from(13);
        let out = SpectralCocluster::default().cocluster(&a, 3, &mut rng);
        assert_eq!(out.k, 1);
        assert_eq!(out.row_labels, vec![0; 5]);
        assert_eq!(out.col_labels, vec![0; 4]);
    }

    #[test]
    fn k_one_short_circuits() {
        let cfg = PlantedConfig { rows: 20, cols: 20, seed: 103, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut rng = Xoshiro256::seed_from(14);
        let out = SpectralCocluster::default().cocluster(&ds.matrix, 1, &mut rng);
        assert_eq!(out.k, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = PlantedConfig { rows: 60, cols: 50, seed: 104, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut r1 = Xoshiro256::seed_from(15);
        let mut r2 = Xoshiro256::seed_from(15);
        let a = SpectralCocluster::default().cocluster(&ds.matrix, 4, &mut r1);
        let b = SpectralCocluster::default().cocluster(&ds.matrix, 4, &mut r2);
        assert_eq!(a.row_labels, b.row_labels);
        assert_eq!(a.col_labels, b.col_labels);
    }

    #[test]
    fn embed_dim_auto_scales_with_k() {
        let scc = SpectralCocluster::default();
        assert_eq!(scc.effective_dim(2, 100, 100), 2);
        assert_eq!(scc.effective_dim(8, 100, 100), 3);
        assert_eq!(scc.effective_dim(16, 100, 100), 4);
        // Clamped by matrix size.
        assert_eq!(scc.effective_dim(8, 3, 100), 2);
    }
}
