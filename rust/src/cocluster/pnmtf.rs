//! Parallel Non-negative Matrix Tri-Factorization (PNMTF).
//!
//! Baseline + second atom method (paper §V, Chen et al. TKDE 2023
//! style). Factorizes `A ≈ R·S·Cᵀ` with `R ∈ ℝ₊^{M×k}` (row cluster
//! indicators), `S ∈ ℝ₊^{k×d}` (block value matrix), `C ∈ ℝ₊^{N×d}`
//! (column cluster indicators) by multiplicative updates (Long et al.
//! 2005, "block value decomposition"), with the dominant contractions
//! running on the threaded GEMM — that is the "parallel" in PNMTF.
//! Labels are the argmax row of each indicator.

use crate::linalg::matmul::{matmul, matmul_at_b};
use crate::matrix::{ops, DenseMatrix, Matrix};
use crate::rng::Xoshiro256;

use super::{AtomCocluster, CoclusterResult};

#[derive(Clone, Debug)]
pub struct PnmtfConfig {
    pub max_iters: usize,
    /// Stop when relative reconstruction-error improvement < tol.
    pub tol: f64,
    /// Column cluster count; 0 = same as row cluster count `k`.
    pub col_clusters: usize,
    /// Independent restarts; best reconstruction error wins
    /// (multiplicative updates are sensitive to initialization).
    pub restarts: usize,
    /// Iterations before the tol-based early stop may fire
    /// (multiplicative updates often plateau briefly at the start).
    pub min_iters: usize,
}

impl Default for PnmtfConfig {
    fn default() -> Self {
        Self { max_iters: 60, tol: 1e-5, col_clusters: 0, restarts: 3, min_iters: 20 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Pnmtf {
    pub config: PnmtfConfig,
}

impl Pnmtf {
    pub fn new(config: PnmtfConfig) -> Self {
        Self { config }
    }
}

const EPS: f32 = 1e-9;

/// Elementwise multiplicative update `x ← x · num / den`.
fn mult_update(x: &mut DenseMatrix, num: &DenseMatrix, den: &DenseMatrix) {
    for ((x, &n), &d) in x.data_mut().iter_mut().zip(num.data()).zip(den.data()) {
        *x *= n / (d + EPS);
        if !x.is_finite() {
            *x = EPS;
        }
    }
}

fn argmax_rows(x: &DenseMatrix) -> Vec<usize> {
    (0..x.rows())
        .map(|i| {
            let row = x.row(i);
            let mut best = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

impl AtomCocluster for Pnmtf {
    fn name(&self) -> &'static str {
        "pnmtf"
    }

    fn cocluster(&self, a: &Matrix, k: usize, rng: &mut Xoshiro256) -> CoclusterResult {
        let (m, n) = (a.rows(), a.cols());
        if m == 0 || n == 0 || k == 1 || a.frobenius() < 1e-12 {
            return CoclusterResult { row_labels: vec![0; m], col_labels: vec![0; n], k: 1, objective: 0.0 };
        }
        let mut best: Option<CoclusterResult> = None;
        for _ in 0..self.config.restarts.max(1) {
            let run = self.factorize_once(a, k, rng);
            if best.as_ref().map_or(true, |b| run.objective < b.objective) {
                best = Some(run);
            }
        }
        best.unwrap()
    }
}

impl Pnmtf {
    /// One multiplicative-update run from a fresh random init.
    fn factorize_once(&self, a: &Matrix, k: usize, rng: &mut Xoshiro256) -> CoclusterResult {
        let (m, n) = (a.rows(), a.cols());
        let d = if self.config.col_clusters == 0 { k } else { self.config.col_clusters };
        // Non-negative random init scaled to the data magnitude.
        let scale = (a.frobenius() / ((m * n) as f64).sqrt()).sqrt().max(1e-6) as f32;
        let mut r = DenseMatrix::zeros(m, k);
        let mut s = DenseMatrix::zeros(k, d);
        let mut c = DenseMatrix::zeros(n, d);
        for x in r.data_mut() {
            *x = scale * (0.5 + rng.next_f32());
        }
        for x in s.data_mut() {
            *x = 0.5 + rng.next_f32();
        }
        for x in c.data_mut() {
            *x = scale * (0.5 + rng.next_f32());
        }

        let a_fro2 = a.frobenius().powi(2);
        let mut prev_err = f64::INFINITY;
        let mut objective = f64::INFINITY;
        for it in 0..self.config.max_iters {
            // R ← R ∘ (A·C·Sᵀ) / (R·S·Cᵀ·C·Sᵀ)
            let cs_t = matmul(&c, &s.transpose()); // n×k
            let num_r = ops::matmul_dense(a, &cs_t); // m×k
            let ct_c = matmul_at_b(&c, &c); // d×d
            let s_ctc_st = matmul(&matmul(&s, &ct_c), &s.transpose()); // k×k
            let den_r = matmul(&r, &s_ctc_st); // m×k
            mult_update(&mut r, &num_r, &den_r);

            // C ← C ∘ (Aᵀ·R·S) / (C·Sᵀ·Rᵀ·R·S)
            let rs = matmul(&r, &s); // m×d
            let num_c = ops::matmul_transpose_dense(a, &rs); // n×d
            let rt_r = matmul_at_b(&r, &r); // k×k
            let st_rtr_s = matmul(&matmul(&s.transpose(), &rt_r), &s); // d×d
            let den_c = matmul(&c, &st_rtr_s); // n×d
            mult_update(&mut c, &num_c, &den_c);

            // S ← S ∘ (Rᵀ·A·C) / (Rᵀ·R·S·Cᵀ·C)
            let a_c = ops::matmul_dense(a, &c); // m×d
            let num_s = matmul_at_b(&r, &a_c); // k×d
            let rt_r = matmul_at_b(&r, &r);
            let ct_c = matmul_at_b(&c, &c);
            let den_s = matmul(&matmul(&rt_r, &s), &ct_c); // k×d
            mult_update(&mut s, &num_s, &den_s);

            // ‖A - RSCᵀ‖² = ‖A‖² - 2⟨A, RSCᵀ⟩ + ‖RSCᵀ‖², computed without
            // materializing the m×n reconstruction.
            let rs = matmul(&r, &s); // m×d
            let at_rs = ops::matmul_transpose_dense(a, &rs); // n×d
            let cross: f64 = at_rs.data().iter().zip(c.data()).map(|(&x, &y)| x as f64 * y as f64).sum();
            let ct_c = matmul_at_b(&c, &c); // d×d
            let rs_t_rs = matmul_at_b(&rs, &rs); // d×d
            let recon2: f64 = rs_t_rs.data().iter().zip(ct_c.data()).map(|(&x, &y)| x as f64 * y as f64).sum();
            let err = (a_fro2 - 2.0 * cross + recon2).max(0.0);
            objective = err;
            if it + 1 >= self.config.min_iters
                && prev_err.is_finite()
                && (prev_err - err).abs() <= self.config.tol * prev_err.max(1e-30)
            {
                break;
            }
            prev_err = err;
        }

        // Weight indicators by factor scale before argmax (standard NMTF
        // label extraction: column norms of S fold into R/C).
        let row_labels = argmax_rows(&r);
        let col_labels = argmax_rows(&c);
        let k_out = k.max(d);
        CoclusterResult { row_labels, col_labels, k: k_out, objective }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{planted_dense, planted_sparse, PlantedConfig};
    use crate::metrics::score_coclustering;

    #[test]
    fn recovers_planted_dense() {
        let cfg = PlantedConfig { rows: 150, cols: 120, row_clusters: 3, col_clusters: 3, noise: 0.1, signal: 1.5, seed: 201, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut rng = Xoshiro256::seed_from(21);
        let out = Pnmtf::default().cocluster(&ds.matrix, 3, &mut rng);
        out.validate(150, 120).unwrap();
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        assert!(s.nmi() > 0.8, "nmi {}", s.nmi());
    }

    #[test]
    fn recovers_planted_sparse() {
        let cfg = PlantedConfig { rows: 300, cols: 240, row_clusters: 3, col_clusters: 3, density: 0.08, signal: 3.0, seed: 202, ..Default::default() };
        let ds = planted_sparse(&cfg);
        let mut rng = Xoshiro256::seed_from(22);
        let out = Pnmtf::default().cocluster(&ds.matrix, 3, &mut rng);
        let s = score_coclustering(&ds.row_labels, &out.row_labels, &ds.col_labels, &out.col_labels);
        assert!(s.nmi() > 0.55, "nmi {}", s.nmi());
    }

    #[test]
    fn objective_decreases() {
        let cfg = PlantedConfig { rows: 80, cols: 60, seed: 203, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut rng = Xoshiro256::seed_from(23);
        let short = Pnmtf::new(PnmtfConfig { max_iters: 2, tol: 0.0, ..Default::default() })
            .cocluster(&ds.matrix, 4, &mut rng);
        let mut rng = Xoshiro256::seed_from(23);
        let long = Pnmtf::new(PnmtfConfig { max_iters: 40, tol: 0.0, ..Default::default() })
            .cocluster(&ds.matrix, 4, &mut rng);
        assert!(long.objective <= short.objective * 1.001, "short {} long {}", short.objective, long.objective);
    }

    #[test]
    fn factors_stay_finite_and_nonnegative_labels_valid() {
        let cfg = PlantedConfig { rows: 50, cols: 50, noise: 2.0, seed: 204, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut rng = Xoshiro256::seed_from(24);
        let out = Pnmtf::default().cocluster(&ds.matrix, 5, &mut rng);
        out.validate(50, 50).unwrap();
    }

    #[test]
    fn rectangular_cluster_counts() {
        let cfg = PlantedConfig { rows: 90, cols: 70, row_clusters: 4, col_clusters: 2, noise: 0.1, seed: 205, ..Default::default() };
        let ds = planted_dense(&cfg);
        let mut rng = Xoshiro256::seed_from(25);
        let out = Pnmtf::new(PnmtfConfig { col_clusters: 2, ..Default::default() })
            .cocluster(&ds.matrix, 4, &mut rng);
        assert!(out.col_labels.iter().all(|&l| l < 2));
    }

    #[test]
    fn degenerate_input_single_cluster() {
        let a = Matrix::Dense(DenseMatrix::zeros(6, 6));
        let mut rng = Xoshiro256::seed_from(26);
        let out = Pnmtf::default().cocluster(&a, 3, &mut rng);
        assert_eq!(out.k, 1);
    }
}
