//! Lloyd's k-means with k-means++ seeding.
//!
//! Shared by the spectral atom (cluster the stacked embedding `Z`) and
//! the hierarchical merger (cluster residual ids by profile similarity).

use crate::matrix::DenseMatrix;
use crate::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct KmeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when relative inertia improvement drops below this.
    pub tol: f64,
    /// Independent restarts; best inertia wins.
    pub restarts: usize,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        Self { k: 4, max_iters: 50, tol: 1e-6, restarts: 3 }
    }
}

#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub labels: Vec<usize>,
    pub centroids: DenseMatrix,
    pub inertia: f64,
    pub iterations: usize,
}

/// Squared Euclidean distance between a point row and centroid row.
#[inline]
fn sqdist(p: &[f32], c: &[f32]) -> f64 {
    p.iter().zip(c).map(|(&a, &b)| {
        let d = a as f64 - b as f64;
        d * d
    }).sum()
}

/// k-means++ seeding (Arthur & Vassilvitskii 2007).
fn seed_pp(points: &DenseMatrix, k: usize, rng: &mut Xoshiro256) -> DenseMatrix {
    let n = points.rows();
    let dim = points.cols();
    let mut centroids = DenseMatrix::zeros(k, dim);
    let first = rng.next_below(n);
    centroids.row_mut(0).copy_from_slice(points.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sqdist(points.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let idx = rng.sample_weighted(&d2);
        centroids.row_mut(c).copy_from_slice(points.row(idx));
        for i in 0..n {
            let nd = sqdist(points.row(i), centroids.row(c));
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centroids
}

fn lloyd(points: &DenseMatrix, k: usize, cfg: &KmeansConfig, rng: &mut Xoshiro256) -> KmeansResult {
    let n = points.rows();
    let dim = points.cols();
    let mut centroids = seed_pp(points, k, rng);
    let mut labels = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.max_iters {
        iterations = it + 1;
        // Assign.
        let mut new_inertia = 0.0f64;
        for i in 0..n {
            let p = points.row(i);
            let (mut best, mut best_d) = (0usize, f64::INFINITY);
            for c in 0..k {
                let d = sqdist(p, centroids.row(c));
                if d < best_d {
                    best = c;
                    best_d = d;
                }
            }
            labels[i] = best;
            new_inertia += best_d;
        }
        // Update.
        let mut sums = DenseMatrix::zeros(k, dim);
        let mut counts = vec![0usize; k];
        for i in 0..n {
            counts[labels[i]] += 1;
            let src = points.row(i);
            let dst = sums.row_mut(labels[i]);
            for t in 0..dim {
                dst[t] += src[t];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster at the point farthest from its centroid.
                let far = (0..n).max_by(|&a, &b| {
                    sqdist(points.row(a), centroids.row(labels[a]))
                        .partial_cmp(&sqdist(points.row(b), centroids.row(labels[b])))
                        .unwrap()
                }).unwrap();
                centroids.row_mut(c).copy_from_slice(points.row(far));
                continue;
            }
            let inv = 1.0 / counts[c] as f32;
            let src = sums.row(c).to_vec();
            let dst = centroids.row_mut(c);
            for t in 0..dim {
                dst[t] = src[t] * inv;
            }
        }
        // Converged?
        if inertia.is_finite() && (inertia - new_inertia).abs() <= cfg.tol * inertia.max(1e-30) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    KmeansResult { labels, centroids, inertia, iterations }
}

/// Run k-means with restarts; returns the best run by inertia.
pub fn kmeans(points: &DenseMatrix, cfg: &KmeansConfig, rng: &mut Xoshiro256) -> KmeansResult {
    assert!(cfg.k >= 1, "k must be positive");
    assert!(points.rows() >= cfg.k, "need at least k points, got {} for k={}", points.rows(), cfg.k);
    let mut best: Option<KmeansResult> = None;
    for _ in 0..cfg.restarts.max(1) {
        let run = lloyd(points, cfg.k, cfg, rng);
        if best.as_ref().map_or(true, |b| run.inertia < b.inertia) {
            best = Some(run);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(centers: &[(f32, f32)], per: usize, spread: f32, rng: &mut Xoshiro256) -> (DenseMatrix, Vec<usize>) {
        let n = centers.len() * per;
        let mut m = DenseMatrix::zeros(n, 2);
        let mut truth = Vec::with_capacity(n);
        for (c, &(cx, cy)) in centers.iter().enumerate() {
            for i in 0..per {
                let idx = c * per + i;
                m.set(idx, 0, cx + spread * rng.next_normal() as f32);
                m.set(idx, 1, cy + spread * rng.next_normal() as f32);
                truth.push(c);
            }
        }
        (m, truth)
    }

    #[test]
    fn separable_blobs_recovered() {
        let mut rng = Xoshiro256::seed_from(91);
        let (pts, truth) = blobs(&[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 40, 0.4, &mut rng);
        let out = kmeans(&pts, &KmeansConfig { k: 3, ..Default::default() }, &mut rng);
        let nmi = crate::metrics::normalized_mutual_information(&truth, &out.labels);
        assert!(nmi > 0.99, "nmi {nmi}");
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Xoshiro256::seed_from(92);
        let (pts, _) = blobs(&[(0.0, 0.0), (5.0, 5.0)], 50, 1.0, &mut rng);
        let i1 = kmeans(&pts, &KmeansConfig { k: 1, ..Default::default() }, &mut rng).inertia;
        let i2 = kmeans(&pts, &KmeansConfig { k: 2, ..Default::default() }, &mut rng).inertia;
        let i4 = kmeans(&pts, &KmeansConfig { k: 4, ..Default::default() }, &mut rng).inertia;
        assert!(i1 > i2 && i2 > i4, "{i1} {i2} {i4}");
    }

    #[test]
    fn k_equals_n_gives_zero_inertia() {
        let mut rng = Xoshiro256::seed_from(93);
        let (pts, _) = blobs(&[(0.0, 0.0), (9.0, 9.0), (0.0, 9.0), (9.0, 0.0)], 1, 0.0, &mut rng);
        let out = kmeans(&pts, &KmeansConfig { k: 4, restarts: 5, ..Default::default() }, &mut rng);
        assert!(out.inertia < 1e-9);
    }

    #[test]
    fn labels_in_range_and_every_cluster_used_on_blobs() {
        let mut rng = Xoshiro256::seed_from(94);
        let (pts, _) = blobs(&[(0.0, 0.0), (8.0, 8.0)], 30, 0.5, &mut rng);
        let out = kmeans(&pts, &KmeansConfig { k: 2, ..Default::default() }, &mut rng);
        assert!(out.labels.iter().all(|&l| l < 2));
        assert!(out.labels.contains(&0) && out.labels.contains(&1));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Xoshiro256::seed_from(95);
        let mut r2 = Xoshiro256::seed_from(95);
        let (pts, _) = blobs(&[(0.0, 0.0), (6.0, 6.0)], 25, 0.8, &mut r1);
        let mut r1b = Xoshiro256::seed_from(96);
        let mut r2b = Xoshiro256::seed_from(96);
        let (pts2, _) = blobs(&[(0.0, 0.0), (6.0, 6.0)], 25, 0.8, &mut r2);
        let a = kmeans(&pts, &KmeansConfig::default(), &mut r1b);
        let b = kmeans(&pts2, &KmeansConfig::default(), &mut r2b);
        assert_eq!(a.labels, b.labels);
    }
}
