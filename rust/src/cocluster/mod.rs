//! Atom co-clustering algorithms.
//!
//! LAMC is atom-method agnostic (§IV-C.1 of the paper): any algorithm
//! that maps a (sub)matrix to row + column labels can plug into the
//! partition/merge framework. This module ships the two atoms the paper
//! evaluates — spectral co-clustering ([`scc`], Dhillon 2001) and
//! parallel non-negative matrix tri-factorization ([`pnmtf`], Chen et
//! al. 2023 style) — plus the shared k-means engine.

pub mod kmeans;
pub mod pnmtf;
pub mod scc;

use crate::matrix::Matrix;
use crate::rng::Xoshiro256;

pub use kmeans::{kmeans, KmeansConfig, KmeansResult};
pub use pnmtf::{Pnmtf, PnmtfConfig};
pub use scc::{SpectralCocluster, SpectralConfig};

/// Output of one co-clustering run: a label per row and per column.
#[derive(Clone, Debug, PartialEq)]
pub struct CoclusterResult {
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    /// Number of co-clusters the labels range over.
    pub k: usize,
    /// Algorithm-specific objective (inertia for SCC's k-means stage,
    /// reconstruction error for PNMTF). Lower is better; used by the
    /// merger to weight votes.
    pub objective: f64,
}

impl CoclusterResult {
    /// Basic structural validation (used by tests & the coordinator).
    pub fn validate(&self, rows: usize, cols: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.row_labels.len() == rows, "row label count");
        anyhow::ensure!(self.col_labels.len() == cols, "col label count");
        anyhow::ensure!(
            self.row_labels.iter().chain(&self.col_labels).all(|&l| l < self.k),
            "label out of range"
        );
        Ok(())
    }
}

/// An atom co-clusterer: matrix → co-clustering with `k` clusters.
///
/// Implementations must be deterministic given the `rng` stream so the
/// whole pipeline is reproducible from one seed.
pub trait AtomCocluster: Send + Sync {
    fn name(&self) -> &'static str;
    fn cocluster(&self, a: &Matrix, k: usize, rng: &mut Xoshiro256) -> CoclusterResult;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_shapes() {
        let r = CoclusterResult { row_labels: vec![0, 1], col_labels: vec![0], k: 2, objective: 0.0 };
        assert!(r.validate(2, 1).is_ok());
        assert!(r.validate(3, 1).is_err());
        assert!(r.validate(2, 2).is_err());
    }

    #[test]
    fn validate_catches_label_overflow() {
        let r = CoclusterResult { row_labels: vec![0, 5], col_labels: vec![0], k: 2, objective: 0.0 };
        assert!(r.validate(2, 1).is_err());
    }
}
