//! Matrix substrates: dense row-major and CSR sparse storage.
//!
//! All co-clustering inputs are `M × N` matrices of `f32` (rows = features
//! or documents, columns = samples or terms, matching the paper's
//! formulation in §III-A). Dense storage backs the small/medium dense
//! workloads (Amazon-1000); CSR backs the sparse text workloads
//! (CLASSIC4, RCV1-Large) where densifying would not fit the testbed.

pub mod csr;
pub mod dense;
pub mod io;
pub mod ops;

pub use csr::CsrMatrix;
pub use dense::DenseMatrix;

/// A matrix that can serve as co-clustering input: either storage format,
/// unified behind the handful of accessors the algorithms need.
#[derive(Clone, Debug)]
pub enum Matrix {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl Matrix {
    pub fn rows(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows(),
            Matrix::Sparse(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.cols(),
            Matrix::Sparse(m) => m.cols(),
        }
    }

    /// Number of stored non-zeros (dense counts all entries).
    pub fn nnz(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows() * m.cols(),
            Matrix::Sparse(m) => m.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Matrix::Sparse(_))
    }

    /// Extract the dense submatrix `A[rows, cols]` (gather, not a view —
    /// the partition sampler permutes indices so blocks are not contiguous).
    pub fn gather_block(&self, rows: &[usize], cols: &[usize]) -> DenseMatrix {
        match self {
            Matrix::Dense(m) => m.gather_block(rows, cols),
            Matrix::Sparse(m) => m.gather_block(rows, cols),
        }
    }

    /// Row sums (degrees of the bipartite row vertices).
    pub fn row_sums(&self) -> Vec<f64> {
        match self {
            Matrix::Dense(m) => m.row_sums(),
            Matrix::Sparse(m) => m.row_sums(),
        }
    }

    /// Column sums (degrees of the bipartite column vertices).
    pub fn col_sums(&self) -> Vec<f64> {
        match self {
            Matrix::Dense(m) => m.col_sums(),
            Matrix::Sparse(m) => m.col_sums(),
        }
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        match self {
            Matrix::Dense(m) => m.frobenius(),
            Matrix::Sparse(m) => m.frobenius(),
        }
    }

    /// Force to dense (used by baselines that require dense input; callers
    /// must check size budgets first — see `coordinator::limits`).
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            Matrix::Dense(m) => m.clone(),
            Matrix::Sparse(m) => m.to_dense(),
        }
    }

    /// Content fingerprint: a 64-bit hash over shape, storage kind and
    /// every stored entry, mixed with `rng::SplitMix64`. Two matrices
    /// with equal fingerprints are (with overwhelming probability) equal
    /// in content, which is what the service's result cache keys on —
    /// see `service::cache`. Dense and sparse storage of the same values
    /// hash differently by design: they take different execution paths.
    pub fn fingerprint(&self) -> u64 {
        use crate::rng::mix64 as mix;
        match self {
            Matrix::Dense(m) => {
                let mut h = mix(0x4C41_4D43_0000_0001, m.rows() as u64);
                h = mix(h, m.cols() as u64);
                for &x in m.data() {
                    h = mix(h, x.to_bits() as u64);
                }
                h
            }
            Matrix::Sparse(m) => {
                let mut h = mix(0x4C41_4D43_0000_0002, m.rows() as u64);
                h = mix(h, m.cols() as u64);
                h = mix(h, m.nnz() as u64);
                for i in 0..m.rows() {
                    for (j, v) in m.row_iter(i) {
                        h = mix(h, ((i as u64) << 32) ^ j as u64);
                        h = mix(h, v.to_bits() as u64);
                    }
                }
                h
            }
        }
    }

    /// Approximate resident bytes of the storage.
    pub fn storage_bytes(&self) -> usize {
        match self {
            Matrix::Dense(m) => m.rows() * m.cols() * std::mem::size_of::<f32>(),
            Matrix::Sparse(m) => {
                m.nnz() * (std::mem::size_of::<f32>() + std::mem::size_of::<u32>())
                    + (m.rows() + 1) * std::mem::size_of::<usize>()
            }
        }
    }
}

impl From<DenseMatrix> for Matrix {
    fn from(m: DenseMatrix) -> Self {
        Matrix::Dense(m)
    }
}

impl From<CsrMatrix> for Matrix {
    fn from(m: CsrMatrix) -> Self {
        Matrix::Sparse(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enum_dispatch_matches_backends() {
        let d = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let s = CsrMatrix::from_dense(&d);
        let md: Matrix = d.clone().into();
        let ms: Matrix = s.into();
        assert_eq!(md.rows(), ms.rows());
        assert_eq!(md.cols(), ms.cols());
        assert_eq!(md.row_sums(), ms.row_sums());
        assert_eq!(md.col_sums(), ms.col_sums());
        assert!((md.frobenius() - ms.frobenius()).abs() < 1e-12);
        assert_eq!(ms.nnz(), 2);
        assert_eq!(md.nnz(), 4);
    }

    #[test]
    fn fingerprint_detects_content_changes() {
        let base = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let same = Matrix::from(base.clone()).fingerprint();
        assert_eq!(same, Matrix::from(base.clone()).fingerprint(), "deterministic");

        let mut bumped = base.clone();
        bumped.set(1, 1, 2.5);
        assert_ne!(same, Matrix::from(bumped).fingerprint(), "value change");

        let wide = DenseMatrix::from_vec(1, 4, vec![1.0, 0.0, 0.0, 2.0]);
        assert_ne!(same, Matrix::from(wide).fingerprint(), "shape change");

        let sparse = Matrix::from(CsrMatrix::from_dense(&base));
        assert_ne!(same, sparse.fingerprint(), "storage kind is part of the key");
        assert_eq!(sparse.fingerprint(), Matrix::from(CsrMatrix::from_dense(&base)).fingerprint());
    }

    #[test]
    fn gather_block_consistent_across_backends() {
        let d = DenseMatrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        let s = CsrMatrix::from_dense(&d);
        let bd = Matrix::from(d).gather_block(&[2, 0], &[1, 2]);
        let bs = Matrix::from(s).gather_block(&[2, 0], &[1, 2]);
        assert_eq!(bd.data(), bs.data());
        assert_eq!(bd.data(), &[8.0, 9.0, 2.0, 3.0]);
    }
}
