//! Row-major dense `f32` matrix.
//!
//! The dense substrate favours simplicity + cache-friendly row-major
//! traversal; the compute-heavy kernels live in [`crate::linalg`] and are
//! blocked/threaded there rather than here.

use crate::rng::Xoshiro256;

#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>, // row-major, len == rows * cols
}

impl DenseMatrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an owned row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a slice of row vectors (test/ergonomic constructor).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |v| v.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// i.i.d. standard-normal entries (used by randomized sketching).
    pub fn randn(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_normal() as f32).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 64;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Gather submatrix `A[rows, cols]` in the given index order.
    pub fn gather_block(&self, rows: &[usize], cols: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(rows.len(), cols.len());
        for (bi, &i) in rows.iter().enumerate() {
            let src = self.row(i);
            let dst = out.row_mut(bi);
            for (bj, &j) in cols.iter().enumerate() {
                dst[bj] = src[j];
            }
        }
        out
    }

    /// Row sums in f64 (degree vector `D1`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|&x| x as f64).sum())
            .collect()
    }

    /// Column sums in f64 (degree vector `D2`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                sums[j] += x as f64;
            }
        }
        sums
    }

    /// Frobenius norm with f64 accumulation.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Elementwise maximum absolute difference (test helper).
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Zero-pad (or truncate is forbidden — asserts growth) to shape
    /// `(r, c)`; used to fit odd-sized partition blocks to a compiled
    /// artifact's static shape.
    pub fn pad_to(&self, r: usize, c: usize) -> DenseMatrix {
        assert!(r >= self.rows && c >= self.cols, "pad_to cannot shrink");
        let mut out = DenseMatrix::zeros(r, c);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Scale every entry in place.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "buffer length mismatch")]
    fn from_vec_checks_length() {
        DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::seed_from(11);
        let m = DenseMatrix::randn(37, 53, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_values() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.get(0, 0), 1.0);
    }

    #[test]
    fn sums_match_manual() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.row_sums(), vec![3.0, 7.0]);
        assert_eq!(m.col_sums(), vec![4.0, 6.0]);
    }

    #[test]
    fn frobenius_pythagoras() {
        let m = DenseMatrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert!((m.frobenius() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn gather_block_orders_indices() {
        let m = DenseMatrix::from_rows(&[
            vec![0.0, 1.0, 2.0],
            vec![3.0, 4.0, 5.0],
            vec![6.0, 7.0, 8.0],
        ]);
        let b = m.gather_block(&[1, 0], &[2, 0]);
        assert_eq!(b.data(), &[5.0, 3.0, 2.0, 0.0]);
    }

    #[test]
    fn pad_to_grows_with_zeros() {
        let m = DenseMatrix::from_rows(&[vec![1.0], vec![2.0]]);
        let p = m.pad_to(3, 2);
        assert_eq!(p.get(0, 0), 1.0);
        assert_eq!(p.get(1, 0), 2.0);
        assert_eq!(p.get(2, 1), 0.0);
        assert_eq!(p.get(0, 1), 0.0);
    }

    #[test]
    fn eye_is_identity_under_gather() {
        let e = DenseMatrix::eye(4);
        assert_eq!(e.get(2, 2), 1.0);
        assert_eq!(e.get(2, 3), 0.0);
        assert_eq!(e.row_sums(), vec![1.0; 4]);
    }
}
