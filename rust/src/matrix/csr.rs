//! Compressed Sparse Row matrix for the text workloads.
//!
//! CLASSIC4- and RCV1-style document–term matrices are ~1–2% dense;
//! storing them densely at RCV1 scale would exceed the testbed budget,
//! and the paper's sparse experiments (Table II, "up to 30%" headline)
//! depend on sparsity-aware traversal.

use super::dense::DenseMatrix;

#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row pointers, len = rows + 1.
    indptr: Vec<usize>,
    /// Column indices per stored entry, len = nnz. `u32` keeps RCV1-scale
    /// index arrays half the size of `usize`.
    indices: Vec<u32>,
    /// Stored values, len = nnz.
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from raw CSR arrays (validates invariants).
    pub fn new(rows: usize, cols: usize, indptr: Vec<usize>, indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr tail");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr monotone");
        debug_assert!(indices.iter().all(|&j| (j as usize) < cols), "col index bound");
        Self { rows, cols, indptr, indices, values }
    }

    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut triplets: Vec<(usize, usize, f32)>) -> Self {
        triplets.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(triplets.len());
        let mut values: Vec<f32> = Vec::with_capacity(triplets.len());
        for (i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet out of bounds");
            if indptr[i + 1] > indptr[i] && indices.len() == indptr[i + 1] {
                // The current row is the one being filled; a repeated
                // column folds into its last stored entry. Guarded
                // accumulate: both accessors are `Some` here by the
                // checks above, but a panicking unwrap would turn a
                // future refactor slip into a crash on user data.
                if let (Some(&last_j), Some(last_v)) = (indices.last(), values.last_mut()) {
                    if last_j as usize == j {
                        *last_v += v;
                        continue;
                    }
                }
            }
            indices.push(j as u32);
            values.push(v);
            indptr[i + 1] = indices.len();
        }
        // Forward-fill row pointers for empty rows.
        for i in 1..=rows {
            if indptr[i] < indptr[i - 1] {
                indptr[i] = indptr[i - 1];
            }
        }
        Self::new(rows, cols, indptr, indices, values)
    }

    /// Convert a dense matrix, dropping exact zeros.
    pub fn from_dense(d: &DenseMatrix) -> Self {
        let mut indptr = Vec::with_capacity(d.rows() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for i in 0..d.rows() {
            for (j, &v) in d.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Self { rows: d.rows(), cols: d.cols(), indptr, indices, values }
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let row = out.row_mut(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                row[self.indices[idx] as usize] = self.values[idx];
            }
        }
        out
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of stored entries.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// Iterate the stored entries of row `i` as `(col, value)`.
    #[inline]
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&j, &v)| (j as usize, v))
    }

    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| {
                let lo = self.indptr[i];
                let hi = self.indptr[i + 1];
                self.values[lo..hi].iter().map(|&v| v as f64).sum()
            })
            .collect()
    }

    pub fn col_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0f64; self.cols];
        for (&j, &v) in self.indices.iter().zip(&self.values) {
            sums[j as usize] += v as f64;
        }
        sums
    }

    pub fn frobenius(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Gather the dense block `A[rows, cols]` (arbitrary index order).
    ///
    /// Builds a column lookup once (O(N)), then streams each selected
    /// row's non-zeros — O(sum nnz(row)) instead of O(|rows|·|cols|·log).
    pub fn gather_block(&self, rows: &[usize], cols: &[usize]) -> DenseMatrix {
        let mut col_pos: Vec<i32> = vec![-1; self.cols];
        for (bj, &j) in cols.iter().enumerate() {
            col_pos[j] = bj as i32;
        }
        let mut out = DenseMatrix::zeros(rows.len(), cols.len());
        for (bi, &i) in rows.iter().enumerate() {
            let dst = out.row_mut(bi);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let bj = col_pos[self.indices[idx] as usize];
                if bj >= 0 {
                    dst[bj as usize] = self.values[idx];
                }
            }
        }
        out
    }

    /// Sparse × dense: `Y = A · X` where `X` is `cols × k` dense, `Y` is
    /// `rows × k`. The workhorse of sparse spectral co-clustering.
    pub fn matmul_dense(&self, x: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, x.rows(), "shape mismatch in csr·dense");
        let k = x.cols();
        let mut out = DenseMatrix::zeros(self.rows, k);
        for i in 0..self.rows {
            let dst = out.row_mut(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let v = self.values[idx];
                let xr = x.row(self.indices[idx] as usize);
                for t in 0..k {
                    dst[t] += v * xr[t];
                }
            }
        }
        out
    }

    /// Transposed sparse × dense: `Y = Aᵀ · X` where `X` is `rows × k`.
    pub fn matmul_transpose_dense(&self, x: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.rows, x.rows(), "shape mismatch in csrᵀ·dense");
        let k = x.cols();
        let mut out = DenseMatrix::zeros(self.cols, k);
        for i in 0..self.rows {
            let xr = x.row(i);
            for idx in self.indptr[i]..self.indptr[i + 1] {
                let v = self.values[idx];
                let dst = out.row_mut(self.indices[idx] as usize);
                for t in 0..k {
                    dst[t] += v * xr[t];
                }
            }
        }
        out
    }

    /// Scale rows and columns: `B = diag(r) · A · diag(c)` (normalization).
    pub fn scale_rows_cols(&self, r: &[f32], c: &[f32]) -> CsrMatrix {
        assert_eq!(r.len(), self.rows);
        assert_eq!(c.len(), self.cols);
        let mut values = self.values.clone();
        for i in 0..self.rows {
            for idx in self.indptr[i]..self.indptr[i + 1] {
                values[idx] *= r[i] * c[self.indices[idx] as usize];
            }
        }
        CsrMatrix { rows: self.rows, cols: self.cols, indptr: self.indptr.clone(), indices: self.indices.clone(), values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        CsrMatrix::from_triplets(3, 3, vec![(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)])
    }

    #[test]
    fn triplets_round_trip_dense() {
        let s = sample();
        let d = s.to_dense();
        assert_eq!(d.get(0, 2), 2.0);
        assert_eq!(d.get(1, 1), 0.0);
        assert_eq!(CsrMatrix::from_dense(&d), s);
    }

    #[test]
    fn triplets_sum_duplicates() {
        let s = CsrMatrix::from_triplets(1, 2, vec![(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(s.nnz(), 1);
        assert_eq!(s.to_dense().get(0, 1), 3.5);
    }

    #[test]
    fn empty_rows_handled() {
        let s = sample();
        assert_eq!(s.row_iter(1).count(), 0);
        assert_eq!(s.row_sums()[1], 0.0);
    }

    #[test]
    fn sums_and_norm_match_dense() {
        let s = sample();
        let d = s.to_dense();
        assert_eq!(s.row_sums(), d.row_sums());
        assert_eq!(s.col_sums(), d.col_sums());
        assert!((s.frobenius() - d.frobenius()).abs() < 1e-12);
    }

    #[test]
    fn gather_block_matches_dense_gather() {
        let mut rng = Xoshiro256::seed_from(12);
        let mut trip = Vec::new();
        for _ in 0..200 {
            trip.push((rng.next_below(20), rng.next_below(15), rng.next_f32()));
        }
        let s = CsrMatrix::from_triplets(20, 15, trip);
        let d = s.to_dense();
        let rows = [7, 3, 19, 0];
        let cols = [14, 2, 9];
        assert_eq!(s.gather_block(&rows, &cols).data(), d.gather_block(&rows, &cols).data());
    }

    #[test]
    fn matmul_dense_matches_naive() {
        let mut rng = Xoshiro256::seed_from(13);
        let mut trip = Vec::new();
        for _ in 0..100 {
            trip.push((rng.next_below(10), rng.next_below(12), rng.next_f32()));
        }
        let s = CsrMatrix::from_triplets(10, 12, trip);
        let x = DenseMatrix::randn(12, 4, &mut rng);
        let y = s.matmul_dense(&x);
        let d = s.to_dense();
        for i in 0..10 {
            for t in 0..4 {
                let want: f32 = (0..12).map(|j| d.get(i, j) * x.get(j, t)).sum();
                assert!((y.get(i, t) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_transpose_matches_naive() {
        let mut rng = Xoshiro256::seed_from(14);
        let mut trip = Vec::new();
        for _ in 0..100 {
            trip.push((rng.next_below(10), rng.next_below(12), rng.next_f32()));
        }
        let s = CsrMatrix::from_triplets(10, 12, trip);
        let x = DenseMatrix::randn(10, 3, &mut rng);
        let y = s.matmul_transpose_dense(&x);
        let d = s.to_dense();
        for j in 0..12 {
            for t in 0..3 {
                let want: f32 = (0..10).map(|i| d.get(i, j) * x.get(i, t)).sum();
                assert!((y.get(j, t) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn scale_rows_cols_matches_dense() {
        let s = sample();
        let r = [2.0f32, 1.0, 0.5];
        let c = [1.0f32, 3.0, 2.0];
        let scaled = s.scale_rows_cols(&r, &c).to_dense();
        assert_eq!(scaled.get(0, 0), 2.0);
        assert_eq!(scaled.get(0, 2), 8.0);
        assert_eq!(scaled.get(2, 1), 6.0);
    }

    #[test]
    fn density_fraction() {
        let s = sample();
        assert!((s.density() - 4.0 / 9.0).abs() < 1e-12);
    }
}
