//! Shared matrix operations used by multiple co-clustering algorithms.

use super::{CsrMatrix, DenseMatrix, Matrix};

/// Clamp used when inverting degree vectors: rows/columns that are all
/// zero (padding, empty documents) get weight 0 rather than Inf, which
/// drops them out of the spectral embedding instead of poisoning it.
pub const DEGREE_EPS: f64 = 1e-12;

/// `d → d^{-1/2}` with zero-degree protection.
pub fn inv_sqrt_degrees(degrees: &[f64]) -> Vec<f32> {
    degrees
        .iter()
        .map(|&d| if d > DEGREE_EPS { (1.0 / d.sqrt()) as f32 } else { 0.0 })
        .collect()
}

/// Bipartite spectral normalization `A_n = D1^{-1/2} · A · D2^{-1/2}`
/// (Dhillon 2001 §4), preserving the input's storage format.
pub fn bipartite_normalize(a: &Matrix) -> (Matrix, Vec<f32>, Vec<f32>) {
    let r = inv_sqrt_degrees(&a.row_sums());
    let c = inv_sqrt_degrees(&a.col_sums());
    let an = match a {
        Matrix::Dense(d) => {
            let mut out = d.clone();
            for i in 0..out.rows() {
                let ri = r[i];
                for (j, x) in out.row_mut(i).iter_mut().enumerate() {
                    *x *= ri * c[j];
                }
            }
            Matrix::Dense(out)
        }
        Matrix::Sparse(s) => Matrix::Sparse(s.scale_rows_cols(&r, &c)),
    };
    (an, r, c)
}

/// `Y = A · X` for either storage format (`X` dense `cols×k`).
pub fn matmul_dense(a: &Matrix, x: &DenseMatrix) -> DenseMatrix {
    match a {
        Matrix::Dense(d) => crate::linalg::matmul::matmul(d, x),
        Matrix::Sparse(s) => s.matmul_dense(x),
    }
}

/// `Y = Aᵀ · X` for either storage format (`X` dense `rows×k`).
pub fn matmul_transpose_dense(a: &Matrix, x: &DenseMatrix) -> DenseMatrix {
    match a {
        Matrix::Dense(d) => crate::linalg::matmul::matmul_at_b(d, x),
        Matrix::Sparse(s) => s.matmul_transpose_dense(x),
    }
}

/// Vertically stack two dense matrices with equal column counts.
pub fn vstack(top: &DenseMatrix, bottom: &DenseMatrix) -> DenseMatrix {
    assert_eq!(top.cols(), bottom.cols(), "vstack column mismatch");
    let mut data = Vec::with_capacity((top.rows() + bottom.rows()) * top.cols());
    data.extend_from_slice(top.data());
    data.extend_from_slice(bottom.data());
    DenseMatrix::from_vec(top.rows() + bottom.rows(), top.cols(), data)
}

/// Scale each row `i` of `m` by `w[i]` in place.
pub fn scale_rows_inplace(m: &mut DenseMatrix, w: &[f32]) {
    assert_eq!(m.rows(), w.len());
    for i in 0..m.rows() {
        let wi = w[i];
        for x in m.row_mut(i) {
            *x *= wi;
        }
    }
}

/// Make a CSR copy of any matrix (used when a sparse pipeline receives a
/// dense input).
pub fn to_csr(a: &Matrix) -> CsrMatrix {
    match a {
        Matrix::Dense(d) => CsrMatrix::from_dense(d),
        Matrix::Sparse(s) => s.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn inv_sqrt_handles_zero() {
        let out = inv_sqrt_degrees(&[4.0, 0.0, 1.0]);
        assert_eq!(out, vec![0.5, 0.0, 1.0]);
    }

    #[test]
    fn normalize_dense_matches_manual() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 3.0]]);
        let (an, r, c) = bipartite_normalize(&Matrix::Dense(a));
        // row sums = [2,4]; col sums = [2,4]
        assert!((r[0] - (0.5f32).sqrt()).abs() < 1e-6);
        assert!((c[1] - 0.5).abs() < 1e-6);
        let an = an.to_dense();
        // an[1][1] = 3 / sqrt(4*4) = 0.75
        assert!((an.get(1, 1) - 0.75).abs() < 1e-6);
    }

    #[test]
    fn normalize_sparse_matches_dense_path() {
        let mut rng = Xoshiro256::seed_from(31);
        let mut trip = Vec::new();
        for _ in 0..60 {
            trip.push((rng.next_below(8), rng.next_below(9), rng.next_f32() + 0.1));
        }
        let s = CsrMatrix::from_triplets(8, 9, trip);
        let d = s.to_dense();
        let (an_s, _, _) = bipartite_normalize(&Matrix::Sparse(s));
        let (an_d, _, _) = bipartite_normalize(&Matrix::Dense(d));
        assert!(an_s.to_dense().max_abs_diff(&an_d.to_dense()) < 1e-6);
    }

    #[test]
    fn normalized_matrix_top_singular_value_is_one() {
        // For a connected bipartite graph the leading singular value of
        // A_n is exactly 1 with singular pair (D1^{1/2}1, D2^{1/2}1).
        let mut rng = Xoshiro256::seed_from(32);
        let mut a = DenseMatrix::randn(12, 10, &mut rng);
        for x in a.data_mut() {
            *x = x.abs() + 0.05;
        }
        let (an, _, _) = bipartite_normalize(&Matrix::Dense(a));
        let an = an.to_dense();
        // Power iteration for sigma_max.
        let mut v = DenseMatrix::from_vec(10, 1, vec![1.0; 10]);
        for _ in 0..200 {
            let u = crate::linalg::matmul::matmul(&an, &v);
            let mut w = crate::linalg::matmul::matmul_at_b(&an, &u);
            let n = w.frobenius() as f32;
            w.scale(1.0 / n);
            v = w;
        }
        let u = crate::linalg::matmul::matmul(&an, &v);
        let sigma = u.frobenius();
        assert!((sigma - 1.0).abs() < 1e-3, "sigma {sigma}");
    }

    #[test]
    fn vstack_shapes_and_values() {
        let a = DenseMatrix::from_rows(&[vec![1.0, 2.0]]);
        let b = DenseMatrix::from_rows(&[vec![3.0, 4.0], vec![5.0, 6.0]]);
        let v = vstack(&a, &b);
        assert_eq!(v.rows(), 3);
        assert_eq!(v.get(2, 1), 6.0);
    }

    #[test]
    fn matmul_dispatch_agrees() {
        let mut rng = Xoshiro256::seed_from(33);
        let d = DenseMatrix::randn(9, 7, &mut rng);
        let s = CsrMatrix::from_dense(&d);
        let x = DenseMatrix::randn(7, 3, &mut rng);
        let yd = matmul_dense(&Matrix::Dense(d.clone()), &x);
        let ys = matmul_dense(&Matrix::Sparse(s.clone()), &x);
        assert!(yd.max_abs_diff(&ys) < 1e-4);
        let xt = DenseMatrix::randn(9, 3, &mut rng);
        let zd = matmul_transpose_dense(&Matrix::Dense(d), &xt);
        let zs = matmul_transpose_dense(&Matrix::Sparse(s), &xt);
        assert!(zd.max_abs_diff(&zs) < 1e-4);
    }
}
