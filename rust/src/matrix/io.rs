//! Matrix (de)serialization.
//!
//! A tiny self-describing binary format (`LAMC` magic + format tag) so
//! generated datasets can be cached on disk between benchmark runs, plus
//! a MatrixMarket-subset text reader for interoperability with external
//! sparse datasets.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{CsrMatrix, DenseMatrix, Matrix};

const MAGIC: &[u8; 4] = b"LAMC";
const TAG_DENSE: u8 = 1;
const TAG_CSR: u8 = 2;

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Save any matrix to the LAMC binary format.
pub fn save(matrix: &Matrix, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    w.write_all(MAGIC)?;
    match matrix {
        Matrix::Dense(d) => {
            w.write_all(&[TAG_DENSE])?;
            write_u64(&mut w, d.rows() as u64)?;
            write_u64(&mut w, d.cols() as u64)?;
            write_f32s(&mut w, d.data())?;
        }
        Matrix::Sparse(s) => {
            w.write_all(&[TAG_CSR])?;
            write_u64(&mut w, s.rows() as u64)?;
            write_u64(&mut w, s.cols() as u64)?;
            write_u64(&mut w, s.nnz() as u64)?;
            // Re-derive CSR arrays through the public API to avoid
            // exposing internals: stream triplets row-major.
            for i in 0..s.rows() {
                for (j, v) in s.row_iter(i) {
                    write_u64(&mut w, i as u64)?;
                    write_u64(&mut w, j as u64)?;
                    w.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Load a matrix saved by [`save`].
pub fn load(path: &Path) -> Result<Matrix> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a LAMC matrix file: {path:?}");
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    match tag[0] {
        TAG_DENSE => {
            let rows = read_u64(&mut r)? as usize;
            let cols = read_u64(&mut r)? as usize;
            let data = read_f32s(&mut r, rows * cols)?;
            Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)))
        }
        TAG_CSR => {
            let rows = read_u64(&mut r)? as usize;
            let cols = read_u64(&mut r)? as usize;
            let nnz = read_u64(&mut r)? as usize;
            let mut triplets = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                let i = read_u64(&mut r)? as usize;
                let j = read_u64(&mut r)? as usize;
                let mut b = [0u8; 4];
                r.read_exact(&mut b)?;
                triplets.push((i, j, f32::from_le_bytes(b)));
            }
            Ok(Matrix::Sparse(CsrMatrix::from_triplets(rows, cols, triplets)))
        }
        t => bail!("unknown matrix tag {t}"),
    }
}

/// Read a MatrixMarket `coordinate real general` file into CSR.
///
/// Supports the subset emitted by scipy's `mmwrite` for real sparse
/// matrices; 1-based indices per the spec.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix> {
    let r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut lines = r.lines();
    let header = lines.next().context("empty MatrixMarket file")??;
    if !header.starts_with("%%MatrixMarket matrix coordinate") {
        bail!("unsupported MatrixMarket header: {header}");
    }
    let pattern = header.contains(" pattern");
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut triplets = Vec::new();
    for line in lines {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let mut parts = line.split_whitespace();
        if dims.is_none() {
            let m: usize = parts.next().context("dims")?.parse()?;
            let n: usize = parts.next().context("dims")?.parse()?;
            let nnz: usize = parts.next().context("dims")?.parse()?;
            dims = Some((m, n, nnz));
            triplets.reserve(nnz);
            continue;
        }
        let i: usize = parts.next().context("row")?.parse()?;
        let j: usize = parts.next().context("col")?.parse()?;
        let v: f32 = if pattern { 1.0 } else { parts.next().context("val")?.parse()? };
        if i == 0 || j == 0 {
            bail!("MatrixMarket indices are 1-based; got ({i},{j})");
        }
        triplets.push((i - 1, j - 1, v));
    }
    let (m, n, _) = dims.context("missing MatrixMarket size line")?;
    Ok(CsrMatrix::from_triplets(m, n, triplets))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn dense_round_trip() {
        let mut rng = Xoshiro256::seed_from(21);
        let d = DenseMatrix::randn(13, 7, &mut rng);
        let dir = std::env::temp_dir().join("lamc_io_test_dense");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.lamc");
        save(&Matrix::Dense(d.clone()), &path).unwrap();
        match load(&path).unwrap() {
            Matrix::Dense(got) => assert_eq!(got, d),
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn sparse_round_trip() {
        let s = CsrMatrix::from_triplets(4, 5, vec![(0, 1, 2.0), (3, 4, -1.5), (2, 0, 7.0)]);
        let dir = std::env::temp_dir().join("lamc_io_test_sparse");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.lamc");
        save(&Matrix::Sparse(s.clone()), &path).unwrap();
        match load(&path).unwrap() {
            Matrix::Sparse(got) => assert_eq!(got, s),
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lamc_io_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.lamc");
        std::fs::write(&path, b"not a matrix").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn matrix_market_subset() {
        let dir = std::env::temp_dir().join("lamc_io_test_mm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate real general\n% comment\n3 4 2\n1 2 5.0\n3 4 -1.0\n",
        )
        .unwrap();
        let s = read_matrix_market(&path).unwrap();
        assert_eq!(s.rows(), 3);
        assert_eq!(s.cols(), 4);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense().get(0, 1), 5.0);
        assert_eq!(s.to_dense().get(2, 3), -1.0);
    }
}
