//! Leveled stderr logger (dependency-free).
//!
//! Controlled by `LAMC_LOG` (error|warn|info|debug|trace, default info).
//! Timestamps are monotonic seconds since process start — enough for
//! correlating scheduler events without pulling in a clock/tz stack.

use std::cell::Cell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("LAMC_LOG").unwrap_or_default().to_lowercase().as_str() {
            "" | "info" => Level::Info,
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            other => {
                // A typo'd LAMC_LOG (e.g. "inof") must not silently read
                // as a deliberate Info — warn once, then default.
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "lamc: unrecognized LAMC_LOG='{other}' \
                         (want error|warn|info|debug|trace); defaulting to info"
                    );
                });
                Level::Info
            }
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

/// Current log level (lazily read from the environment).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = Level::from_env();
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (CLI `-v`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Seconds since first log call.
pub fn uptime() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

thread_local! {
    static JOB_SCOPE: Cell<Option<u64>> = const { Cell::new(None) };
}

/// RAII guard: while alive, every log line emitted from this thread is
/// tagged `[job N]`, so interleaved multi-job serve logs correlate.
/// Restores the previous scope on drop, so nested scopes compose.
pub struct JobScope(Option<u64>);

/// Enter job `id`'s log scope on the current thread.
pub fn job_scope(id: u64) -> JobScope {
    JobScope(JOB_SCOPE.with(|s| s.replace(Some(id))))
}

/// The job id tagging this thread's log lines, if any.
pub fn current_job() -> Option<u64> {
    JOB_SCOPE.with(|s| s.get())
}

impl Drop for JobScope {
    fn drop(&mut self) {
        let prev = self.0;
        JOB_SCOPE.with(|s| s.set(prev));
    }
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if l <= level() {
        match current_job() {
            Some(id) => eprintln!("[{:>9.3}] {} {} [job {id}]: {}", uptime(), l.tag(), module, msg),
            None => eprintln!("[{:>9.3}] {} {}: {}", uptime(), l.tag(), module, msg),
        }
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_level_round_trips() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn uptime_monotonic() {
        let a = uptime();
        let b = uptime();
        assert!(b >= a);
    }

    #[test]
    fn job_scope_nests_and_restores() {
        assert_eq!(current_job(), None);
        {
            let _outer = job_scope(7);
            assert_eq!(current_job(), Some(7));
            {
                let _inner = job_scope(9);
                assert_eq!(current_job(), Some(9));
            }
            assert_eq!(current_job(), Some(7), "inner scope restores the outer one");
        }
        assert_eq!(current_job(), None);
    }
}
