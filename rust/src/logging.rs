//! Leveled stderr logger (dependency-free).
//!
//! Controlled by `LAMC_LOG` (error|warn|info|debug|trace, default info).
//! Timestamps are monotonic seconds since process start — enough for
//! correlating scheduler events without pulling in a clock/tz stack.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("LAMC_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

/// Current log level (lazily read from the environment).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = Level::from_env();
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (CLI `-v`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Seconds since first log call.
pub fn uptime() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if l <= level() {
        eprintln!("[{:>9.3}] {} {}: {}", uptime(), l.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_level_round_trips() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn uptime_monotonic() {
        let a = uptime();
        let b = uptime();
        assert!(b >= a);
    }
}
