//! Store-to-store repacking: change a store's chunk geometry —
//! row-band (LAMC2) ↔ tiled (LAMC3), or different band/tile extents —
//! **without ever materializing the matrix**.
//!
//! The pass is a single sequential sweep: source chunks decode one row
//! band at a time (every column tile of the band is pinned while its
//! rows drain), rows replay through a fresh [`ChunkWriter`], and the
//! writer seals-and-fsyncs output bands as they fill. Peak memory is
//! one source row band + one destination row band + whatever the
//! reader's byte-bounded chunk cache holds (which this sweep never
//! needs — every source chunk is read exactly once).
//!
//! The destination keeps the source's **content fingerprint** verbatim:
//! the bytes on disk change, the matrix does not, so a repacked store
//! hits the same service result-cache entries as its source
//! (`tests/integration_store.rs` asserts this end to end).

use std::path::Path;

use anyhow::{bail, Result};

use super::chunk::{ChunkWriter, DecodedChunk, StoreReader, StoreSummary};
use super::codec::Codec;
use super::format::{Layout, DEFAULT_CHUNK_ROWS};

/// How to re-chunk. `chunk_cols: None` produces a row-band (LAMC2)
/// store; `Some(width)` produces a tiled (LAMC3) store.
#[derive(Clone, Copy, Debug)]
pub struct RepackOptions {
    /// Output row-band height.
    pub chunk_rows: usize,
    /// Output column-band width (`None` = row bands).
    pub chunk_cols: Option<usize>,
    /// Byte budget for the source reader's decoded-chunk cache. The
    /// sweep reads every chunk exactly once, so 0 (no cache) is the
    /// tightest-memory choice and costs no extra I/O.
    pub cache_budget: usize,
    /// Payload codec for the *output* chunks — repacking is also how a
    /// store gets compressed or decompressed in place, independent of
    /// the source's codec (the fingerprint covers uncompressed content,
    /// so it survives either direction).
    pub codec: Codec,
}

impl Default for RepackOptions {
    fn default() -> Self {
        RepackOptions {
            chunk_rows: DEFAULT_CHUNK_ROWS,
            chunk_cols: None,
            cache_budget: 0,
            codec: Codec::None,
        }
    }
}

/// Repack the store at `src` into `dst` with a new chunk geometry.
/// Streaming both ways; fingerprint preserved. See the module docs.
pub fn repack(src: &Path, dst: &Path, opts: &RepackOptions) -> Result<StoreSummary> {
    let reader = StoreReader::open_with_cache(src, opts.cache_budget)?;
    repack_reader(&reader, dst, opts.chunk_rows, opts.chunk_cols, opts.codec)
}

/// Repack through an already-open reader (the reader's cache budget is
/// whatever it was opened with).
pub fn repack_reader(
    reader: &StoreReader,
    dst: &Path,
    chunk_rows: usize,
    chunk_cols: Option<usize>,
    codec: Codec,
) -> Result<StoreSummary> {
    let header = reader.header();
    let mut writer = match chunk_cols {
        Some(w) => ChunkWriter::create_tiled(dst, header.layout, header.cols, chunk_rows, w)?,
        None => ChunkWriter::create(dst, header.layout, header.cols, chunk_rows)?,
    };
    writer.set_codec(codec);
    // Same content, same identity: carry the source fingerprint forward
    // instead of recomputing over the new chunk checksums.
    writer.set_fingerprint(header.fingerprint);

    // The band sweep below reads every source chunk exactly once, in
    // index order — feed the prefetcher the linear plan so the next
    // band streams in while this one re-chunks.
    reader.prefetch_scan();

    let n_row_bands = header.n_row_bands();
    let layout = header.layout;
    let mut dense_row: Vec<f32> = Vec::with_capacity(header.cols);
    let mut sparse_row: Vec<(u32, f32)> = Vec::new();
    for rb in 0..n_row_bands {
        // Pin this band's tiles (a row-band store has exactly one) so
        // the sweep is independent of the reader's cache policy.
        let tiles = reader.band_tiles(rb)?;
        let band_rows = tiles[0].0.rows;
        for lr in 0..band_rows {
            match layout {
                Layout::Dense => {
                    dense_row.clear();
                    for (meta, chunk) in &tiles {
                        let Some(values) = chunk.dense_values() else {
                            bail!("dense store decoded a csr chunk")
                        };
                        dense_row.extend_from_slice(&values[lr * meta.cols..(lr + 1) * meta.cols]);
                    }
                    writer.append_dense_row(&dense_row)?;
                }
                Layout::Csr => {
                    sparse_row.clear();
                    for (meta, chunk) in &tiles {
                        let DecodedChunk::Csr { indptr, indices, values } = &**chunk else {
                            bail!("csr store decoded a dense chunk")
                        };
                        for t in indptr[lr] as usize..indptr[lr + 1] as usize {
                            sparse_row.push((meta.col_lo as u32 + indices[t], values[t]));
                        }
                    }
                    writer.append_sparse_row(&sparse_row)?;
                }
            }
        }
    }
    writer.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{CsrMatrix, DenseMatrix, Matrix};
    use crate::rng::Xoshiro256;
    use crate::store::chunk::{pack_matrix, pack_matrix_tiled};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lamc_repack_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn dense(seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from(seed);
        Matrix::Dense(DenseMatrix::randn(43, 19, &mut rng))
    }

    fn sparse(seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from(seed);
        let mut trip = Vec::new();
        for _ in 0..350 {
            trip.push((rng.next_below(43), rng.next_below(19), rng.next_f32() + 0.01));
        }
        Matrix::Sparse(CsrMatrix::from_triplets(43, 19, trip))
    }

    fn read_back(path: &Path) -> Matrix {
        StoreReader::open(path).unwrap().read_all().unwrap()
    }

    fn assert_same(a: &Matrix, b: &Matrix) {
        match (a, b) {
            (Matrix::Dense(x), Matrix::Dense(y)) => assert_eq!(x, y),
            (Matrix::Sparse(x), Matrix::Sparse(y)) => {
                assert_eq!(x.nnz(), y.nnz());
                assert_eq!(x.to_dense().data(), y.to_dense().data());
            }
            _ => panic!("layout changed across repack"),
        }
    }

    #[test]
    fn band_to_tiled_and_back_preserves_content_and_fingerprint() {
        for (name, matrix) in [("dense", dense(11)), ("sparse", sparse(12))] {
            let a = tmp(&format!("{name}_a.lamc2"));
            let b = tmp(&format!("{name}_b.lamc3"));
            let c = tmp(&format!("{name}_c.lamc2"));
            let s0 = pack_matrix(&matrix, &a, 8).unwrap();
            let s1 = repack(
                &a,
                &b,
                &RepackOptions { chunk_rows: 5, chunk_cols: Some(4), cache_budget: 0, codec: Codec::None },
            )
            .unwrap();
            assert!(s1.tiled);
            assert_eq!(s1.fingerprint, s0.fingerprint, "{name}: identity survives re-tiling");
            assert_eq!(s1.nnz, s0.nnz, "{name}: no entries invented or dropped");
            let s2 = repack(
                &b,
                &c,
                &RepackOptions { chunk_rows: 16, chunk_cols: None, cache_budget: 0, codec: Codec::None },
            )
            .unwrap();
            assert!(!s2.tiled);
            assert_eq!(s2.fingerprint, s0.fingerprint);
            assert_same(&matrix, &read_back(&a));
            assert_same(&matrix, &read_back(&b));
            assert_same(&matrix, &read_back(&c));
        }
    }

    #[test]
    fn codec_round_trip_preserves_content_and_fingerprint() {
        // none -> shuffle-lz -> none, re-chunking along the way: the
        // fingerprint covers uncompressed content, so compressing and
        // decompressing a store must both keep its identity.
        for (name, matrix) in [("dense", dense(21)), ("sparse", sparse(22))] {
            let a = tmp(&format!("{name}_codec_a.lamc2"));
            let b = tmp(&format!("{name}_codec_b.lamc3"));
            let c = tmp(&format!("{name}_codec_c.lamc2"));
            let s0 = pack_matrix(&matrix, &a, 8).unwrap();
            let s1 = repack(
                &a,
                &b,
                &RepackOptions {
                    chunk_rows: 5,
                    chunk_cols: Some(4),
                    cache_budget: 0,
                    codec: Codec::ShuffleLz,
                },
            )
            .unwrap();
            assert_eq!(s1.codec, Codec::ShuffleLz);
            assert_eq!(s1.fingerprint, s0.fingerprint, "{name}: identity survives compression");
            let s2 = repack(
                &b,
                &c,
                &RepackOptions {
                    chunk_rows: 16,
                    chunk_cols: None,
                    cache_budget: 0,
                    codec: Codec::None,
                },
            )
            .unwrap();
            assert_eq!(s2.fingerprint, s0.fingerprint, "{name}: identity survives decompression");
            assert_same(&matrix, &read_back(&b));
            assert_same(&matrix, &read_back(&c));
        }
    }

    #[test]
    fn rechunking_band_heights_streams_every_chunk_once() {
        let matrix = dense(13);
        let a = tmp("rechunk_a.lamc2");
        let b = tmp("rechunk_b.lamc2");
        pack_matrix(&matrix, &a, 4).unwrap();
        let reader = StoreReader::open_with_cache(&a, 0).unwrap();
        repack_reader(&reader, &b, 32, None, Codec::None).unwrap();
        assert_eq!(
            reader.chunks_read() as usize,
            reader.n_chunks(),
            "sequential sweep reads each source chunk exactly once"
        );
        assert_same(&matrix, &read_back(&b));
    }

    #[test]
    fn tiled_to_tiled_regrid() {
        let matrix = sparse(14);
        let a = tmp("regrid_a.lamc3");
        let b = tmp("regrid_b.lamc3");
        pack_matrix_tiled(&matrix, &a, 6, 3).unwrap();
        let s = repack(
            &a,
            &b,
            &RepackOptions { chunk_rows: 9, chunk_cols: Some(7), cache_budget: 0, codec: Codec::None },
        )
        .unwrap();
        assert_eq!((s.chunk_rows, s.chunk_cols), (9, 7));
        assert_same(&matrix, &read_back(&b));
    }

    #[test]
    fn explicit_zero_entries_survive_repack() {
        // Repack must preserve the stored-entry structure, not just the
        // dense view: an explicitly stored 0.0 stays an entry.
        let path_a = tmp("zeros_a.lamc2");
        let path_b = tmp("zeros_b.lamc3");
        let mut w = ChunkWriter::create(&path_a, Layout::Csr, 5, 2).unwrap();
        w.append_sparse_row(&[(1, 0.0), (3, 2.0)]).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.append_sparse_row(&[(0, -1.0)]).unwrap();
        w.finish().unwrap();
        let s = repack(
            &path_a,
            &path_b,
            &RepackOptions { chunk_rows: 1, chunk_cols: Some(2), cache_budget: 0, codec: Codec::None },
        )
        .unwrap();
        assert_eq!(s.nnz, 3, "explicit zero kept");
        match read_back(&path_b) {
            Matrix::Sparse(got) => {
                assert_eq!(got.nnz(), 3);
                assert_eq!(got.to_dense().get(0, 3), 2.0);
                assert_eq!(got.to_dense().get(2, 0), -1.0);
            }
            _ => panic!("layout"),
        }
    }
}
