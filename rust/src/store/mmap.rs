//! Minimal read-only memory map (no new dependencies).
//!
//! The store read path maps a finished store file once and serves chunk
//! payloads as borrowed slices: uncompressed dense chunks decode with
//! **zero copies** (the cache holds a view into the map), and
//! compressed chunks decompress straight from the mapped bytes into the
//! pooled buffers — no intermediate read buffer either way.
//!
//! This wrapper declares `mmap`/`munmap` directly (libc is already
//! linked into every std binary on unix), keeps all the `unsafe` in one
//! ~60-line file, and degrades gracefully: [`Mmap::map`] returns `None`
//! on non-unix targets, on any mapping failure, on empty files, or when
//! `LAMC_NO_MMAP=1` — callers then use the pread-into-buffer fallback,
//! which is behaviorally identical (the property harness runs both).
//!
//! Safety model: LAMC store files are immutable once `finish()` has
//! fsynced them, and the reader maps a file only after validating its
//! footer. Truncating a mapped file out from under a running reader is
//! outside the contract (as it is for every mmap consumer).

#[cfg(unix)]
mod sys {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    use core::ffi::c_void;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// A whole-file read-only private mapping.
    pub struct Mmap {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is read-only and owned until Drop; sharing immutable
    // bytes across threads is safe.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        /// Map `len` bytes of `file` read-only. `None` on failure (the
        /// caller falls back to pread), on empty files, or when
        /// `LAMC_NO_MMAP=1` forces the fallback path.
        pub fn map(file: &File, len: usize) -> Option<Mmap> {
            if len == 0 || std::env::var_os("LAMC_NO_MMAP").is_some_and(|v| v == "1") {
                return None;
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            // MAP_FAILED is (void*)-1.
            if ptr as usize == usize::MAX || ptr.is_null() {
                None
            } else {
                Some(Mmap { ptr, len })
            }
        }

        pub fn as_slice(&self) -> &[u8] {
            // Valid for `len` bytes for the lifetime of the mapping.
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(not(unix))]
mod sys {
    use std::fs::File;

    /// Non-unix stub: mapping never succeeds, the reader uses pread.
    pub struct Mmap {
        never: core::convert::Infallible,
    }

    impl Mmap {
        pub fn map(_file: &File, _len: usize) -> Option<Mmap> {
            None
        }

        pub fn as_slice(&self) -> &[u8] {
            match self.never {}
        }
    }
}

pub(crate) use sys::Mmap;

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    #[test]
    fn maps_and_reads_back() {
        let dir = std::env::temp_dir().join("lamc_mmap_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bin");
        let bytes: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &bytes).unwrap();
        let file = std::fs::File::open(&path).unwrap();
        let map = Mmap::map(&file, bytes.len()).expect("mapping a real file succeeds");
        assert_eq!(map.as_slice(), &bytes[..]);
    }

    #[test]
    fn empty_file_declines() {
        let dir = std::env::temp_dir().join("lamc_mmap_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        let file = std::fs::File::open(&path).unwrap();
        assert!(Mmap::map(&file, 0).is_none());
    }
}
