//! On-disk layout of the LAMC chunked matrix store (row-band **LAMC2**
//! and tiled **LAMC3**).
//!
//! A store file is a single self-describing artifact:
//!
//! ```text
//! ┌──────────────┬────────────┬────────────┬───┬────────────┬───────────────────────────┐
//! │ magic        │ chunk 0    │ chunk 1    │ … │ chunk n-1  │ footer                    │
//! │ (8 bytes)    │ (payload)  │ (payload)  │   │ (payload)  │ header + index + trailer  │
//! └──────────────┴────────────┴────────────┴───┴────────────┴───────────────────────────┘
//! ```
//!
//! Two chunk geometries share that envelope:
//!
//! * **LAMC2 (version 1), row bands** — chunk `i` holds rows
//!   `[i·chunk_rows, min((i+1)·chunk_rows, rows))` across *all* columns.
//! * **LAMC3 (version 2), tiles** — chunks form a row-band × col-band
//!   grid: chunk `i` is the tile at row band `i / n_col_bands`, column
//!   band `i % n_col_bands` (row-band-major order), holding that band's
//!   rows restricted to columns `[col_lo, col_lo + cols)`. A
//!   column-heavy read touches only the column bands it needs instead
//!   of decoding whole rows — the access shape the paper's dynamic
//!   partition planner (§IV-B) generates.
//!
//! The footer — written last, which is what makes streaming ingest
//! possible — carries the header (dims, layout, chunk grid, content
//! fingerprint) and one [`ChunkMeta`] index entry per chunk (offset,
//! length, row/col range, stored-entry count, checksum). The trailer is
//! `footer_len : u64`, `footer_checksum : u64`, then the 8-byte footer
//! magic, so a reader finds the footer by seeking from the end.
//!
//! All integers are little-endian `u64`s; values are `f32` LE; CSR
//! column indices are `u32` LE (matching [`crate::matrix::CsrMatrix`]),
//! stored **tile-relative** in LAMC3 so every tile is independently
//! decodable. Checksums chain [`crate::rng::mix64`] over 8-byte words —
//! the same primitive behind `Matrix::fingerprint`, so the whole stack
//! shares one hashing scheme.
//!
//! **Footer revisions 3 and 4** add per-chunk payload compression to
//! the row-band and tiled geometries respectively: the header gains the
//! writer's [`Codec`](super::codec::Codec), and every index entry gains
//! a codec tag plus the uncompressed (`raw_len`) payload length. The
//! file magics stay per-geometry (`LAMC2*` for versions 1/3/5, `LAMC3*`
//! for 2/4/6), and a writer configured with `codec=none` emits exactly
//! the version-1/2 bytes — pre-codec files are byte-stable and every
//! pre-codec reader field keeps its meaning. Entry `checksum` always
//! covers the **stored** bytes (what is read off disk); the content
//! fingerprint chains the checksums of the **uncompressed** payloads,
//! so the same matrix has the same fingerprint under every codec and
//! recompression never invalidates service result-cache entries.
//!
//! **Footer revisions 5 and 6** make a store appendable: the header
//! gains an append `generation` (0 for a freshly packed file, bumped by
//! every [`ChunkWriter::append_to`](super::ChunkWriter::append_to)
//! session), and every index entry gains the checksum of its
//! **uncompressed** payload (`raw_checksum` — the fingerprint chain
//! input, so an appender can extend the content fingerprint without
//! re-reading old payloads) plus the generation that sealed it (`gen`).
//! Readers derive "dirty bands since generation G" straight from the
//! index. A generation footer always carries the codec fields too, and
//! pre-generation files decode with `generation = 0` throughout
//! (`raw_checksum` backfills from `checksum` when the chunk is stored
//! raw). Old readers see revisions 5/6 as `UnsupportedVersion`.
//!
//! Failure taxonomy is typed ([`StoreError`]): a reader distinguishes
//! "not a store at all", "store cut short" (e.g. an ingest that died
//! before `finish`), and "store damaged" (checksum/structure mismatch),
//! so callers can react differently to each (see `docs/STORE.md`).

use std::path::{Path, PathBuf};

use super::codec::Codec;
use crate::rng::mix64 as mix;

/// Leading file magic of a row-band (version 1) store.
pub const MAGIC: &[u8; 8] = b"LAMC2\0\0\0";
/// Leading file magic of a tiled (version 2) store.
pub const MAGIC_TILED: &[u8; 8] = b"LAMC3\0\0\0";
/// Trailing footer magic of a row-band store.
pub const FOOTER_MAGIC: &[u8; 8] = b"LAMC2FTR";
/// Trailing footer magic of a tiled store.
pub const FOOTER_MAGIC_TILED: &[u8; 8] = b"LAMC3FTR";
/// Format version of the row-band layout.
pub const VERSION: u64 = 1;
/// Format version of the tiled layout.
pub const VERSION_TILED: u64 = 2;
/// Format version of the row-band layout with codec fields.
pub const VERSION_CODEC: u64 = 3;
/// Format version of the tiled layout with codec fields.
pub const VERSION_TILED_CODEC: u64 = 4;
/// Format version of the row-band layout with codec + generation fields.
pub const VERSION_GEN: u64 = 5;
/// Format version of the tiled layout with codec + generation fields.
pub const VERSION_TILED_GEN: u64 = 6;
/// Default row-band height for writers that don't specify one. (There
/// is deliberately no tiled counterpart: a useful tile width tracks the
/// planner's block width ψ, so every tiled writer must choose one.)
pub const DEFAULT_CHUNK_ROWS: usize = 256;

/// Bytes of the fixed trailer: `footer_len`, `footer_checksum`, magic.
pub const TRAILER_BYTES: u64 = 24;
/// Words of a version-1 encoded header.
const HEADER_WORDS_V1: usize = 8;
/// Words of a version-2 encoded header (adds `chunk_cols`).
const HEADER_WORDS_V2: usize = 9;
/// Words of a version-1 index entry.
const ENTRY_WORDS_V1: usize = 6;
/// Words of a version-2 index entry (adds `col_lo`, `cols`).
const ENTRY_WORDS_V2: usize = 8;
/// Extra header words in a codec revision (the writer codec).
const HEADER_CODEC_WORDS: usize = 1;
/// Extra entry words in a codec revision (`codec` tag, `raw_len`).
const ENTRY_CODEC_WORDS: usize = 2;
/// Extra header words in a generation revision (the append generation).
const HEADER_GEN_WORDS: usize = 1;
/// Extra entry words in a generation revision (`raw_checksum`, `gen`).
const ENTRY_GEN_WORDS: usize = 2;

/// Per-version footer geometry:
/// `(tiled, has_codec, has_gen, header_words, entry_words)`.
fn version_shape(version: u64) -> Option<(bool, bool, bool, usize, usize)> {
    match version {
        VERSION => Some((false, false, false, HEADER_WORDS_V1, ENTRY_WORDS_V1)),
        VERSION_TILED => Some((true, false, false, HEADER_WORDS_V2, ENTRY_WORDS_V2)),
        VERSION_CODEC => Some((
            false,
            true,
            false,
            HEADER_WORDS_V1 + HEADER_CODEC_WORDS,
            ENTRY_WORDS_V1 + ENTRY_CODEC_WORDS,
        )),
        VERSION_TILED_CODEC => Some((
            true,
            true,
            false,
            HEADER_WORDS_V2 + HEADER_CODEC_WORDS,
            ENTRY_WORDS_V2 + ENTRY_CODEC_WORDS,
        )),
        VERSION_GEN => Some((
            false,
            true,
            true,
            HEADER_WORDS_V1 + HEADER_CODEC_WORDS + HEADER_GEN_WORDS,
            ENTRY_WORDS_V1 + ENTRY_CODEC_WORDS + ENTRY_GEN_WORDS,
        )),
        VERSION_TILED_GEN => Some((
            true,
            true,
            true,
            HEADER_WORDS_V2 + HEADER_CODEC_WORDS + HEADER_GEN_WORDS,
            ENTRY_WORDS_V2 + ENTRY_CODEC_WORDS + ENTRY_GEN_WORDS,
        )),
        _ => None,
    }
}

/// Storage layout of the chunk payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// Row-major dense `f32`: payload is `rows·cols` values (the
    /// chunk's own `rows`/`cols`, i.e. the tile shape in LAMC3).
    Dense,
    /// CSR chunk: payload is `(rows+1)` relative `u64` row pointers,
    /// then `nnz` `u32` column indices (chunk-relative), then `nnz`
    /// `f32` values.
    Csr,
}

impl Layout {
    pub fn tag(self) -> u64 {
        match self {
            Layout::Dense => 1,
            Layout::Csr => 2,
        }
    }

    pub fn from_tag(tag: u64) -> Option<Layout> {
        match tag {
            1 => Some(Layout::Dense),
            2 => Some(Layout::Csr),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Layout::Dense => "dense",
            Layout::Csr => "csr",
        }
    }
}

/// Decoded store header (the self-description part of the footer).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreHeader {
    /// [`VERSION`] (row bands) or [`VERSION_TILED`] (tiles).
    pub version: u64,
    pub layout: Layout,
    pub rows: usize,
    pub cols: usize,
    /// Stored entries across all chunks (dense: `rows·cols`).
    pub nnz: u64,
    /// Row-band height; every band but the last spans exactly this many rows.
    pub chunk_rows: usize,
    /// Column-band width. Row-band stores carry `cols` here (one column
    /// band spanning the whole width), so grid arithmetic never branches
    /// on version.
    pub chunk_cols: usize,
    pub n_chunks: usize,
    /// Content fingerprint over (layout, dims, nnz, per-chunk checksums
    /// of the **uncompressed** payloads) — or, for a repacked store,
    /// the source store's fingerprint carried over verbatim (same
    /// content, different chunking or codec). O(1) to read back —
    /// registering a store-backed matrix never re-scans the data
    /// (unlike `Matrix::fingerprint`).
    pub fingerprint: u64,
    /// Codec the writer was configured with. Individual chunks may
    /// still be [`Codec::None`] (incompressible payloads are stored
    /// raw); versions 1/2 are always `Codec::None`.
    pub codec: Codec,
    /// Append generation: 0 for a freshly packed store, bumped by each
    /// append session. Pre-generation footer revisions decode as 0.
    pub generation: u64,
}

impl StoreHeader {
    /// Is this the tiled (LAMC3) geometry?
    pub fn is_tiled(&self) -> bool {
        matches!(self.version, VERSION_TILED | VERSION_TILED_CODEC | VERSION_TILED_GEN)
    }

    /// Row bands in the chunk grid.
    pub fn n_row_bands(&self) -> usize {
        if self.rows == 0 || self.chunk_rows == 0 {
            0
        } else {
            self.rows.div_ceil(self.chunk_rows)
        }
    }

    /// Column bands per row band (1 for row-band stores).
    pub fn n_col_bands(&self) -> usize {
        if self.cols == 0 || self.chunk_cols == 0 {
            1
        } else {
            self.cols.div_ceil(self.chunk_cols)
        }
    }
}

/// Index entry for one chunk (a row band in LAMC2, a tile in LAMC3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Byte offset of the payload from the start of the file.
    pub offset: u64,
    /// **Stored** payload length in bytes (compressed size when
    /// `codec != None`).
    pub len: u64,
    /// First global row covered by this chunk.
    pub row_lo: usize,
    /// Rows in this chunk (`chunk_rows` except possibly the last band).
    pub rows: usize,
    /// First global column covered by this chunk (0 in LAMC2).
    pub col_lo: usize,
    /// Columns in this chunk (the full width in LAMC2).
    pub cols: usize,
    /// Stored entries in this chunk.
    pub nnz: u64,
    /// `checksum_bytes` of the **stored** payload bytes.
    pub checksum: u64,
    /// How this chunk's payload is encoded on disk.
    pub codec: Codec,
    /// Uncompressed payload length; equals `len` when `codec == None`.
    pub raw_len: u64,
    /// `checksum_bytes` of the **uncompressed** payload — the
    /// fingerprint chain input. Equals `checksum` when the chunk is
    /// stored raw; 0 ("unknown") when decoding a pre-generation footer
    /// whose chunk is compressed.
    pub raw_checksum: u64,
    /// Append generation that sealed this chunk (0 in pre-generation
    /// footers). A chunk is dirty relative to base generation G when
    /// `gen > G`.
    pub gen: u64,
}

/// Typed store failures. Returned inside `anyhow::Error` so callers can
/// `downcast_ref::<StoreError>()` and branch on the kind.
#[derive(Debug)]
pub enum StoreError {
    /// The file does not start with a LAMC store magic (or is too short to).
    NotAStore(PathBuf),
    /// The file starts like a store but ends before a valid footer —
    /// typical of an ingest that died before `finish()` or a partial copy.
    Truncated { path: PathBuf, detail: String },
    /// Structure or checksum mismatch: the file is complete but damaged.
    Corrupt { path: PathBuf, detail: String },
    /// Footer declares a format version this build cannot read.
    UnsupportedVersion { path: PathBuf, version: u64 },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::NotAStore(p) => write!(f, "not a LAMC store: {p:?}"),
            StoreError::Truncated { path, detail } => {
                write!(f, "truncated LAMC store {path:?}: {detail}")
            }
            StoreError::Corrupt { path, detail } => {
                write!(f, "corrupt LAMC store {path:?}: {detail}")
            }
            StoreError::UnsupportedVersion { path, version } => {
                write!(f, "LAMC store {path:?} has unsupported version {version}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Checksum a byte slice: a [`mix`] chain over the length and each
/// little-endian 8-byte word (zero-padded tail). Deterministic across
/// platforms; sensitive to any bit flip and to length changes.
pub fn checksum_bytes(bytes: &[u8]) -> u64 {
    let mut h = mix(0x4C41_4D43_4353_554D, bytes.len() as u64);
    let mut words = bytes.chunks_exact(8);
    for w in words.by_ref() {
        h = mix(h, u64::from_le_bytes([w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]]));
    }
    let rem = words.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        h = mix(h, u64::from_le_bytes(tail));
    }
    h
}

/// Store content fingerprint: layout, dims, nnz, and every chunk
/// checksum, chained in order. Cheap to compute at `finish()` (the
/// writer already has the chunk checksums) and O(1) to read back from
/// the header. Deliberately *not* the same chain as
/// `Matrix::fingerprint`: in-memory and store-backed registrations take
/// different execution paths, and the cache key reflects that (the same
/// argument that separates dense from CSR fingerprints). `repack`
/// carries the source fingerprint forward instead of recomputing, so
/// re-chunking never invalidates result-cache entries.
pub fn store_fingerprint(
    layout: Layout,
    rows: usize,
    cols: usize,
    nnz: u64,
    chunk_checksums: impl IntoIterator<Item = u64>,
) -> u64 {
    let mut h = mix(0x4C41_4D43_0000_0005, layout.tag());
    h = mix(h, rows as u64);
    h = mix(h, cols as u64);
    h = mix(h, nnz);
    for c in chunk_checksums {
        h = mix(h, c);
    }
    h
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn word(bytes: &[u8], i: usize) -> u64 {
    let b = &bytes[i * 8..i * 8 + 8];
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Encode the footer body (header words then index entries). Version 1
/// emits the exact LAMC2 byte layout (row-band fields only); version 2
/// adds `chunk_cols` to the header and `col_lo`/`cols` to each entry;
/// versions 3/4 append the writer codec to the header and
/// `codec`/`raw_len` to each entry; versions 5/6 additionally append
/// the append generation to the header and `raw_checksum`/`gen` to
/// each entry. A `codec=none` writer uses version 1/2, so pre-codec
/// files stay byte-stable.
pub fn encode_footer(header: &StoreHeader, index: &[ChunkMeta]) -> Vec<u8> {
    debug_assert_eq!(header.n_chunks, index.len());
    let (tiled, has_codec, has_gen, header_words, entry_words) =
        version_shape(header.version).expect("writer uses a known footer version");
    let _ = tiled;
    debug_assert!(
        has_codec
            || (header.codec == Codec::None
                && index.iter().all(|e| e.codec == Codec::None && e.raw_len == e.len)),
        "codec fields require a codec footer revision"
    );
    debug_assert!(
        has_gen || (header.generation == 0 && index.iter().all(|e| e.gen == 0)),
        "generation fields require a generation footer revision"
    );
    let mut out = Vec::with_capacity((header_words + entry_words * index.len()) * 8);
    push_u64(&mut out, header.version);
    push_u64(&mut out, header.layout.tag());
    push_u64(&mut out, header.rows as u64);
    push_u64(&mut out, header.cols as u64);
    push_u64(&mut out, header.chunk_rows as u64);
    if tiled {
        push_u64(&mut out, header.chunk_cols as u64);
    }
    push_u64(&mut out, header.nnz);
    push_u64(&mut out, index.len() as u64);
    push_u64(&mut out, header.fingerprint);
    if has_codec {
        push_u64(&mut out, header.codec.tag());
    }
    if has_gen {
        push_u64(&mut out, header.generation);
    }
    for e in index {
        push_u64(&mut out, e.offset);
        push_u64(&mut out, e.len);
        push_u64(&mut out, e.row_lo as u64);
        push_u64(&mut out, e.rows as u64);
        if tiled {
            push_u64(&mut out, e.col_lo as u64);
            push_u64(&mut out, e.cols as u64);
        }
        push_u64(&mut out, e.nnz);
        push_u64(&mut out, e.checksum);
        if has_codec {
            push_u64(&mut out, e.codec.tag());
            push_u64(&mut out, e.raw_len);
        }
        if has_gen {
            push_u64(&mut out, e.raw_checksum);
            push_u64(&mut out, e.gen);
        }
    }
    out
}

/// Decode and validate a footer body read back from disk.
///
/// `payload_end` is the byte offset where the footer starts (i.e. where
/// chunk payloads must end); chunk extents are checked against it.
pub fn decode_footer(
    bytes: &[u8],
    payload_end: u64,
    path: &Path,
) -> Result<(StoreHeader, Vec<ChunkMeta>), StoreError> {
    let corrupt = |detail: String| StoreError::Corrupt { path: path.to_path_buf(), detail };
    if bytes.len() < HEADER_WORDS_V1 * 8 || bytes.len() % 8 != 0 {
        return Err(corrupt(format!("footer body has {} bytes", bytes.len())));
    }
    let version = word(bytes, 0);
    let Some((tiled, has_codec, has_gen, header_words, entry_words)) = version_shape(version)
    else {
        return Err(StoreError::UnsupportedVersion { path: path.to_path_buf(), version });
    };
    if bytes.len() < header_words * 8 {
        return Err(corrupt(format!("footer body has {} bytes", bytes.len())));
    }
    let layout = Layout::from_tag(word(bytes, 1))
        .ok_or_else(|| corrupt(format!("unknown layout tag {}", word(bytes, 1))))?;
    let rows = word(bytes, 2) as usize;
    let cols = word(bytes, 3) as usize;
    let chunk_rows = word(bytes, 4) as usize;
    let mut w = 5;
    let chunk_cols = if tiled {
        w += 1;
        word(bytes, 5) as usize
    } else {
        cols
    };
    let nnz = word(bytes, w);
    let n_chunks = word(bytes, w + 1) as usize;
    let fingerprint = word(bytes, w + 2);
    let codec = if has_codec {
        Codec::from_tag(word(bytes, w + 3))
            .ok_or_else(|| corrupt(format!("unknown codec tag {}", word(bytes, w + 3))))?
    } else {
        Codec::None
    };
    let generation = if has_gen { word(bytes, w + 4) } else { 0 };

    // Bound n_chunks by what the body could possibly hold before doing
    // size arithmetic with it (a crafted count must not overflow).
    if n_chunks > bytes.len() / (entry_words * 8)
        || bytes.len() != (header_words + entry_words * n_chunks) * 8
    {
        return Err(corrupt(format!(
            "footer declares {n_chunks} chunks but body has {} bytes",
            bytes.len()
        )));
    }
    if (chunk_rows == 0 || (tiled && chunk_cols == 0)) && n_chunks > 0 {
        return Err(corrupt("zero chunk extent with chunks present".into()));
    }

    let header = StoreHeader {
        version,
        layout,
        rows,
        cols,
        nnz,
        chunk_rows,
        chunk_cols,
        n_chunks,
        fingerprint,
        codec,
        generation,
    };
    let n_col_bands = header.n_col_bands();
    // checked_mul: crafted dims must not overflow the grid arithmetic.
    if tiled && n_chunks > 0 && header.n_row_bands().checked_mul(n_col_bands) != Some(n_chunks) {
        return Err(corrupt(format!(
            "tiled footer declares {n_chunks} chunks for a {}x{} grid",
            header.n_row_bands(),
            n_col_bands
        )));
    }

    let mut index = Vec::with_capacity(n_chunks);
    let mut covered_rows = 0usize;
    let mut covered_nnz = 0u64;
    for i in 0..n_chunks {
        let base = header_words + entry_words * i;
        let mut e = if tiled {
            ChunkMeta {
                offset: word(bytes, base),
                len: word(bytes, base + 1),
                row_lo: word(bytes, base + 2) as usize,
                rows: word(bytes, base + 3) as usize,
                col_lo: word(bytes, base + 4) as usize,
                cols: word(bytes, base + 5) as usize,
                nnz: word(bytes, base + 6),
                checksum: word(bytes, base + 7),
                codec: Codec::None,
                raw_len: 0,
                raw_checksum: 0,
                gen: 0,
            }
        } else {
            ChunkMeta {
                offset: word(bytes, base),
                len: word(bytes, base + 1),
                row_lo: word(bytes, base + 2) as usize,
                rows: word(bytes, base + 3) as usize,
                col_lo: 0,
                cols,
                nnz: word(bytes, base + 4),
                checksum: word(bytes, base + 5),
                codec: Codec::None,
                raw_len: 0,
                raw_checksum: 0,
                gen: 0,
            }
        };
        let gen_words = if has_gen { ENTRY_GEN_WORDS } else { 0 };
        if has_codec {
            let cbase = base + entry_words - gen_words - ENTRY_CODEC_WORDS;
            e.codec = Codec::from_tag(word(bytes, cbase))
                .ok_or_else(|| corrupt(format!("chunk {i}: unknown codec tag {}", word(bytes, cbase))))?;
            e.raw_len = word(bytes, cbase + 1);
        } else {
            e.raw_len = e.len;
        }
        if has_gen {
            let gbase = base + entry_words - ENTRY_GEN_WORDS;
            e.raw_checksum = word(bytes, gbase);
            e.gen = word(bytes, gbase + 1);
            if e.gen > generation {
                return Err(corrupt(format!(
                    "chunk {i} sealed at generation {} but header is at {generation}",
                    e.gen
                )));
            }
            if e.codec == Codec::None && e.raw_checksum != e.checksum {
                return Err(corrupt(format!(
                    "chunk {i} stored raw but raw_checksum {:#x} != checksum {:#x}",
                    e.raw_checksum, e.checksum
                )));
            }
        } else if e.codec == Codec::None {
            // Raw chunks store exactly their uncompressed bytes, so the
            // stored checksum doubles as the fingerprint chain input.
            e.raw_checksum = e.checksum;
        }
        if e.codec == Codec::None && e.raw_len != e.len {
            return Err(corrupt(format!(
                "chunk {i} stored raw but declares raw_len {} != len {}",
                e.raw_len, e.len
            )));
        }
        if e.codec != Codec::None && e.len >= e.raw_len {
            // The writer only keeps a compressed form when it is
            // strictly smaller; an inflating "compressed" chunk is
            // either damage or a crafted decompression bomb setup.
            return Err(corrupt(format!(
                "chunk {i} compressed to {} bytes, not smaller than raw {}",
                e.len, e.raw_len
            )));
        }
        if e.offset < MAGIC.len() as u64 || e.offset.saturating_add(e.len) > payload_end {
            return Err(corrupt(format!(
                "chunk {i} extent [{}, {}) escapes payload region [8, {payload_end})",
                e.offset,
                e.offset.saturating_add(e.len)
            )));
        }
        if tiled {
            // Exact grid check: tile i sits at row band i / n_col_bands,
            // column band i % n_col_bands, in row-band-major order.
            let rb = i / n_col_bands;
            let cb = i % n_col_bands;
            let want_row_lo = rb * chunk_rows;
            let want_col_lo = cb * chunk_cols;
            let want_rows = chunk_rows.min(rows.saturating_sub(want_row_lo));
            let want_cols = chunk_cols.min(cols.saturating_sub(want_col_lo));
            if e.row_lo != want_row_lo
                || e.rows != want_rows
                || e.col_lo != want_col_lo
                || e.cols != want_cols
                || e.rows == 0
                || e.cols == 0
            {
                return Err(corrupt(format!(
                    "tile {i} covers rows [{}, {}) cols [{}, {}) — not grid cell ({rb}, {cb})",
                    e.row_lo,
                    e.row_lo.saturating_add(e.rows),
                    e.col_lo,
                    e.col_lo.saturating_add(e.cols)
                )));
            }
            // Count each row band's height once (at its first tile).
            if cb == 0 {
                covered_rows = covered_rows.saturating_add(e.rows);
            }
        } else {
            if Some(e.row_lo) != i.checked_mul(chunk_rows) || e.rows == 0 || e.rows > chunk_rows {
                return Err(corrupt(format!(
                    "chunk {i} covers rows [{}, {}) — not a {chunk_rows}-row band",
                    e.row_lo,
                    e.row_lo.saturating_add(e.rows)
                )));
            }
            covered_rows = covered_rows.saturating_add(e.rows);
        }
        // Saturating accumulators: a crafted footer must fail the
        // coverage comparisons below, never wrap or panic here.
        covered_nnz = covered_nnz.saturating_add(e.nnz);
        index.push(e);
    }
    if covered_rows != rows {
        return Err(corrupt(format!("chunks cover {covered_rows} rows, header says {rows}")));
    }
    if covered_nnz != nnz {
        return Err(corrupt(format!("chunks hold {covered_nnz} entries, header says {nnz}")));
    }

    // Chunk extents must be pairwise disjoint, not just inside the
    // payload region: a crafted footer aliasing two index entries onto
    // one extent (or overlapping extents) would otherwise decode
    // cleanly and silently serve the wrong bytes for one of them.
    // Sort a shadow of (offset, len, i) and check adjacent pairs.
    let mut extents: Vec<(u64, u64, usize)> =
        index.iter().enumerate().map(|(i, e)| (e.offset, e.len, i)).collect();
    extents.sort_unstable();
    for pair in extents.windows(2) {
        let (a_off, a_len, a_i) = pair[0];
        let (b_off, _, b_i) = pair[1];
        if a_off.saturating_add(a_len) > b_off {
            return Err(corrupt(format!(
                "chunk {a_i} extent [{a_off}, {}) overlaps chunk {b_i} at offset {b_off}",
                a_off.saturating_add(a_len)
            )));
        }
    }

    Ok((header, index))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header(n_chunks: usize) -> (StoreHeader, Vec<ChunkMeta>) {
        let mut index = Vec::new();
        let mut offset = 8u64;
        for i in 0..n_chunks {
            index.push(ChunkMeta {
                offset,
                len: 40,
                row_lo: i * 2,
                rows: 2,
                col_lo: 0,
                cols: 7,
                nnz: 10,
                checksum: 0xABC0 + i as u64,
                codec: Codec::None,
                raw_len: 40,
                raw_checksum: 0xABC0 + i as u64,
                gen: 0,
            });
            offset += 40;
        }
        let h = StoreHeader {
            version: VERSION,
            layout: Layout::Csr,
            rows: n_chunks * 2,
            cols: 7,
            nnz: 10 * n_chunks as u64,
            chunk_rows: 2,
            chunk_cols: 7,
            n_chunks,
            fingerprint: store_fingerprint(
                Layout::Csr,
                n_chunks * 2,
                7,
                10 * n_chunks as u64,
                index.iter().map(|e| e.checksum),
            ),
            codec: Codec::None,
            generation: 0,
        };
        (h, index)
    }

    /// A 2×2 tile grid over a 5×5 dense matrix (3-row / 3-col bands).
    fn tiled_header() -> (StoreHeader, Vec<ChunkMeta>) {
        let mut index = Vec::new();
        let mut offset = 8u64;
        let grid = [
            (0usize, 3usize, 0usize, 3usize),
            (0, 3, 3, 2),
            (3, 2, 0, 3),
            (3, 2, 3, 2),
        ];
        for (i, &(row_lo, rows, col_lo, cols)) in grid.iter().enumerate() {
            let nnz = (rows * cols) as u64;
            index.push(ChunkMeta {
                offset,
                len: nnz * 4,
                row_lo,
                rows,
                col_lo,
                cols,
                nnz,
                checksum: 0xF00 + i as u64,
                codec: Codec::None,
                raw_len: nnz * 4,
                raw_checksum: 0xF00 + i as u64,
                gen: 0,
            });
            offset += nnz * 4;
        }
        let h = StoreHeader {
            version: VERSION_TILED,
            layout: Layout::Dense,
            rows: 5,
            cols: 5,
            nnz: 25,
            chunk_rows: 3,
            chunk_cols: 3,
            n_chunks: 4,
            fingerprint: store_fingerprint(
                Layout::Dense,
                5,
                5,
                25,
                index.iter().map(|e| e.checksum),
            ),
            codec: Codec::None,
            generation: 0,
        };
        (h, index)
    }

    fn payload_end(index: &[ChunkMeta]) -> u64 {
        index.last().map(|e| e.offset + e.len).unwrap_or(8)
    }

    #[test]
    fn footer_round_trip() {
        let (h, index) = header(3);
        let bytes = encode_footer(&h, &index);
        let (h2, index2) = decode_footer(&bytes, 8 + 3 * 40, Path::new("/t")).unwrap();
        assert_eq!(h, h2);
        assert_eq!(index, index2);
    }

    #[test]
    fn tiled_footer_round_trip() {
        let (h, index) = tiled_header();
        let bytes = encode_footer(&h, &index);
        let (h2, index2) = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap();
        assert_eq!(h, h2);
        assert_eq!(index, index2);
        assert!(h2.is_tiled());
        assert_eq!((h2.n_row_bands(), h2.n_col_bands()), (2, 2));
    }

    #[test]
    fn tiled_footer_rejects_grid_violations() {
        let (h, mut index) = tiled_header();
        index[1].col_lo = 2; // tile (0,1) must start at column 3
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn tiled_footer_rejects_wrong_chunk_count() {
        let (mut h, mut index) = tiled_header();
        index.pop();
        h.n_chunks = 3;
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn v1_decode_fills_implicit_column_band() {
        let (h, index) = header(2);
        let bytes = encode_footer(&h, &index);
        let (h2, index2) = decode_footer(&bytes, 8 + 2 * 40, Path::new("/t")).unwrap();
        assert!(!h2.is_tiled());
        assert_eq!(h2.chunk_cols, h2.cols, "one column band spans the width");
        assert_eq!(h2.n_col_bands(), 1);
        assert!(index2.iter().all(|e| e.col_lo == 0 && e.cols == 7));
    }

    #[test]
    fn decode_rejects_bad_extents() {
        let (h, mut index) = header(2);
        index[1].len = 1 << 40; // escapes the payload region
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, 8 + 2 * 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn decode_rejects_row_coverage_mismatch() {
        let (mut h, index) = header(2);
        h.rows = 99;
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, 8 + 2 * 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn decode_rejects_future_version() {
        let (h, index) = header(1);
        let mut bytes = encode_footer(&h, &index);
        bytes[..8].copy_from_slice(&999u64.to_le_bytes());
        let err = decode_footer(&bytes, 8 + 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::UnsupportedVersion { version: 999, .. }), "{err}");
    }

    #[test]
    fn v1_encoding_is_byte_stable() {
        // LAMC2 files written before the tiled layout existed must keep
        // decoding: version 1 encodes exactly the historical byte layout
        // (8 header words, 6 entry words — no column fields).
        let (h, index) = header(2);
        let bytes = encode_footer(&h, &index);
        assert_eq!(bytes.len(), (8 + 6 * 2) * 8);
        let (h2, _) = decode_footer(&bytes, 8 + 2 * 40, Path::new("/t")).unwrap();
        assert_eq!(h2.version, VERSION);
    }

    /// Rewrite a v1/v2 header+index into its codec revision with
    /// chunk 1 stored shuffle-lz-compressed.
    fn with_codec(mut h: StoreHeader, mut index: Vec<ChunkMeta>) -> (StoreHeader, Vec<ChunkMeta>) {
        h.version = if h.is_tiled() { VERSION_TILED_CODEC } else { VERSION_CODEC };
        h.codec = Codec::ShuffleLz;
        // Compress chunk 1 to half its raw bytes and shift the later
        // extents down so the payload region stays contiguous.
        let shrink = index[1].len / 2;
        index[1].codec = Codec::ShuffleLz;
        index[1].len -= shrink;
        // Pre-generation footers don't carry raw checksums for
        // compressed chunks; decode reports "unknown" (0).
        index[1].raw_checksum = 0;
        for e in index.iter_mut().skip(2) {
            e.offset -= shrink;
        }
        (h, index)
    }

    /// Rewrite a codec-revision header+index into its generation
    /// revision, as a two-append store (generations 0, 1, 2).
    fn with_gen(mut h: StoreHeader, mut index: Vec<ChunkMeta>) -> (StoreHeader, Vec<ChunkMeta>) {
        h.version = if h.is_tiled() { VERSION_TILED_GEN } else { VERSION_GEN };
        h.generation = 2;
        for (i, e) in index.iter_mut().enumerate() {
            e.raw_checksum = if e.codec == Codec::None { e.checksum } else { 0xBEEF + i as u64 };
            e.gen = (i as u64).min(2);
        }
        (h, index)
    }

    #[test]
    fn codec_footer_round_trips_both_geometries() {
        for (h0, i0) in [header(3), tiled_header()] {
            let (h, index) = with_codec(h0, i0);
            let bytes = encode_footer(&h, &index);
            let (h2, index2) = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap();
            assert_eq!(h, h2);
            assert_eq!(index, index2);
            assert_eq!(h2.codec, Codec::ShuffleLz);
            assert_eq!(index2[1].codec, Codec::ShuffleLz);
            assert!(index2[1].raw_len > index2[1].len);
            assert_eq!(index2[0].codec, Codec::None, "per-chunk raw fallback survives");
        }
        let (h, _) = with_codec(tiled_header().0, tiled_header().1);
        assert!(h.is_tiled(), "version 4 is still the tiled geometry");
    }

    #[test]
    fn codec_footer_rejects_unknown_codec_tag() {
        let (h, index) = with_codec(header(3).0, header(3).1);
        let mut bytes = encode_footer(&h, &index);
        // Header codec word is word 8 in a v3 footer (after fingerprint).
        bytes[8 * 8..9 * 8].copy_from_slice(&77u64.to_le_bytes());
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn codec_footer_rejects_inflating_compressed_chunk() {
        let (h, mut index) = with_codec(header(3).0, header(3).1);
        index[1].raw_len = index[1].len; // "compressed" but not smaller
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn generation_footer_round_trips_both_geometries() {
        for (h0, i0) in [header(3), tiled_header()] {
            let (hc, ic) = with_codec(h0, i0);
            let (h, index) = with_gen(hc, ic);
            let bytes = encode_footer(&h, &index);
            let (h2, index2) = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap();
            assert_eq!(h, h2);
            assert_eq!(index, index2);
            assert_eq!(h2.generation, 2);
            assert_eq!(index2[0].gen, 0);
            assert_eq!(index2[2].gen, 2);
            assert_eq!(index2[0].raw_checksum, index2[0].checksum, "raw chunk");
            assert_eq!(index2[1].raw_checksum, 0xBEEF + 1, "compressed chunk keeps raw checksum");
        }
        let (h, _) = with_gen(with_codec(tiled_header().0, tiled_header().1).0, vec![]);
        assert!(h.is_tiled(), "version 6 is still the tiled geometry");
    }

    #[test]
    fn generation_footer_rejects_entry_from_the_future() {
        let (hc, ic) = with_codec(header(3).0, header(3).1);
        let (h, mut index) = with_gen(hc, ic);
        index[0].gen = h.generation + 1;
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
        assert!(format!("{err}").contains("generation"), "{err}");
    }

    #[test]
    fn generation_footer_rejects_raw_checksum_mismatch_on_raw_chunk() {
        let (hc, ic) = with_codec(header(3).0, header(3).1);
        let (h, mut index) = with_gen(hc, ic);
        index[0].raw_checksum ^= 1; // raw chunk: must equal stored checksum
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn pre_generation_footers_decode_with_generation_zero() {
        let (h, index) = with_codec(header(3).0, header(3).1);
        let bytes = encode_footer(&h, &index);
        let (h2, index2) = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap();
        assert_eq!(h2.generation, 0);
        assert!(index2.iter().all(|e| e.gen == 0));
        assert_eq!(index2[0].raw_checksum, index2[0].checksum);
        assert_eq!(index2[1].raw_checksum, 0, "compressed pre-gen chunk: unknown");
    }

    #[test]
    fn decode_rejects_overlapping_extents() {
        // Chunk 1 shifted to overlap chunk 0's tail byte: both extents
        // are individually inside the payload region, so only the
        // pairwise-disjointness check can catch this.
        let (h, mut index) = header(3);
        index[1].offset -= 1;
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, payload_end(&index), Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
        let msg = format!("{err}");
        assert!(msg.contains("overlaps"), "{msg}");
    }

    #[test]
    fn decode_rejects_aliased_extents() {
        // Two index entries pointing at the same payload extent — reads
        // of chunk 2 would silently serve chunk 0's bytes.
        let (h, mut index) = header(3);
        index[2].offset = index[0].offset;
        index[2].len = index[0].len;
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, 8 + 3 * 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn checksum_sensitivity() {
        let a = checksum_bytes(b"hello world");
        assert_eq!(a, checksum_bytes(b"hello world"), "deterministic");
        assert_ne!(a, checksum_bytes(b"hello worlc"), "bit flip");
        assert_ne!(a, checksum_bytes(b"hello world\0"), "length change");
        assert_ne!(checksum_bytes(b""), checksum_bytes(b"\0"), "padding not confusable");
    }

    #[test]
    fn fingerprint_covers_every_input() {
        let base = store_fingerprint(Layout::Dense, 4, 5, 20, [1, 2]);
        assert_ne!(base, store_fingerprint(Layout::Csr, 4, 5, 20, [1, 2]));
        assert_ne!(base, store_fingerprint(Layout::Dense, 5, 4, 20, [1, 2]));
        assert_ne!(base, store_fingerprint(Layout::Dense, 4, 5, 21, [1, 2]));
        assert_ne!(base, store_fingerprint(Layout::Dense, 4, 5, 20, [2, 1]));
        assert_eq!(base, store_fingerprint(Layout::Dense, 4, 5, 20, vec![1, 2]));
    }
}
