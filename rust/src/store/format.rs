//! On-disk layout of the LAMC2 chunked matrix store.
//!
//! A store file is a single self-describing artifact:
//!
//! ```text
//! ┌──────────────┬────────────┬────────────┬───┬────────────┬───────────────────────────┐
//! │ magic LAMC2  │ chunk 0    │ chunk 1    │ … │ chunk n-1  │ footer                    │
//! │ (8 bytes)    │ (payload)  │ (payload)  │   │ (payload)  │ header + index + trailer  │
//! └──────────────┴────────────┴────────────┴───┴────────────┴───────────────────────────┘
//! ```
//!
//! Chunks are fixed-height **row bands**: chunk `i` holds rows
//! `[i·chunk_rows, min((i+1)·chunk_rows, rows))` in the matrix's own
//! storage order (dense row-major or CSR). The footer — written last,
//! which is what makes streaming ingest possible — carries the header
//! (dims, layout, chunk height, content fingerprint) and one
//! [`ChunkMeta`] index entry per chunk (offset, length, row range,
//! stored-entry count, checksum). The trailer is `footer_len : u64`,
//! `footer_checksum : u64`, then the 8-byte footer magic, so a reader
//! finds the footer by seeking from the end.
//!
//! All integers are little-endian `u64`s; values are `f32` LE; CSR
//! column indices are `u32` LE (matching [`crate::matrix::CsrMatrix`]).
//! Checksums chain [`crate::rng::mix64`] over 8-byte words — the same
//! primitive behind `Matrix::fingerprint`, so the whole stack shares one
//! hashing scheme.
//!
//! Failure taxonomy is typed ([`StoreError`]): a reader distinguishes
//! "not a store at all", "store cut short" (e.g. an ingest that died
//! before `finish`), and "store damaged" (checksum/structure mismatch),
//! so callers can react differently to each (see `docs/STORE.md`).

use std::path::{Path, PathBuf};

use crate::rng::mix64 as mix;

/// Leading file magic (8 bytes).
pub const MAGIC: &[u8; 8] = b"LAMC2\0\0\0";
/// Trailing footer magic (8 bytes).
pub const FOOTER_MAGIC: &[u8; 8] = b"LAMC2FTR";
/// Current format version.
pub const VERSION: u64 = 1;
/// Default row-band height for writers that don't specify one.
pub const DEFAULT_CHUNK_ROWS: usize = 256;

/// Bytes of the fixed trailer: `footer_len`, `footer_checksum`, magic.
pub const TRAILER_BYTES: u64 = 24;
/// Bytes of one encoded header (8 words).
const HEADER_WORDS: usize = 8;
/// Bytes of one encoded index entry (6 words).
const ENTRY_WORDS: usize = 6;

/// Storage layout of the chunk payloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// Row-major dense `f32`: payload is `rows·cols` values.
    Dense,
    /// CSR band: payload is `(rows+1)` relative `u64` row pointers, then
    /// `nnz` `u32` column indices, then `nnz` `f32` values.
    Csr,
}

impl Layout {
    pub fn tag(self) -> u64 {
        match self {
            Layout::Dense => 1,
            Layout::Csr => 2,
        }
    }

    pub fn from_tag(tag: u64) -> Option<Layout> {
        match tag {
            1 => Some(Layout::Dense),
            2 => Some(Layout::Csr),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Layout::Dense => "dense",
            Layout::Csr => "csr",
        }
    }
}

/// Decoded store header (the self-description part of the footer).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreHeader {
    pub layout: Layout,
    pub rows: usize,
    pub cols: usize,
    /// Stored entries across all chunks (dense: `rows·cols`).
    pub nnz: u64,
    /// Row-band height; every chunk but the last holds exactly this many rows.
    pub chunk_rows: usize,
    pub n_chunks: usize,
    /// Content fingerprint over (layout, dims, nnz, per-chunk checksums).
    /// O(1) to read back — registering a store-backed matrix never
    /// re-scans the data (unlike `Matrix::fingerprint`).
    pub fingerprint: u64,
}

/// Index entry for one chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Byte offset of the payload from the start of the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// First global row covered by this chunk.
    pub row_lo: usize,
    /// Rows in this chunk (`chunk_rows` except possibly the last).
    pub rows: usize,
    /// Stored entries in this chunk.
    pub nnz: u64,
    /// `checksum_bytes` of the payload.
    pub checksum: u64,
}

/// Typed store failures. Returned inside `anyhow::Error` so callers can
/// `downcast_ref::<StoreError>()` and branch on the kind.
#[derive(Debug)]
pub enum StoreError {
    /// The file does not start with the LAMC2 magic (or is too short to).
    NotAStore(PathBuf),
    /// The file starts like a store but ends before a valid footer —
    /// typical of an ingest that died before `finish()` or a partial copy.
    Truncated { path: PathBuf, detail: String },
    /// Structure or checksum mismatch: the file is complete but damaged.
    Corrupt { path: PathBuf, detail: String },
    /// Footer declares a format version this build cannot read.
    UnsupportedVersion { path: PathBuf, version: u64 },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::NotAStore(p) => write!(f, "not a LAMC2 store: {p:?}"),
            StoreError::Truncated { path, detail } => {
                write!(f, "truncated LAMC2 store {path:?}: {detail}")
            }
            StoreError::Corrupt { path, detail } => {
                write!(f, "corrupt LAMC2 store {path:?}: {detail}")
            }
            StoreError::UnsupportedVersion { path, version } => {
                write!(f, "LAMC2 store {path:?} has unsupported version {version}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

/// Checksum a byte slice: a [`mix`] chain over the length and each
/// little-endian 8-byte word (zero-padded tail). Deterministic across
/// platforms; sensitive to any bit flip and to length changes.
pub fn checksum_bytes(bytes: &[u8]) -> u64 {
    let mut h = mix(0x4C41_4D43_4353_554D, bytes.len() as u64);
    let mut words = bytes.chunks_exact(8);
    for w in words.by_ref() {
        h = mix(h, u64::from_le_bytes([w[0], w[1], w[2], w[3], w[4], w[5], w[6], w[7]]));
    }
    let rem = words.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        h = mix(h, u64::from_le_bytes(tail));
    }
    h
}

/// Store content fingerprint: layout, dims, nnz, and every chunk
/// checksum, chained in order. Cheap to compute at `finish()` (the
/// writer already has the chunk checksums) and O(1) to read back from
/// the header. Deliberately *not* the same chain as
/// `Matrix::fingerprint`: in-memory and store-backed registrations take
/// different execution paths, and the cache key reflects that (the same
/// argument that separates dense from CSR fingerprints).
pub fn store_fingerprint(
    layout: Layout,
    rows: usize,
    cols: usize,
    nnz: u64,
    chunk_checksums: impl IntoIterator<Item = u64>,
) -> u64 {
    let mut h = mix(0x4C41_4D43_0000_0005, layout.tag());
    h = mix(h, rows as u64);
    h = mix(h, cols as u64);
    h = mix(h, nnz);
    for c in chunk_checksums {
        h = mix(h, c);
    }
    h
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn word(bytes: &[u8], i: usize) -> u64 {
    let b = &bytes[i * 8..i * 8 + 8];
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Encode the footer body (header words then index entries).
pub fn encode_footer(header: &StoreHeader, index: &[ChunkMeta]) -> Vec<u8> {
    debug_assert_eq!(header.n_chunks, index.len());
    let mut out = Vec::with_capacity((HEADER_WORDS + ENTRY_WORDS * index.len()) * 8);
    push_u64(&mut out, VERSION);
    push_u64(&mut out, header.layout.tag());
    push_u64(&mut out, header.rows as u64);
    push_u64(&mut out, header.cols as u64);
    push_u64(&mut out, header.chunk_rows as u64);
    push_u64(&mut out, header.nnz);
    push_u64(&mut out, index.len() as u64);
    push_u64(&mut out, header.fingerprint);
    for e in index {
        push_u64(&mut out, e.offset);
        push_u64(&mut out, e.len);
        push_u64(&mut out, e.row_lo as u64);
        push_u64(&mut out, e.rows as u64);
        push_u64(&mut out, e.nnz);
        push_u64(&mut out, e.checksum);
    }
    out
}

/// Decode and validate a footer body read back from disk.
///
/// `payload_end` is the byte offset where the footer starts (i.e. where
/// chunk payloads must end); chunk extents are checked against it.
pub fn decode_footer(
    bytes: &[u8],
    payload_end: u64,
    path: &Path,
) -> Result<(StoreHeader, Vec<ChunkMeta>), StoreError> {
    let corrupt = |detail: String| StoreError::Corrupt { path: path.to_path_buf(), detail };
    if bytes.len() < HEADER_WORDS * 8 || bytes.len() % 8 != 0 {
        return Err(corrupt(format!("footer body has {} bytes", bytes.len())));
    }
    let version = word(bytes, 0);
    if version != VERSION {
        return Err(StoreError::UnsupportedVersion { path: path.to_path_buf(), version });
    }
    let layout = Layout::from_tag(word(bytes, 1))
        .ok_or_else(|| corrupt(format!("unknown layout tag {}", word(bytes, 1))))?;
    let rows = word(bytes, 2) as usize;
    let cols = word(bytes, 3) as usize;
    let chunk_rows = word(bytes, 4) as usize;
    let nnz = word(bytes, 5);
    let n_chunks = word(bytes, 6) as usize;
    let fingerprint = word(bytes, 7);

    if bytes.len() != (HEADER_WORDS + ENTRY_WORDS * n_chunks) * 8 {
        return Err(corrupt(format!(
            "footer declares {n_chunks} chunks but body has {} bytes",
            bytes.len()
        )));
    }
    if chunk_rows == 0 && n_chunks > 0 {
        return Err(corrupt("zero chunk height with chunks present".into()));
    }

    let mut index = Vec::with_capacity(n_chunks);
    let mut covered_rows = 0usize;
    let mut covered_nnz = 0u64;
    for i in 0..n_chunks {
        let base = HEADER_WORDS + ENTRY_WORDS * i;
        let e = ChunkMeta {
            offset: word(bytes, base),
            len: word(bytes, base + 1),
            row_lo: word(bytes, base + 2) as usize,
            rows: word(bytes, base + 3) as usize,
            nnz: word(bytes, base + 4),
            checksum: word(bytes, base + 5),
        };
        if e.offset < MAGIC.len() as u64 || e.offset.saturating_add(e.len) > payload_end {
            return Err(corrupt(format!(
                "chunk {i} extent [{}, {}) escapes payload region [8, {payload_end})",
                e.offset,
                e.offset.saturating_add(e.len)
            )));
        }
        if e.row_lo != i * chunk_rows || e.rows == 0 || e.rows > chunk_rows {
            return Err(corrupt(format!(
                "chunk {i} covers rows [{}, {}) — not a {chunk_rows}-row band",
                e.row_lo,
                e.row_lo + e.rows
            )));
        }
        covered_rows += e.rows;
        covered_nnz += e.nnz;
        index.push(e);
    }
    if covered_rows != rows {
        return Err(corrupt(format!("chunks cover {covered_rows} rows, header says {rows}")));
    }
    if covered_nnz != nnz {
        return Err(corrupt(format!("chunks hold {covered_nnz} entries, header says {nnz}")));
    }

    Ok((StoreHeader { layout, rows, cols, nnz, chunk_rows, n_chunks, fingerprint }, index))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header(n_chunks: usize) -> (StoreHeader, Vec<ChunkMeta>) {
        let mut index = Vec::new();
        let mut offset = 8u64;
        for i in 0..n_chunks {
            index.push(ChunkMeta {
                offset,
                len: 40,
                row_lo: i * 2,
                rows: 2,
                nnz: 10,
                checksum: 0xABC0 + i as u64,
            });
            offset += 40;
        }
        let h = StoreHeader {
            layout: Layout::Csr,
            rows: n_chunks * 2,
            cols: 7,
            nnz: 10 * n_chunks as u64,
            chunk_rows: 2,
            n_chunks,
            fingerprint: store_fingerprint(
                Layout::Csr,
                n_chunks * 2,
                7,
                10 * n_chunks as u64,
                index.iter().map(|e| e.checksum),
            ),
        };
        (h, index)
    }

    #[test]
    fn footer_round_trip() {
        let (h, index) = header(3);
        let bytes = encode_footer(&h, &index);
        let (h2, index2) = decode_footer(&bytes, 8 + 3 * 40, Path::new("/t")).unwrap();
        assert_eq!(h, h2);
        assert_eq!(index, index2);
    }

    #[test]
    fn decode_rejects_bad_extents() {
        let (h, mut index) = header(2);
        index[1].len = 1 << 40; // escapes the payload region
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, 8 + 2 * 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn decode_rejects_row_coverage_mismatch() {
        let (mut h, index) = header(2);
        h.rows = 99;
        let bytes = encode_footer(&h, &index);
        let err = decode_footer(&bytes, 8 + 2 * 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::Corrupt { .. }), "{err}");
    }

    #[test]
    fn decode_rejects_future_version() {
        let (h, index) = header(1);
        let mut bytes = encode_footer(&h, &index);
        bytes[..8].copy_from_slice(&999u64.to_le_bytes());
        let err = decode_footer(&bytes, 8 + 40, Path::new("/t")).unwrap_err();
        assert!(matches!(err, StoreError::UnsupportedVersion { version: 999, .. }), "{err}");
    }

    #[test]
    fn checksum_sensitivity() {
        let a = checksum_bytes(b"hello world");
        assert_eq!(a, checksum_bytes(b"hello world"), "deterministic");
        assert_ne!(a, checksum_bytes(b"hello worlc"), "bit flip");
        assert_ne!(a, checksum_bytes(b"hello world\0"), "length change");
        assert_ne!(checksum_bytes(b""), checksum_bytes(b"\0"), "padding not confusable");
    }

    #[test]
    fn fingerprint_covers_every_input() {
        let base = store_fingerprint(Layout::Dense, 4, 5, 20, [1, 2]);
        assert_ne!(base, store_fingerprint(Layout::Csr, 4, 5, 20, [1, 2]));
        assert_ne!(base, store_fingerprint(Layout::Dense, 5, 4, 20, [1, 2]));
        assert_ne!(base, store_fingerprint(Layout::Dense, 4, 5, 21, [1, 2]));
        assert_ne!(base, store_fingerprint(Layout::Dense, 4, 5, 20, [2, 1]));
        assert_eq!(base, store_fingerprint(Layout::Dense, 4, 5, 20, vec![1, 2]));
    }
}
