//! Chunk payload compression (pure Rust, no deps).
//!
//! One codec beyond "store the bytes": **shuffle-lz**. It exploits the
//! two regularities LAMC payloads actually have:
//!
//! 1. **Byte-plane shuffle.** Payloads are streams of 4-byte machine
//!    words — `f32` values, `u32` CSR column indices, and (for the CSR
//!    row-pointer prefix) `u64`s, which are just two 4-byte words. The
//!    high bytes of neighboring words are strongly correlated (sign +
//!    exponent for floats, high index bits for columns), while the low
//!    bytes look like noise. Transposing the stream into four byte
//!    planes (all byte-0s, then all byte-1s, …) turns that vertical
//!    correlation into horizontal runs an LZ pass can see. Lengths not
//!    divisible by 4 keep their tail verbatim after the planes.
//!
//! 2. **LZSS back-references.** A greedy single-pass encoder over the
//!    shuffled stream: a control byte `< 0x80` introduces a literal run
//!    of `ctrl + 1` bytes (1..=128); a control byte `>= 0x80` is a
//!    match of `(ctrl & 0x7f) + MIN_MATCH` bytes (4..=131) at a 2-byte
//!    little-endian backward offset (1..=65535). Matches may overlap
//!    their own output (the RLE case: offset 1 repeats one byte), so
//!    decode copies byte-by-byte.
//!
//! The writer stores whichever is smaller, per chunk: if the encoded
//! form is not strictly smaller than the raw payload, the chunk is
//! stored raw and tagged [`Codec::None`] (see `store::chunk`). Decoding
//! is exact — `decode(encode(x)) == x` for every byte string — which
//! the round-trip property tests below and the store-level harness both
//! lock down.

use std::path::Path;

use anyhow::Result;

use super::format::StoreError;

/// Shortest back-reference worth a 3-byte token.
const MIN_MATCH: usize = 4;
/// Longest match a control byte can express: `0x7f + MIN_MATCH`.
const MAX_MATCH: usize = 0x7f + MIN_MATCH;
/// Longest literal run a control byte can express.
const MAX_LITERAL: usize = 128;
/// Back-reference window (2-byte offset, 0 reserved as invalid).
const MAX_OFFSET: usize = u16::MAX as usize;
/// Hash-table slots for the 4-byte-prefix match finder.
const HASH_BITS: u32 = 15;

/// Per-chunk payload codec. The tag is what the footer stores; `None`
/// must stay tag 0 so a zeroed field reads as "raw bytes".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    /// Payload stored verbatim.
    #[default]
    None,
    /// Byte-plane shuffle + LZSS (see module docs).
    ShuffleLz,
}

impl Codec {
    /// Footer encoding of this codec.
    pub fn tag(self) -> u64 {
        match self {
            Codec::None => 0,
            Codec::ShuffleLz => 1,
        }
    }

    /// Decode a footer tag; `None` for tags this build doesn't know.
    pub fn from_tag(tag: u64) -> Option<Codec> {
        match tag {
            0 => Some(Codec::None),
            1 => Some(Codec::ShuffleLz),
            _ => None,
        }
    }

    /// CLI / display name.
    pub fn as_str(self) -> &'static str {
        match self {
            Codec::None => "none",
            Codec::ShuffleLz => "shuffle-lz",
        }
    }

    /// Parse a `--codec` argument.
    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "none" => Some(Codec::None),
            "shuffle-lz" => Some(Codec::ShuffleLz),
            _ => None,
        }
    }
}

/// Transpose `bytes` into four byte planes (stride-4 shuffle); the
/// `len % 4` tail is appended verbatim.
fn shuffle(bytes: &[u8]) -> Vec<u8> {
    let words = bytes.len() / 4;
    let mut out = Vec::with_capacity(bytes.len());
    for plane in 0..4 {
        out.extend((0..words).map(|w| bytes[w * 4 + plane]));
    }
    out.extend_from_slice(&bytes[words * 4..]);
    out
}

/// Inverse of [`shuffle`].
fn unshuffle(bytes: &[u8]) -> Vec<u8> {
    let words = bytes.len() / 4;
    let mut out = vec![0u8; bytes.len()];
    for plane in 0..4 {
        for w in 0..words {
            out[w * 4 + plane] = bytes[plane * words + w];
        }
    }
    out[words * 4..].copy_from_slice(&bytes[words * 4..]);
    out
}

fn hash4(b: &[u8]) -> usize {
    // Multiplicative hash of the 4-byte prefix; the constant is the
    // 32-bit golden-ratio multiplier.
    let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Greedy LZSS over `src`. Always produces a valid token stream; the
/// caller compares lengths and keeps the raw bytes if this is not a win.
fn lz_compress(src: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    // Last position that started each 4-byte-prefix hash bucket.
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut literal_start = 0usize;
    let mut i = 0usize;

    let flush_literals = |out: &mut Vec<u8>, lo: usize, hi: usize| {
        let mut p = lo;
        while p < hi {
            let run = (hi - p).min(MAX_LITERAL);
            out.push((run - 1) as u8);
            out.extend_from_slice(&src[p..p + run]);
            p += run;
        }
    };

    while i + MIN_MATCH <= src.len() {
        let h = hash4(&src[i..]);
        let cand = table[h];
        table[h] = i;
        let mut match_len = 0usize;
        if cand != usize::MAX && i - cand <= MAX_OFFSET {
            let limit = (src.len() - i).min(MAX_MATCH);
            while match_len < limit && src[cand + match_len] == src[i + match_len] {
                match_len += 1;
            }
        }
        if match_len >= MIN_MATCH {
            flush_literals(&mut out, literal_start, i);
            out.push(0x80 | (match_len - MIN_MATCH) as u8);
            out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
            // Seed the table inside the match so the next search can
            // land mid-run (cheap approximation of a full hash chain).
            let end = i + match_len;
            i += 1;
            while i < end && i + MIN_MATCH <= src.len() {
                table[hash4(&src[i..])] = i;
                i += 1;
            }
            i = end;
            literal_start = i;
        } else {
            i += 1;
        }
    }
    flush_literals(&mut out, literal_start, src.len());
    out
}

/// Decode an LZSS token stream into exactly `raw_len` bytes. Malformed
/// streams (truncated tokens, out-of-window offsets, wrong total) are
/// reported, never panicked on — the input is untrusted disk bytes.
fn lz_decompress(src: &[u8], raw_len: usize) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(raw_len);
    let mut i = 0usize;
    while i < src.len() {
        let ctrl = src[i];
        i += 1;
        if ctrl < 0x80 {
            let run = ctrl as usize + 1;
            let Some(lit) = src.get(i..i + run) else {
                return Err(format!("literal run of {run} bytes truncated at {i}"));
            };
            out.extend_from_slice(lit);
            i += run;
        } else {
            let len = (ctrl & 0x7f) as usize + MIN_MATCH;
            let Some(ob) = src.get(i..i + 2) else {
                return Err(format!("match offset truncated at {i}"));
            };
            let offset = u16::from_le_bytes([ob[0], ob[1]]) as usize;
            i += 2;
            if offset == 0 || offset > out.len() {
                return Err(format!("match offset {offset} outside {} decoded bytes", out.len()));
            }
            // Byte-by-byte: matches may overlap their own output.
            let start = out.len() - offset;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
        if out.len() > raw_len {
            return Err(format!("stream decodes past declared {raw_len} bytes"));
        }
    }
    if out.len() != raw_len {
        return Err(format!("stream decoded {} bytes, expected {raw_len}", out.len()));
    }
    Ok(out)
}

/// Encode `raw` with `codec`. For [`Codec::None`] this is a plain copy
/// (callers avoid it on that path); the result is *not* guaranteed to
/// be smaller — the writer stores whichever of raw/encoded wins.
pub fn encode(codec: Codec, raw: &[u8]) -> Vec<u8> {
    match codec {
        Codec::None => raw.to_vec(),
        Codec::ShuffleLz => lz_compress(&shuffle(raw)),
    }
}

/// Decode `stored` back into exactly `raw_len` bytes. Failures are
/// typed [`StoreError::Corrupt`] — a damaged compressed payload whose
/// stored-byte checksum still matched can only mean disk corruption
/// plus a checksum collision, and is reported like any other damage.
pub fn decode(codec: Codec, stored: &[u8], raw_len: usize, path: &Path) -> Result<Vec<u8>> {
    match codec {
        Codec::None => {
            if stored.len() != raw_len {
                return Err(StoreError::Corrupt {
                    path: path.to_path_buf(),
                    detail: format!(
                        "raw chunk stores {} bytes but declares {raw_len}",
                        stored.len()
                    ),
                }
                .into());
            }
            Ok(stored.to_vec())
        }
        Codec::ShuffleLz => match lz_decompress(stored, raw_len) {
            Ok(shuffled) => Ok(unshuffle(&shuffled)),
            Err(detail) => Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                detail: format!("shuffle-lz payload: {detail}"),
            }
            .into()),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn round_trip(bytes: &[u8]) {
        let enc = encode(Codec::ShuffleLz, bytes);
        let dec = decode(Codec::ShuffleLz, &enc, bytes.len(), Path::new("/t")).unwrap();
        assert_eq!(dec, bytes, "round trip of {} bytes", bytes.len());
    }

    #[test]
    fn round_trips_every_tail_length() {
        // Cover len % 4 ∈ {0,1,2,3} and tiny inputs below MIN_MATCH.
        for n in 0..64usize {
            let bytes: Vec<u8> = (0..n).map(|i| (i * 37 % 251) as u8).collect();
            round_trip(&bytes);
        }
    }

    #[test]
    fn round_trips_random_and_structured_payloads() {
        let mut rng = Xoshiro256::seed_from(0x90DEC);
        // Random f32 bit patterns (the dense-payload case).
        let floats: Vec<u8> =
            (0..4096).flat_map(|_| rng.next_f32().to_le_bytes()).collect();
        round_trip(&floats);
        // Monotone u32 indices (the CSR-column case) — highly compressible.
        let indices: Vec<u8> = (0u32..8192).flat_map(|i| (i * 3).to_le_bytes()).collect();
        let enc = encode(Codec::ShuffleLz, &indices);
        assert!(enc.len() < indices.len() / 2, "monotone indices compress well: {}", enc.len());
        round_trip(&indices);
        // Constant runs (explicit zeros / padding).
        round_trip(&vec![0u8; 10_000]);
        let enc = encode(Codec::ShuffleLz, &vec![0u8; 10_000]);
        assert!(enc.len() < 200, "RLE case collapses: {}", enc.len());
    }

    #[test]
    fn empty_payload() {
        round_trip(&[]);
        assert!(encode(Codec::ShuffleLz, &[]).is_empty());
    }

    #[test]
    fn incompressible_input_still_round_trips() {
        // A keyed byte mix with no 4-byte repeats to speak of: encoded
        // form is larger (literal-run overhead) but must still decode.
        let mut rng = Xoshiro256::seed_from(7);
        let noise: Vec<u8> = (0..5000).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let enc = encode(Codec::ShuffleLz, &noise);
        assert!(enc.len() >= noise.len(), "noise does not compress");
        round_trip(&noise);
    }

    #[test]
    fn malformed_streams_are_typed_errors() {
        let raw: Vec<u8> = (0..256u32).flat_map(|i| i.to_le_bytes()).collect();
        let enc = encode(Codec::ShuffleLz, &raw);
        // Truncated stream.
        let err = decode(Codec::ShuffleLz, &enc[..enc.len() - 1], raw.len(), Path::new("/t"))
            .unwrap_err();
        assert!(err.downcast_ref::<StoreError>().is_some(), "{err}");
        // Wrong declared length.
        let err = decode(Codec::ShuffleLz, &enc, raw.len() + 1, Path::new("/t")).unwrap_err();
        assert!(err.downcast_ref::<StoreError>().is_some(), "{err}");
        // Out-of-window offset right at the start.
        let bogus = [0x80u8, 0xff, 0xff];
        let err = decode(Codec::ShuffleLz, &bogus, 4, Path::new("/t")).unwrap_err();
        assert!(err.downcast_ref::<StoreError>().is_some(), "{err}");
    }

    #[test]
    fn codec_tags_round_trip() {
        for c in [Codec::None, Codec::ShuffleLz] {
            assert_eq!(Codec::from_tag(c.tag()), Some(c));
            assert_eq!(Codec::parse(c.as_str()), Some(c));
        }
        assert_eq!(Codec::from_tag(99), None);
        assert_eq!(Codec::parse("zstd"), None);
    }
}
