//! Band-ownership manifest for sharded stores (`LAMCM1`).
//!
//! A shard manifest describes one logical matrix split into contiguous
//! **row bands**, each band living in its own LAMC2/LAMC3 store file.
//! Band boundaries are aligned to the parent store's chunk height, so a
//! band never splits a tile row — the tile grid produced by `repack`
//! is the shard unit, exactly as the router's scatter logic assumes.
//!
//! The manifest is a small text file next to the shard stores:
//!
//! ```text
//! LAMCM1
//! matrix rows=300 cols=1000 nnz=37000 sparse=1 fingerprint=00a1b2c3d4e5f607 layout=csr chunk_rows=64 chunk_cols=128
//! shard index=0 row_lo=0 row_hi=128 file=cc.s0.lamc3
//! shard index=1 row_lo=128 row_hi=300 file=cc.s1.lamc3
//! checksum=8f1d2c3b4a596877
//! ```
//!
//! `fingerprint` is the parent store's content fingerprint: every
//! worker holding a band of the "same" matrix must agree on it, which
//! is how the router rejects topologies assembled from different
//! ingests of a dataset. The trailing `checksum` line covers every
//! preceding byte (via [`checksum_bytes`]) so a truncated or edited
//! manifest is rejected at load time.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::store::chunk::{ChunkWriter, StoreReader};
use crate::store::format::{checksum_bytes, Layout};

const MAGIC_LINE: &str = "LAMCM1";

/// One row band of a sharded matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// Position in the band order (0-based, contiguous).
    pub index: usize,
    /// First parent row in the band (inclusive).
    pub row_lo: usize,
    /// One past the last parent row (exclusive).
    pub row_hi: usize,
    /// Store file holding the band, relative to the manifest.
    pub file: String,
}

/// Parsed + validated shard manifest.
#[derive(Clone, Debug)]
pub struct ShardManifest {
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub sparse: bool,
    /// Parent store content fingerprint (shared by every band).
    pub fingerprint: u64,
    pub layout: Layout,
    pub chunk_rows: usize,
    /// 0 for row-band (LAMC2) shards.
    pub chunk_cols: usize,
    pub entries: Vec<ShardEntry>,
    /// Directory shard paths are resolved against (the manifest's own).
    dir: PathBuf,
}

impl ShardManifest {
    /// Load and validate a manifest file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("read shard manifest {path:?}"))?;
        let dir = path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf();
        Self::parse(&text, dir).with_context(|| format!("shard manifest {path:?}"))
    }

    fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let Some((body, tail)) = text.rsplit_once("checksum=") else {
            bail!("missing trailing checksum line");
        };
        let want: u64 = u64::from_str_radix(tail.trim(), 16)
            .context("malformed checksum value")?;
        let got = checksum_bytes(body.as_bytes());
        ensure!(got == want, "manifest checksum mismatch (corrupt or edited)");

        let mut lines = body.lines();
        ensure!(
            lines.next() == Some(MAGIC_LINE),
            "not a shard manifest (missing {MAGIC_LINE} magic)"
        );
        let header = lines.next().context("missing matrix header line")?;
        let mut fields = parse_fields("matrix", header)?;
        let rows = take_usize(&mut fields, "rows")?;
        let cols = take_usize(&mut fields, "cols")?;
        let nnz = take_u64(&mut fields, "nnz")?;
        let sparse = take_u64(&mut fields, "sparse")? != 0;
        let fingerprint = take_hex(&mut fields, "fingerprint")?;
        let layout = match fields.remove("layout").context("missing field 'layout'")?.as_str() {
            "dense" => Layout::Dense,
            "csr" => Layout::Csr,
            other => bail!("unknown layout '{other}' (want dense|csr)"),
        };
        let chunk_rows = take_usize(&mut fields, "chunk_rows")?;
        let chunk_cols = take_usize(&mut fields, "chunk_cols")?;

        let mut entries = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let mut fields = parse_fields("shard", line)?;
            entries.push(ShardEntry {
                index: take_usize(&mut fields, "index")?,
                row_lo: take_usize(&mut fields, "row_lo")?,
                row_hi: take_usize(&mut fields, "row_hi")?,
                file: fields.remove("file").context("missing field 'file'")?,
            });
        }

        let manifest = Self {
            rows,
            cols,
            nnz,
            sparse,
            fingerprint,
            layout,
            chunk_rows,
            chunk_cols,
            entries,
            dir,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Structural invariants: non-empty, indices 0..n in order, bands
    /// non-empty and contiguously covering `0..rows`.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.rows > 0 && self.cols > 0, "empty parent matrix");
        ensure!(!self.entries.is_empty(), "manifest lists no shards");
        let mut expect_lo = 0;
        for (i, e) in self.entries.iter().enumerate() {
            ensure!(e.index == i, "shard indices out of order (found {} at position {i})", e.index);
            ensure!(e.row_lo < e.row_hi, "shard {i} band {}..{} is empty", e.row_lo, e.row_hi);
            ensure!(
                e.row_lo == expect_lo,
                "shard bands are not contiguous: shard {i} starts at row {} (expected {})",
                e.row_lo,
                expect_lo
            );
            ensure!(!e.file.is_empty(), "shard {i} has no file");
            expect_lo = e.row_hi;
        }
        ensure!(
            expect_lo == self.rows,
            "shard bands cover rows 0..{expect_lo} but the matrix has {} rows",
            self.rows
        );
        Ok(())
    }

    /// Absolute path of a shard's store file.
    pub fn shard_path(&self, entry: &ShardEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// `(row_lo, row_hi)` per band, in band order.
    pub fn band_spans(&self) -> Vec<(usize, usize)> {
        self.entries.iter().map(|e| (e.row_lo, e.row_hi)).collect()
    }

    /// Serialize to `path` (checksum stamped last).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.validate()?;
        let mut body = format!("{MAGIC_LINE}\n");
        body.push_str(&format!(
            "matrix rows={} cols={} nnz={} sparse={} fingerprint={:016x} layout={} chunk_rows={} chunk_cols={}\n",
            self.rows,
            self.cols,
            self.nnz,
            u64::from(self.sparse),
            self.fingerprint,
            self.layout.as_str(),
            self.chunk_rows,
            self.chunk_cols,
        ));
        for e in &self.entries {
            body.push_str(&format!(
                "shard index={} row_lo={} row_hi={} file={}\n",
                e.index, e.row_lo, e.row_hi, e.file
            ));
        }
        let sum = checksum_bytes(body.as_bytes());
        body.push_str(&format!("checksum={sum:016x}\n"));
        fs::write(path, body).with_context(|| format!("write shard manifest {path:?}"))
    }
}

fn parse_fields(
    tag: &str,
    line: &str,
) -> Result<std::collections::BTreeMap<String, String>> {
    let mut tokens = line.split_whitespace();
    ensure!(
        tokens.next() == Some(tag),
        "expected a '{tag}' line, got: {line}"
    );
    let mut map = std::collections::BTreeMap::new();
    for token in tokens {
        let (k, v) = token
            .split_once('=')
            .with_context(|| format!("malformed field '{token}' (want key=value)"))?;
        ensure!(
            map.insert(k.to_string(), v.to_string()).is_none(),
            "duplicate field '{k}'"
        );
    }
    Ok(map)
}

fn take_usize(map: &mut std::collections::BTreeMap<String, String>, key: &str) -> Result<usize> {
    map.remove(key)
        .with_context(|| format!("missing field '{key}'"))?
        .parse()
        .with_context(|| format!("field '{key}' is not an integer"))
}

fn take_u64(map: &mut std::collections::BTreeMap<String, String>, key: &str) -> Result<u64> {
    map.remove(key)
        .with_context(|| format!("missing field '{key}'"))?
        .parse()
        .with_context(|| format!("field '{key}' is not an integer"))
}

fn take_hex(map: &mut std::collections::BTreeMap<String, String>, key: &str) -> Result<u64> {
    let text = map.remove(key).with_context(|| format!("missing field '{key}'"))?;
    u64::from_str_radix(&text, 16).with_context(|| format!("field '{key}' is not hex"))
}

/// Split an existing store into `n_shards` row bands under `out_dir`,
/// writing one store file per band plus a `<stem>.lamcm` manifest.
///
/// Band boundaries are rounded up to a multiple of the source chunk
/// height so bands never split a chunk band — every shard store keeps
/// the parent's layout, chunk geometry and exact f32 payloads, which is
/// what makes a routed run gather byte-identical blocks. When rounding
/// leaves fewer than `n_shards` non-empty bands, the actual count wins.
///
/// Returns the manifest path and the parsed manifest.
pub fn shard_store(
    reader: &StoreReader,
    out_dir: &Path,
    stem: &str,
    n_shards: usize,
) -> Result<(PathBuf, ShardManifest)> {
    ensure!(n_shards > 0, "need at least one shard");
    let header = reader.header().clone();
    let rows = header.rows;
    let cols = header.cols;
    ensure!(rows > 0 && cols > 0, "cannot shard an empty store");

    // chunk-aligned band height, then the resulting band spans.
    let raw = rows.div_ceil(n_shards);
    let band_rows = raw.div_ceil(header.chunk_rows) * header.chunk_rows;
    let mut spans = Vec::new();
    let mut lo = 0;
    while lo < rows {
        let hi = (lo + band_rows).min(rows);
        spans.push((lo, hi));
        lo = hi;
    }

    fs::create_dir_all(out_dir).with_context(|| format!("create shard dir {out_dir:?}"))?;
    let ext = if header.is_tiled() { "lamc3" } else { "lamc2" };
    let all_cols: Vec<usize> = (0..cols).collect();
    let mut entries = Vec::new();
    for (index, &(row_lo, row_hi)) in spans.iter().enumerate() {
        let file = format!("{stem}.s{index}.{ext}");
        let path = out_dir.join(&file);
        let mut writer = if header.is_tiled() {
            ChunkWriter::create_tiled(&path, header.layout, cols, header.chunk_rows, header.chunk_cols)?
        } else {
            ChunkWriter::create(&path, header.layout, cols, header.chunk_rows)?
        };
        // Shards keep the source's payload codec along with its geometry.
        writer.set_codec(header.codec);
        // Stream the band one chunk-height slab at a time: peak memory
        // is one slab, same as repack.
        let mut r = row_lo;
        while r < row_hi {
            let stop = (r + header.chunk_rows).min(row_hi);
            let slab_rows: Vec<usize> = (r..stop).collect();
            let slab = reader.tile(&slab_rows, &all_cols)?;
            for i in 0..slab.rows() {
                let row = &slab.data()[i * cols..(i + 1) * cols];
                match header.layout {
                    Layout::Dense => writer.append_dense_row(row)?,
                    // Re-derive CSR entries from the dense slab. Explicit
                    // zeros are dropped; `tile` yields 0.0 for absent
                    // entries either way, so gathers are unchanged.
                    Layout::Csr => {
                        let entries: Vec<(u32, f32)> = row
                            .iter()
                            .enumerate()
                            .filter(|(_, &v)| v != 0.0)
                            .map(|(j, &v)| (j as u32, v))
                            .collect();
                        writer.append_sparse_row(&entries)?;
                    }
                }
            }
            r = stop;
        }
        let summary = writer.finish()?;
        ensure!(
            summary.rows == row_hi - row_lo,
            "shard {index} wrote {} rows, expected {}",
            summary.rows,
            row_hi - row_lo
        );
        entries.push(ShardEntry { index, row_lo, row_hi, file });
    }

    let manifest = ShardManifest {
        rows,
        cols,
        nnz: header.nnz,
        sparse: header.layout == Layout::Csr,
        fingerprint: header.fingerprint,
        layout: header.layout,
        chunk_rows: header.chunk_rows,
        chunk_cols: header.chunk_cols,
        entries,
        dir: out_dir.to_path_buf(),
    };
    let manifest_path = out_dir.join(format!("{stem}.lamcm"));
    manifest.save(&manifest_path)?;
    Ok((manifest_path, manifest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{DenseMatrix, Matrix};
    use crate::rng::Xoshiro256;
    use crate::store::chunk::pack_matrix_tiled;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lamc_manifest_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::seed_from(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32()).collect();
        Matrix::Dense(DenseMatrix::from_vec(rows, cols, data))
    }

    #[test]
    fn shard_store_round_trips_every_value() {
        let dir = tmp_dir("roundtrip");
        let matrix = sample_matrix(70, 40, 9);
        let store = dir.join("m.lamc3");
        pack_matrix_tiled(&matrix, &store, 16, 16).unwrap();
        let reader = StoreReader::open(&store).unwrap();
        let (path, manifest) = shard_store(&reader, &dir.join("shards"), "m", 3).unwrap();

        // Bands are chunk-aligned, contiguous, and cover all rows.
        let loaded = ShardManifest::load(&path).unwrap();
        assert_eq!(loaded.rows, 70);
        assert_eq!(loaded.cols, 40);
        assert_eq!(loaded.fingerprint, reader.fingerprint());
        assert_eq!(loaded.band_spans(), manifest.band_spans());
        for (lo, _) in loaded.band_spans() {
            assert_eq!(lo % 16, 0, "band start {lo} not chunk-aligned");
        }

        // Every value survives the split exactly.
        let all_cols: Vec<usize> = (0..40).collect();
        for entry in &loaded.entries {
            let shard = StoreReader::open(&loaded.shard_path(entry)).unwrap();
            assert_eq!(shard.rows(), entry.row_hi - entry.row_lo);
            assert_eq!(shard.cols(), 40);
            let local: Vec<usize> = (0..shard.rows()).collect();
            let got = shard.tile(&local, &all_cols).unwrap();
            let parent_rows: Vec<usize> = (entry.row_lo..entry.row_hi).collect();
            let want = reader.tile(&parent_rows, &all_cols).unwrap();
            assert_eq!(got.data(), want.data(), "shard {} content", entry.index);
        }
    }

    #[test]
    fn sparse_shards_gather_identically() {
        let dir = tmp_dir("sparse");
        let mut rng = Xoshiro256::seed_from(41);
        let (rows, cols) = (50, 30);
        let mut triplets = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.next_f32() < 0.15 {
                    triplets.push((i, j, rng.next_f32() + 0.01));
                }
            }
        }
        let matrix = Matrix::Sparse(crate::matrix::CsrMatrix::from_triplets(rows, cols, triplets));
        let store = dir.join("s.lamc2");
        crate::store::chunk::pack_matrix(&matrix, &store, 8).unwrap();
        let reader = StoreReader::open(&store).unwrap();
        let (path, _) = shard_store(&reader, &dir.join("shards"), "s", 2).unwrap();
        let loaded = ShardManifest::load(&path).unwrap();
        assert!(loaded.sparse);
        let all_cols: Vec<usize> = (0..cols).collect();
        for entry in &loaded.entries {
            let shard = StoreReader::open(&loaded.shard_path(entry)).unwrap();
            let local: Vec<usize> = (0..shard.rows()).collect();
            let got = shard.tile(&local, &all_cols).unwrap();
            let parent_rows: Vec<usize> = (entry.row_lo..entry.row_hi).collect();
            let want = reader.tile(&parent_rows, &all_cols).unwrap();
            assert_eq!(got.data(), want.data());
        }
    }

    #[test]
    fn corrupt_or_gappy_manifests_are_rejected() {
        let dir = tmp_dir("validate");
        let matrix = sample_matrix(32, 10, 3);
        let store = dir.join("m.lamc2");
        crate::store::chunk::pack_matrix(&matrix, &store, 8).unwrap();
        let reader = StoreReader::open(&store).unwrap();
        let (path, manifest) = shard_store(&reader, &dir, "m", 2).unwrap();

        // Flip a digit inside the body: checksum must catch it.
        let text = fs::read_to_string(&path).unwrap();
        let bad = text.replacen("row_lo=0", "row_lo=1", 1);
        fs::write(&path, bad).unwrap();
        let err = ShardManifest::load(&path).unwrap_err().to_string();
        let err = format!("{err:#}");
        assert!(err.contains("manifest"), "{err}");

        // A band gap fails structural validation even with a good sum.
        let mut gappy = manifest.clone();
        gappy.entries[1].row_lo += 8;
        let err = format!("{:#}", gappy.validate().unwrap_err());
        assert!(err.contains("not contiguous"), "{err}");

        // Truncation (no checksum line) is typed too.
        fs::write(&path, "LAMCM1\nmatrix rows=4\n").unwrap();
        let err = format!("{:#}", ShardManifest::load(&path).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
    }
}
