//! `lamc::store` — chunked on-disk matrix store and out-of-core views.
//!
//! Every earlier path in the repo materialized the full input matrix in
//! RAM before the partition planner (paper §IV-B.2) ever ran, capping
//! practical scale far below what the Theorem-1 sampling model targets.
//! This module removes that cap: matrices live on disk in a
//! self-describing chunked format and the pipeline streams **submatrix
//! tiles** — submatrix extraction (§IV-B) only ever needs the chunks a
//! block's rows and columns touch, never the whole matrix.
//!
//! Pieces:
//!
//! * [`format`] — the versioned layouts: **LAMC2** (fixed-height row
//!   bands) and **LAMC3** (a row-band × col-band tile grid, so
//!   column-heavy planner access stops decoding full rows). Both share
//!   the envelope: leading magic, dense or CSR chunk payloads, and a
//!   trailing footer with dims, per-chunk checksums (`rng::mix64`
//!   chains) and an O(1) content fingerprint. Failures are typed
//!   ([`StoreError`]): not-a-store vs truncated vs corrupt. Footer
//!   revisions 3/4 add per-chunk payload compression.
//! * [`codec`](mod@crate::store::codec) — the pure-Rust `shuffle-lz`
//!   payload codec (byte-plane shuffle + LZSS) behind
//!   `lamc pack/ingest/repack --codec`; the content fingerprint is
//!   computed over uncompressed payloads, so recompression preserves
//!   result-cache identity.
//! * [`chunk`] — [`ChunkWriter`], a streaming row-append ingester
//!   (bands sealed + fsynced as they fill — split into column tiles on
//!   the fly in tiled mode; row count unknown until `finish`), and
//!   [`StoreReader`], random access via `tile(rows, cols)` that reads
//!   only the intersecting chunks of either layout, with a byte-bounded
//!   decoded-chunk cache backed by the shared [`crate::cache::ByteLru`].
//! * [`prefetch`](mod@crate::store::prefetch) — the background
//!   prefetcher behind [`StoreReader::prefetch_plan`]: the scheduler
//!   hands the reader its upcoming rounds and a dedicated thread warms
//!   a separately budgeted chunk pool ahead of the compute wave, so
//!   disk I/O overlaps co-clustering instead of serializing against it.
//! * [`repack`](mod@crate::store::repack) — store-to-store re-chunking
//!   (row-band ↔ tiled, new band/tile extents) that streams one band at
//!   a time and preserves the content fingerprint, so a repacked store
//!   keeps its result-cache identity.
//! * [`manifest`](mod@crate::store::manifest) — the `LAMCM1`
//!   band-ownership manifest behind `lamc shard` and the shard router:
//!   one logical matrix split into chunk-aligned row-band store files,
//!   each band registrable on a different `lamc serve` node.
//! * [`view`] — [`MatrixRef`] / [`MatrixView`]: location-transparent
//!   handles adopted by `pipeline::run`, `coordinator::run_rounds` and
//!   the partition planner/sampler, so the same co-clustering code
//!   serves in-memory and out-of-core inputs with byte-identical
//!   results.
//!
//! The `lamc pack` / `lamc ingest` / `lamc inspect` / `lamc repack` CLI
//! commands and the service's `LOAD name=… store=…` verb are thin
//! wrappers over these types; `docs/STORE.md` documents both formats
//! and the RSS expectations.

pub mod chunk;
pub mod codec;
pub mod format;
pub mod manifest;
mod mmap;
pub mod prefetch;
pub mod repack;
pub mod view;

pub use chunk::{
    pack_matrix, pack_matrix_tiled, pack_matrix_tiled_with_codec, pack_matrix_with_codec,
    ChunkWriter, IoCounters, StoreReader, StoreSummary, DEFAULT_CACHE_BYTES,
    DEFAULT_PREFETCH_BYTES,
};
pub use codec::Codec;
pub use format::{
    checksum_bytes, ChunkMeta, Layout, StoreError, StoreHeader, DEFAULT_CHUNK_ROWS,
};
pub use manifest::{shard_store, ShardEntry, ShardManifest};
pub use repack::{repack, repack_reader, RepackOptions};
pub use view::{MatrixRef, MatrixView};
