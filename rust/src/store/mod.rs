//! `lamc::store` — chunked on-disk matrix store and out-of-core views.
//!
//! Every earlier path in the repo materialized the full input matrix in
//! RAM before the partition planner (paper §IV-B.2) ever ran, capping
//! practical scale far below what the Theorem-1 sampling model targets.
//! This module removes that cap: matrices live on disk in a
//! self-describing chunked format and the pipeline streams **row-band
//! tiles** — submatrix extraction (§IV-B) only ever needs the bands a
//! block's rows touch, never the whole matrix.
//!
//! Pieces:
//!
//! * [`format`] — the versioned LAMC2 layout: leading magic, fixed-height
//!   row-band chunks (dense or CSR payloads), and a trailing footer with
//!   dims, per-chunk checksums (`rng::mix64` chains) and an O(1) content
//!   fingerprint. Failures are typed ([`StoreError`]): not-a-store vs
//!   truncated vs corrupt.
//! * [`chunk`] — [`ChunkWriter`], a streaming row-append ingester
//!   (bands sealed + fsynced as they fill; row count unknown until
//!   `finish`), and [`StoreReader`], random access via
//!   `tile(rows, cols)` that reads only the touched bands, with an
//!   optional byte-bounded decoded-band cache.
//! * [`view`] — [`MatrixRef`] / [`MatrixView`]: location-transparent
//!   handles adopted by `pipeline::run`, `coordinator::run_rounds` and
//!   the partition planner/sampler, so the same co-clustering code
//!   serves in-memory and out-of-core inputs with byte-identical
//!   results.
//!
//! The `lamc pack` / `lamc ingest` / `lamc inspect` CLI commands and the
//! service's `LOAD name=… store=…` verb are thin wrappers over these
//! types; `docs/STORE.md` documents the format and the RSS expectations.

pub mod chunk;
pub mod format;
pub mod view;

pub use chunk::{pack_matrix, ChunkWriter, StoreReader, StoreSummary, DEFAULT_CACHE_BYTES};
pub use format::{checksum_bytes, Layout, StoreError, StoreHeader, DEFAULT_CHUNK_ROWS};
pub use view::{MatrixRef, MatrixView};
