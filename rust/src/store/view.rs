//! Location-transparent matrix handles: in-memory or store-backed.
//!
//! [`MatrixRef`] is the owning handle the service registry and
//! long-lived callers hold (cheap to clone — both arms are `Arc`s).
//! [`MatrixView`] is the borrowed, `Copy` form the pipeline and
//! scheduler actually consume; every entry point that used to take
//! `&Matrix` now takes `impl Into<MatrixView<'_>>`, so existing
//! `run(&matrix)` call sites compile unchanged while `run(&matrix_ref)`
//! transparently streams tiles from disk.
//!
//! The one behavioural difference between the arms is *where bytes
//! live*: `gather_block` on a stored view reads only the row bands the
//! block touches (see [`StoreReader::tile`]), so peak memory for a
//! partitioned run is bounded by (workers × block size) + the reader's
//! band cache, not by matrix size.

use std::borrow::Cow;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::matrix::{DenseMatrix, Matrix};
use crate::partition::SamplingRound;

use super::chunk::{IoCounters, StoreReader};

/// Owning handle to a matrix, wherever it lives.
#[derive(Clone, Debug)]
pub enum MatrixRef {
    /// Fully materialized in RAM.
    InMem(Arc<Matrix>),
    /// Resident on disk in a LAMC2/LAMC3 store; tiles stream in on
    /// demand (reading only the chunks each block intersects).
    Stored(Arc<StoreReader>),
}

impl MatrixRef {
    pub fn in_mem(matrix: Matrix) -> Self {
        MatrixRef::InMem(Arc::new(matrix))
    }

    pub fn stored(reader: StoreReader) -> Self {
        MatrixRef::Stored(Arc::new(reader))
    }

    /// Open a LAMC2/LAMC3 store file as a matrix handle.
    pub fn open_store(path: &Path) -> Result<Self> {
        Ok(MatrixRef::stored(StoreReader::open(path)?))
    }

    /// Borrow as the `Copy` view the pipeline consumes.
    pub fn view(&self) -> MatrixView<'_> {
        match self {
            MatrixRef::InMem(m) => MatrixView::Mem(m),
            MatrixRef::Stored(r) => MatrixView::Stored(r),
        }
    }

    pub fn rows(&self) -> usize {
        self.view().rows()
    }

    pub fn cols(&self) -> usize {
        self.view().cols()
    }

    pub fn nnz(&self) -> usize {
        self.view().nnz()
    }

    pub fn is_sparse(&self) -> bool {
        self.view().is_sparse()
    }

    /// Content fingerprint. In-memory: a full `Matrix::fingerprint`
    /// scan. Stored: the O(1) header fingerprint — registering a huge
    /// store never touches its payload.
    pub fn fingerprint(&self) -> u64 {
        self.view().fingerprint()
    }

    /// "memory" or "store" (logging / STATS).
    pub fn location(&self) -> &'static str {
        self.view().location()
    }

    /// Append generation of the backing store (0 for in-memory
    /// matrices and never-appended stores).
    pub fn generation(&self) -> u64 {
        self.view().generation()
    }

    /// Row ranges changed since `generation` — see
    /// [`StoreReader::dirty_rows_since`]. Always empty for in-memory
    /// matrices (they have no append history; incremental callers fall
    /// back to fingerprint equality there).
    pub fn dirty_rows_since(&self, generation: u64) -> Vec<(usize, usize)> {
        self.view().dirty_rows_since(generation)
    }
}

impl From<Matrix> for MatrixRef {
    fn from(m: Matrix) -> Self {
        MatrixRef::in_mem(m)
    }
}

impl From<StoreReader> for MatrixRef {
    fn from(r: StoreReader) -> Self {
        MatrixRef::stored(r)
    }
}

/// Borrowed, `Copy` view over a matrix in either location.
#[derive(Clone, Copy, Debug)]
pub enum MatrixView<'a> {
    Mem(&'a Matrix),
    Stored(&'a StoreReader),
}

impl<'a> MatrixView<'a> {
    pub fn rows(&self) -> usize {
        match self {
            MatrixView::Mem(m) => m.rows(),
            MatrixView::Stored(r) => r.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            MatrixView::Mem(m) => m.cols(),
            MatrixView::Stored(r) => r.cols(),
        }
    }

    /// Stored entries (dense counts every entry).
    pub fn nnz(&self) -> usize {
        match self {
            MatrixView::Mem(m) => m.nnz(),
            MatrixView::Stored(r) => r.nnz(),
        }
    }

    pub fn is_sparse(&self) -> bool {
        match self {
            MatrixView::Mem(m) => m.is_sparse(),
            MatrixView::Stored(r) => r.is_sparse(),
        }
    }

    pub fn fingerprint(&self) -> u64 {
        match self {
            MatrixView::Mem(m) => m.fingerprint(),
            MatrixView::Stored(r) => r.fingerprint(),
        }
    }

    pub fn location(&self) -> &'static str {
        match self {
            MatrixView::Mem(_) => "memory",
            MatrixView::Stored(_) => "store",
        }
    }

    /// Append generation of the backing store (0 for in-memory
    /// matrices and never-appended stores).
    pub fn generation(&self) -> u64 {
        match self {
            MatrixView::Mem(_) => 0,
            MatrixView::Stored(r) => r.generation(),
        }
    }

    /// Row ranges changed since `generation` — see
    /// [`StoreReader::dirty_rows_since`]. Empty for in-memory matrices.
    pub fn dirty_rows_since(&self, generation: u64) -> Vec<(usize, usize)> {
        match self {
            MatrixView::Mem(_) => Vec::new(),
            MatrixView::Stored(r) => r.dirty_rows_since(generation),
        }
    }

    /// Gather the dense submatrix `A[rows, cols]` (global ids, arbitrary
    /// order). Identical output for both arms over equal content; only
    /// the stored arm can fail (I/O, checksum).
    pub fn gather_block(&self, rows: &[usize], cols: &[usize]) -> Result<DenseMatrix> {
        match self {
            MatrixView::Mem(m) => Ok(m.gather_block(rows, cols)),
            MatrixView::Stored(r) => r.tile(rows, cols),
        }
    }

    /// The whole matrix: borrowed when in memory, materialized from disk
    /// when stored (only the whole-matrix baselines need this).
    pub fn materialize(&self) -> Result<Cow<'a, Matrix>> {
        match *self {
            MatrixView::Mem(m) => Ok(Cow::Borrowed(m)),
            MatrixView::Stored(r) => Ok(Cow::Owned(r.read_all()?)),
        }
    }

    /// Ask the backing store to warm its caches for these upcoming
    /// sampling rounds (see [`StoreReader::prefetch_plan`]). A no-op
    /// for in-memory matrices — there is nothing to fetch ahead — and
    /// always advisory: results never depend on it.
    pub fn prefetch_plan(&self, rounds: &[SamplingRound]) {
        if let MatrixView::Stored(r) = self {
            r.prefetch_plan(rounds);
        }
    }

    /// Would [`MatrixView::prefetch_plan`] ever do anything? False for
    /// in-memory matrices and for readers with prefetch disabled — the
    /// scheduler uses this to keep its flat (barrier-free) dispatch
    /// when there is no prefetch to overlap with.
    pub fn prefetch_enabled(&self) -> bool {
        match self {
            MatrixView::Mem(_) => false,
            MatrixView::Stored(r) => r.prefetch_enabled(),
        }
    }

    /// Point-in-time I/O + prefetch counters of the backing store (all
    /// zeros for in-memory matrices).
    pub fn io_counters(&self) -> IoCounters {
        match self {
            MatrixView::Mem(_) => IoCounters::default(),
            MatrixView::Stored(r) => r.io_counters(),
        }
    }

    /// Claim the backing store's unclaimed counter increments (see
    /// [`StoreReader::take_io_delta`]); zeros for in-memory matrices.
    /// `run_rounds`/`run_baseline` fold this into the run's `Stats`.
    pub fn take_io_delta(&self) -> IoCounters {
        match self {
            MatrixView::Mem(_) => IoCounters::default(),
            MatrixView::Stored(r) => r.take_io_delta(),
        }
    }
}

impl<'a> From<&'a Matrix> for MatrixView<'a> {
    fn from(m: &'a Matrix) -> Self {
        MatrixView::Mem(m)
    }
}

impl<'a> From<&'a StoreReader> for MatrixView<'a> {
    fn from(r: &'a StoreReader) -> Self {
        MatrixView::Stored(r)
    }
}

impl<'a> From<&'a MatrixRef> for MatrixView<'a> {
    fn from(r: &'a MatrixRef) -> Self {
        r.view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::store::chunk::pack_matrix;

    fn stored_copy(matrix: &Matrix, name: &str) -> StoreReader {
        let dir = std::env::temp_dir().join("lamc_view_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        pack_matrix(matrix, &path, 5).unwrap();
        StoreReader::open(&path).unwrap()
    }

    #[test]
    fn both_arms_agree_on_shape_and_gather() {
        let mut rng = Xoshiro256::seed_from(41);
        let matrix = Matrix::Dense(DenseMatrix::randn(23, 13, &mut rng));
        let reader = stored_copy(&matrix, "agree.lamc2");
        let mem: MatrixView = (&matrix).into();
        let disk: MatrixView = (&reader).into();
        assert_eq!(mem.rows(), disk.rows());
        assert_eq!(mem.cols(), disk.cols());
        assert_eq!(mem.nnz(), disk.nnz());
        assert_eq!(mem.is_sparse(), disk.is_sparse());
        let rows = [19, 2, 7];
        let cols = [12, 0, 3, 4];
        assert_eq!(
            mem.gather_block(&rows, &cols).unwrap().data(),
            disk.gather_block(&rows, &cols).unwrap().data(),
        );
    }

    #[test]
    fn materialize_round_trips_stored_content() {
        let mut rng = Xoshiro256::seed_from(42);
        let matrix = Matrix::Dense(DenseMatrix::randn(11, 7, &mut rng));
        let reader = stored_copy(&matrix, "materialize.lamc2");
        let view: MatrixView = (&reader).into();
        match &*view.materialize().unwrap() {
            Matrix::Dense(got) => match &matrix {
                Matrix::Dense(want) => assert_eq!(got, want),
                _ => unreachable!(),
            },
            _ => panic!("layout changed"),
        }
    }

    #[test]
    fn matrix_ref_is_cheap_to_clone_and_fingerprints() {
        let mut rng = Xoshiro256::seed_from(43);
        let matrix = Matrix::Dense(DenseMatrix::randn(9, 4, &mut rng));
        let mem_fp = matrix.fingerprint();
        let reader = stored_copy(&matrix, "refs.lamc2");
        let stored_fp = reader.fingerprint();
        let a = MatrixRef::in_mem(matrix);
        let b = a.clone();
        assert_eq!(a.fingerprint(), mem_fp);
        assert_eq!(b.fingerprint(), mem_fp);
        let c = MatrixRef::stored(reader);
        assert_eq!(c.fingerprint(), stored_fp);
        assert_eq!(c.location(), "store");
        assert_eq!(a.location(), "memory");
        // Same content, different location ⇒ different execution path ⇒
        // deliberately different fingerprint (mirrors dense-vs-CSR).
        assert_ne!(mem_fp, stored_fp);
    }
}
