//! Background chunk prefetcher: overlap store I/O with compute.
//!
//! `coordinator::run_rounds` knows the full (round, grid) job list —
//! and therefore the exact set of row/column bands every job will touch
//! — before any worker runs (paper §IV-B/C: the partition grid is fixed
//! at sampling time). This module turns that knowledge into overlap:
//! `plan_chunks` maps upcoming [`SamplingRound`]s to the ordered,
//! deduplicated chunk ids they will read, and `Prefetcher` is the
//! lazily spawned thread that streams those chunks into the reader's
//! **separately budgeted** prefetch cache while the current round's
//! blocks are still co-clustering (both are crate-internal — the
//! public surface is [`StoreReader::prefetch_plan`]).
//!
//! [`StoreReader::prefetch_plan`]: crate::store::StoreReader::prefetch_plan
//!
//! Design rules, each load-bearing:
//!
//! * **Advisory only.** The prefetcher never surfaces errors and never
//!   changes `tile` semantics — a missing, corrupt or slow prefetch
//!   just leaves the demand path to do what it always did. Labels are
//!   byte-identical with prefetch on, off, or starved.
//! * **Own file handle.** Prefetch reads never contend the gathers'
//!   file mutex; the kernel interleaves the two read streams.
//! * **Separate budget.** Prefetched chunks live in their own
//!   [`ByteLru`](crate::cache::ByteLru) pool, so warming round `r+1`
//!   can never evict round `r`'s hot chunks.
//! * **Throttled, not greedy.** When the prefetch pool is full the
//!   thread waits for consumption (promotion frees room) instead of
//!   churning its own earlier work; only after a patience window does
//!   it conclude the plan has diverged from actual access and push out
//!   stale entries — counted as `prefetch_wasted_bytes`.
//! * **Single-flight.** A shared in-flight registry keeps the
//!   prefetcher and a concurrent gather from decoding the same chunk
//!   twice; whoever registers first decodes, the other waits or skips.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::partition::SamplingRound;

use super::chunk::{
    decode_stored_payload, fetch_chunk_mapped, read_verified_payload, ReaderShared,
};
use super::format::{ChunkMeta, Layout, StoreHeader};

/// How long a throttled prefetch waits for consumption before deciding
/// the plan is stale and evicting never-consumed entries to progress.
const STALE_PATIENCE: Duration = Duration::from_millis(250);

/// One timed slice of the throttle wait (re-checks the stop flag).
const THROTTLE_SLICE: Duration = Duration::from_millis(5);

/// Map upcoming sampling rounds to the ordered list of chunk ids their
/// block gathers will touch — job order, first occurrence wins, every
/// id unique. This is the *plan* the prefetcher executes; it is derived
/// purely from the store geometry and the jobs' global row/column ids,
/// the same arithmetic [`tile`](crate::store::StoreReader::tile) uses
/// to pick chunks.
pub(crate) fn plan_chunks(header: &StoreHeader, rounds: &[SamplingRound]) -> Vec<usize> {
    let h = header.chunk_rows.max(1);
    let w = header.chunk_cols.max(1);
    let n_col_bands = header.n_col_bands();
    let mut seen = vec![false; header.n_chunks];
    let mut out = Vec::new();
    for round in rounds {
        for job in &round.jobs {
            // Sorted, deduplicated band lists (a job's rows are a
            // permutation slice — many rows share a band).
            let mut row_bands: Vec<usize> = job.rows.iter().map(|&r| r / h).collect();
            row_bands.sort_unstable();
            row_bands.dedup();
            let mut col_bands: Vec<usize> = job.cols.iter().map(|&c| c / w).collect();
            col_bands.sort_unstable();
            col_bands.dedup();
            for &rb in &row_bands {
                for &cb in &col_bands {
                    let idx = rb * n_col_bands + cb;
                    if let Some(slot) = seen.get_mut(idx) {
                        if !*slot {
                            *slot = true;
                            out.push(idx);
                        }
                    }
                }
            }
        }
    }
    out
}

/// Handle to the background prefetch thread. Owned by the
/// [`StoreReader`](crate::store::StoreReader), spawned on the first
/// non-empty plan; dropping it (with the reader) stops the thread
/// promptly.
pub(crate) struct Prefetcher {
    tx: Option<mpsc::Sender<Vec<usize>>>,
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// Planned chunks not yet processed (fetched or skipped) — the
    /// `prefetch_idle` signal tests synchronize on.
    queued: Arc<AtomicU64>,
}

impl Prefetcher {
    pub(crate) fn spawn(
        path: PathBuf,
        layout: Layout,
        index: Arc<Vec<ChunkMeta>>,
        shared: Arc<ReaderShared>,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Vec<usize>>();
        let stop = Arc::new(AtomicBool::new(false));
        let queued = Arc::new(AtomicU64::new(0));
        let t_stop = Arc::clone(&stop);
        let t_queued = Arc::clone(&queued);
        let handle = std::thread::Builder::new()
            .name("lamc-prefetch".into())
            .spawn(move || {
                // Own handle: prefetch I/O never contends the reader's
                // file mutex. If the file can't be reopened the thread
                // just drains plans — prefetch is advisory.
                let mut file = File::open(&path).ok();
                while let Ok(plan) = rx.recv() {
                    for idx in plan {
                        if t_stop.load(Ordering::Relaxed) {
                            return;
                        }
                        if let Some(f) = file.as_mut() {
                            fetch_one(f, &path, layout, &index, &shared, idx, &t_stop);
                        }
                        t_queued.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            })
            .expect("spawn store prefetcher");
        Self { tx: Some(tx), handle: Some(handle), stop, queued }
    }

    /// Queue a plan (ordered chunk ids). Never blocks.
    pub(crate) fn send(&self, chunks: Vec<usize>) {
        if let Some(tx) = &self.tx {
            self.queued.fetch_add(chunks.len() as u64, Ordering::Relaxed);
            if tx.send(chunks).is_err() {
                self.queued.store(0, Ordering::Relaxed);
            }
        }
    }

    /// True when every queued chunk has been fetched or skipped.
    pub(crate) fn idle(&self) -> bool {
        self.queued.load(Ordering::Relaxed) == 0
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Closing the channel ends a blocked `recv`; the stop flag ends
        // an in-plan loop within one throttle slice.
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Fetch one planned chunk into the prefetch cache. Skips chunks that
/// are already resident or in flight; throttles while the pool is full;
/// swallows every error (the demand path owns error reporting).
fn fetch_one(
    file: &mut File,
    path: &Path,
    layout: Layout,
    index: &[ChunkMeta],
    shared: &ReaderShared,
    idx: usize,
    stop: &AtomicBool,
) {
    let Some(&meta) = index.get(idx) else { return };
    // Budget against the *decoded* (uncompressed) size — that is what
    // the pool will hold resident, whatever the chunk's on-disk codec.
    let est = meta.raw_len as usize;
    if est > shared.prefetch_budget {
        return; // could never be held — don't waste the read
    }
    // Already in the hot cache? `peek` so prefetch never ages it.
    if shared.hot_budget > 0 && shared.hot.lock().unwrap().peek(&idx).is_some() {
        return;
    }
    // Throttle: hold the fetch until the pool has room. Decoded size
    // equals `raw_len` for both layouts, so `est` is exact.
    {
        let mut pool = shared.prefetched.lock().unwrap();
        if pool.peek(&idx).is_some() {
            return; // an earlier plan already fetched it
        }
        // Patience is wall-clock, not wake-count: consumption notifies
        // wake this loop early, and counting those wakes as full slices
        // would burn the window in far less than STALE_PATIENCE. And it
        // restarts whenever a consumption lands — a slow-but-advancing
        // compute wave is a live plan, not a diverged one; only a full
        // window with *zero* consumption triggers stale eviction.
        let mut waiting_since = std::time::Instant::now();
        let mut hits_seen = shared.prefetch_hits.load(Ordering::Relaxed);
        while pool.bytes() + est > shared.prefetch_budget {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let hits_now = shared.prefetch_hits.load(Ordering::Relaxed);
            if hits_now != hits_seen {
                hits_seen = hits_now;
                waiting_since = std::time::Instant::now();
            }
            if waiting_since.elapsed() >= STALE_PATIENCE {
                // The plan has diverged from actual access: reclaim
                // room from never-consumed entries, oldest first.
                while pool.bytes() + est > shared.prefetch_budget {
                    let Some((_, chunk)) = pool.pop_lru() else { return };
                    shared
                        .prefetch_wasted_bytes
                        .fetch_add(chunk.resident_bytes() as u64, Ordering::Relaxed);
                }
                break;
            }
            let (guard, _) = shared.prefetch_room.wait_timeout(pool, THROTTLE_SLICE).unwrap();
            pool = guard;
        }
    }
    // A throttle wait is long enough for a gather to have demand-loaded
    // this chunk — re-check the hot cache before spending the read.
    if shared.hot_budget > 0 && shared.hot.lock().unwrap().peek(&idx).is_some() {
        return;
    }
    // Single-flight: if a gather is decoding this chunk right now, it
    // will land in the hot cache — fetching it again is pure waste.
    {
        let mut inflight = shared.inflight.lock().unwrap();
        if !inflight.insert(idx) {
            return;
        }
    }
    // Publish into the pool BEFORE clearing the in-flight entry: a
    // gather waiting on this chunk must find it resident when it wakes,
    // or it would re-register and decode the same payload again.
    let chunk = read_and_decode(file, path, layout, idx, &meta, shared);
    let displaced = chunk.map(|chunk| {
        let bytes = chunk.resident_bytes();
        shared.prefetched.lock().unwrap().insert(idx, chunk, bytes)
    });
    shared.inflight.lock().unwrap().remove(&idx);
    shared.inflight_done.notify_all();
    let Some(displaced) = displaced else { return };

    for (_, evicted) in displaced.evicted {
        shared.prefetch_wasted_bytes.fetch_add(evicted.resident_bytes() as u64, Ordering::Relaxed);
    }
    if let Some(rejected) = displaced.rejected {
        shared.prefetch_wasted_bytes.fetch_add(rejected.resident_bytes() as u64, Ordering::Relaxed);
    }
    shared.prefetch_issued.fetch_add(1, Ordering::Relaxed);
}

/// The prefetcher's read path: the reader's shared fetch-verify-decode
/// helpers (the mapped path when a mapping exists, else a pread off the
/// prefetcher's own handle), with every failure a silent skip instead
/// of an error (the demand path owns error reporting).
fn read_and_decode(
    file: &mut File,
    path: &Path,
    layout: Layout,
    idx: usize,
    meta: &ChunkMeta,
    shared: &ReaderShared,
) -> Option<Arc<super::chunk::DecodedChunk>> {
    let chunk = if let Some(map) = &shared.mmap {
        fetch_chunk_mapped(map, path, layout, idx, meta, shared).ok()?
    } else {
        let stored = read_verified_payload(file, path, idx, meta, shared).ok()?;
        decode_stored_payload(path, layout, idx, meta, &stored, shared).ok()?
    };
    Some(Arc::new(chunk))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::BlockJob;

    fn header(rows: usize, cols: usize, chunk_rows: usize, chunk_cols: usize) -> StoreHeader {
        let n_row_bands = rows.div_ceil(chunk_rows);
        let n_col_bands = cols.div_ceil(chunk_cols);
        StoreHeader {
            version: super::super::format::VERSION_TILED,
            layout: Layout::Dense,
            rows,
            cols,
            nnz: (rows * cols) as u64,
            chunk_rows,
            chunk_cols,
            n_chunks: n_row_bands * n_col_bands,
            fingerprint: 0,
            codec: crate::store::Codec::None,
            generation: 0,
        }
    }

    fn job(round: usize, rows: Vec<usize>, cols: Vec<usize>) -> SamplingRound {
        SamplingRound { round, jobs: vec![BlockJob { round, grid: (0, 0), rows, cols }] }
    }

    #[test]
    fn plan_covers_exactly_the_touched_chunks() {
        // 4 row bands x 3 col bands of a 40x30 store in 10x10 tiles.
        let h = header(40, 30, 10, 10);
        // Rows 5, 25 -> bands 0, 2; cols 12, 14 -> band 1.
        let plan = plan_chunks(&h, &[job(0, vec![5, 25], vec![12, 14])]);
        assert_eq!(plan, vec![1, 7], "row bands {{0,2}} x col band {{1}}");
    }

    #[test]
    fn plan_deduplicates_across_jobs_and_rounds() {
        let h = header(40, 30, 10, 10);
        let rounds = [job(0, vec![0, 1], vec![0]), job(1, vec![2, 11], vec![1, 29])];
        // Round 0: chunk 0. Round 1: row bands {0,1} x col bands {0,2}
        // = chunks {0,2,3,5}; 0 is already planned.
        let plan = plan_chunks(&h, &rounds);
        assert_eq!(plan, vec![0, 2, 3, 5]);
    }

    #[test]
    fn plan_preserves_job_order() {
        let h = header(40, 30, 10, 10);
        let rounds = [job(0, vec![35], vec![25]), job(1, vec![0], vec![0])];
        let plan = plan_chunks(&h, &rounds);
        assert_eq!(plan, vec![11, 0], "later rounds fetch after earlier ones");
    }

    #[test]
    fn plan_on_row_band_store_ignores_column_split() {
        // LAMC2 geometry: chunk_cols == cols, one col band.
        let h = header(40, 30, 10, 30);
        let plan = plan_chunks(&h, &[job(0, vec![0, 39], vec![3, 29])]);
        assert_eq!(plan, vec![0, 3]);
    }
}
