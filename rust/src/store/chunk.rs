//! Streaming writer and random-access reader for LAMC2/LAMC3 stores.
//!
//! [`ChunkWriter`] is the ingest side: rows arrive one at a time
//! (`append_dense_row` / `append_sparse_row`), are buffered into the
//! current row band, and each band is sealed — encoded, checksummed,
//! written, fsynced — the moment it fills. In tiled (LAMC3) mode the
//! band is split into column tiles as it seals, so tiled ingest is
//! exactly as streaming as row-band ingest: peak writer memory is one
//! band, never the matrix, and total row count need not be known up
//! front (the self-description lives in the footer, written by
//! `finish`).
//!
//! [`StoreReader`] is the serving side: `tile(rows, cols)` gathers an
//! arbitrary-order submatrix by reading **only the chunks the requested
//! rows *and columns* intersect**, verifying each chunk's checksum
//! before use. On a row-band store that is every band the rows touch;
//! on a tiled store a column-heavy query skips the column bands it
//! doesn't need — strictly fewer bytes off disk for the planner's
//! submatrix access pattern. A byte-bounded [`ByteLru`] of decoded
//! chunks (the same LRU the service result cache uses) absorbs the
//! re-reads a partitioned co-clustering round generates; with the cache
//! disabled, peak reader memory is one decoded chunk plus the gathered
//! tile.
//!
//! The reader can also warm itself *ahead* of the compute wave: feed it
//! the scheduler's upcoming rounds via [`StoreReader::prefetch_plan`]
//! and a background thread (see [`super::prefetch`]) streams the chunks
//! those rounds will touch into a **separately budgeted** prefetch
//! cache, so warming the next round can never evict the current round's
//! hot chunks. A shared single-flight registry keeps the prefetcher and
//! a concurrent gather from ever decoding the same chunk twice.

use std::collections::{BTreeMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::cache::ByteLru;
use crate::matrix::{CsrMatrix, DenseMatrix, Matrix};
use crate::partition::SamplingRound;

use super::codec::{self, Codec};
use super::format::{
    checksum_bytes, decode_footer, encode_footer, store_fingerprint, ChunkMeta, Layout,
    StoreError, StoreHeader, DEFAULT_CHUNK_ROWS, FOOTER_MAGIC, FOOTER_MAGIC_TILED, MAGIC,
    MAGIC_TILED, TRAILER_BYTES, VERSION, VERSION_CODEC, VERSION_GEN, VERSION_TILED,
    VERSION_TILED_CODEC, VERSION_TILED_GEN,
};
use super::mmap::Mmap;
use super::prefetch::{plan_chunks, Prefetcher};

/// Default byte budget for the decoded-chunk cache of [`StoreReader::open`].
pub const DEFAULT_CACHE_BYTES: usize = 64 << 20;

/// Default byte budget for the prefetch cache of [`StoreReader::open`]
/// (a *separate* pool: prefetched chunks never compete with the hot
/// decoded-chunk cache for residency).
pub const DEFAULT_PREFETCH_BYTES: usize = 32 << 20;

/// What a finished ingest produced (printed by `lamc pack` / `ingest` /
/// `repack`).
#[derive(Clone, Debug)]
pub struct StoreSummary {
    pub path: PathBuf,
    pub layout: Layout,
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub chunks: usize,
    pub chunk_rows: usize,
    /// Column-band width (`cols` for a row-band store).
    pub chunk_cols: usize,
    /// Tiled (LAMC3) vs row-band (LAMC2).
    pub tiled: bool,
    pub fingerprint: u64,
    /// Total file size, footer included.
    pub file_bytes: u64,
    /// Payload codec the writer was configured with.
    pub codec: Codec,
    /// Uncompressed payload bytes across all chunks (equals the stored
    /// payload bytes when `codec` is [`Codec::None`]) — the numerator
    /// of the on-disk compression ratio.
    pub raw_payload_bytes: u64,
    /// Stored (possibly compressed) payload bytes across all chunks.
    pub stored_payload_bytes: u64,
}

/// Streaming row-append writer. See the module docs for the protocol.
pub struct ChunkWriter {
    path: PathBuf,
    file: BufWriter<File>,
    layout: Layout,
    cols: usize,
    chunk_rows: usize,
    /// `Some(width)` writes the tiled (LAMC3) grid; `None` row bands.
    chunk_cols: Option<usize>,
    /// Bytes written so far (leading magic included) = next chunk offset.
    offset: u64,
    index: Vec<ChunkMeta>,
    // Current (open) band.
    dense_buf: Vec<f32>,
    indptr: Vec<u64>,
    indices: Vec<u32>,
    values: Vec<f32>,
    rows_in_chunk: usize,
    total_rows: usize,
    total_nnz: u64,
    /// `repack` carries the source fingerprint forward so re-chunking
    /// the same content never changes its identity.
    fingerprint_override: Option<u64>,
    /// Payload codec. [`Codec::None`] writes the pre-codec version-1/2
    /// footer byte-for-byte; anything else writes revision 3/4.
    codec: Codec,
    /// Checksums of the **uncompressed** payloads, in chunk order — the
    /// fingerprint chain, kept separate from the per-entry checksums
    /// (which cover the stored bytes) so the fingerprint is identical
    /// under every codec.
    raw_checksums: Vec<u64>,
    /// Uncompressed payload bytes sealed so far.
    raw_payload_bytes: u64,
    /// Append generation stamped on chunks sealed by this session:
    /// 0 for a fresh ingest, old generation + 1 under `append_to`.
    generation: u64,
    /// True under [`ChunkWriter::append_to`]: `finish` writes the
    /// generation footer revision (5/6) and trims any residue of the
    /// overwritten old footer.
    append_mode: bool,
}

impl ChunkWriter {
    /// Create a row-band (LAMC2) store file and start an ingest. `cols`
    /// is fixed up front (every row must have this width); the row
    /// count is not.
    pub fn create(path: &Path, layout: Layout, cols: usize, chunk_rows: usize) -> Result<Self> {
        Self::create_inner(path, layout, cols, chunk_rows, None)
    }

    /// Create a tiled (LAMC3) store: chunks form a `chunk_rows` ×
    /// `chunk_cols` grid of tiles, sealed band by band.
    pub fn create_tiled(
        path: &Path,
        layout: Layout,
        cols: usize,
        chunk_rows: usize,
        chunk_cols: usize,
    ) -> Result<Self> {
        ensure!(chunk_cols > 0, "tile width must be positive");
        Self::create_inner(path, layout, cols, chunk_rows, Some(chunk_cols))
    }

    fn create_inner(
        path: &Path,
        layout: Layout,
        cols: usize,
        chunk_rows: usize,
        chunk_cols: Option<usize>,
    ) -> Result<Self> {
        ensure!(cols > 0, "store needs at least one column");
        ensure!(chunk_rows > 0, "chunk height must be positive");
        let mut file = BufWriter::new(
            File::create(path).with_context(|| format!("create store {path:?}"))?,
        );
        file.write_all(if chunk_cols.is_some() { MAGIC_TILED } else { MAGIC })?;
        Ok(Self {
            path: path.to_path_buf(),
            file,
            layout,
            cols,
            chunk_rows,
            chunk_cols,
            offset: MAGIC.len() as u64,
            index: Vec::new(),
            dense_buf: Vec::new(),
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            rows_in_chunk: 0,
            total_rows: 0,
            total_nnz: 0,
            fingerprint_override: None,
            codec: Codec::None,
            raw_checksums: Vec::new(),
            raw_payload_bytes: 0,
            generation: 0,
            append_mode: false,
        })
    }

    /// Re-open a finished store and resume its ingest: appended rows
    /// seal onto the existing payload region (a partial last band is
    /// read back into the band buffer, its chunks dropped, and the file
    /// position rewound over them, so the final chunk grid is exactly
    /// what a from-scratch pack of the concatenated matrix would
    /// produce). `finish` writes a **generation** footer (revision 5/6)
    /// whose append generation is the old footer's plus one; every
    /// chunk sealed by this session is stamped with the new generation,
    /// so readers can ask for the dirty bands since any base
    /// generation. The content fingerprint is recomputed over the full
    /// uncompressed-payload checksum chain — O(index) for stores that
    /// already carry a generation footer; appending to a pre-generation
    /// store with compressed chunks re-reads those payloads once to
    /// recover their raw checksums.
    ///
    /// Geometry, layout and codec are carried over from the store. A
    /// crash before `finish` leaves the file without a valid footer:
    /// readers report it as `Truncated`/`Corrupt` (typed
    /// [`StoreError`]), the same taxonomy as a fresh ingest that died.
    pub fn append_to(path: &Path) -> Result<Self> {
        let reader = StoreReader::open_with_budgets(path, 0, 0)?;
        let header = reader.header().clone();
        let mut index = reader.index_entries().to_vec();
        let layout = header.layout;
        let tiled = header.is_tiled();

        // Fingerprint chain inputs for the retained chunks. Generation
        // footers persist them per entry; pre-generation footers only
        // do for raw chunks (stored checksum == raw checksum), so a
        // compressed pre-generation chunk is re-read once here.
        for (i, e) in index.iter_mut().enumerate() {
            if e.codec != Codec::None && e.raw_checksum == 0 {
                let mut file = reader.file.lock().unwrap();
                let stored = read_verified_payload(&mut file, path, i, e, &reader.shared)?;
                let raw = codec::decode(e.codec, &stored, e.raw_len as usize, path)
                    .with_context(|| format!("decode chunk {i} of {path:?}"))?;
                e.raw_checksum = checksum_bytes(&raw);
            }
        }

        // Read a partial last band back into the open-band buffers and
        // drop its chunks: they will be re-sealed (with the appended
        // rows) at the same offset, keeping the payload contiguous.
        let chunk_rows = header.chunk_rows;
        let band_rows = if header.rows > 0 { header.rows % chunk_rows } else { 0 };
        let n_col_bands = header.n_col_bands();
        let mut dense_buf = Vec::new();
        let mut indptr = vec![0u64];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        if band_rows > 0 {
            let rb = header.n_row_bands() - 1;
            let tiles = reader.band_tiles(rb)?;
            match layout {
                Layout::Dense => {
                    dense_buf = vec![0.0f32; band_rows * header.cols];
                    for (meta, chunk) in &tiles {
                        let vals = chunk.dense_values().expect("dense store yields dense chunks");
                        for r in 0..band_rows {
                            let dst = r * header.cols + meta.col_lo;
                            dense_buf[dst..dst + meta.cols]
                                .copy_from_slice(&vals[r * meta.cols..(r + 1) * meta.cols]);
                        }
                    }
                }
                Layout::Csr => {
                    for r in 0..band_rows {
                        // Column bands come back in increasing col_lo
                        // order and tile rows are index-sorted, so the
                        // concatenation is globally sorted.
                        for (meta, chunk) in &tiles {
                            let DecodedChunk::Csr { indptr: p, indices: ix, values: vs } =
                                chunk.as_ref()
                            else {
                                bail!("csr store {path:?} yielded a non-csr chunk");
                            };
                            for t in p[r] as usize..p[r + 1] as usize {
                                indices.push(ix[t] + meta.col_lo as u32);
                                values.push(vs[t]);
                            }
                        }
                        indptr.push(indices.len() as u64);
                    }
                }
            }
            index.truncate(index.len() - n_col_bands);
        }
        drop(reader);

        let offset = index.iter().map(|e| e.offset + e.len).max().unwrap_or(MAGIC.len() as u64);
        let raw_checksums: Vec<u64> = index.iter().map(|e| e.raw_checksum).collect();
        let raw_payload_bytes: u64 = index.iter().map(|e| e.raw_len).sum();

        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open store {path:?} for append"))?;
        file.seek(SeekFrom::Start(offset))?;

        Ok(Self {
            path: path.to_path_buf(),
            file: BufWriter::new(file),
            layout,
            cols: header.cols,
            chunk_rows,
            chunk_cols: if tiled { Some(header.chunk_cols) } else { None },
            offset,
            index,
            dense_buf,
            indptr,
            indices,
            values,
            rows_in_chunk: band_rows,
            total_rows: header.rows,
            total_nnz: header.nnz,
            fingerprint_override: None,
            codec: header.codec,
            raw_checksums,
            raw_payload_bytes,
            generation: header.generation + 1,
            append_mode: true,
        })
    }

    /// Compress chunk payloads with `codec` from here on. Call before
    /// the first row; per chunk, the smaller of the raw and encoded
    /// forms is stored (an incompressible chunk stays raw and is tagged
    /// [`Codec::None`] individually). The content fingerprint is always
    /// computed over uncompressed payloads, so the codec choice never
    /// changes a store's identity.
    pub fn set_codec(&mut self, codec: Codec) {
        debug_assert!(self.index.is_empty(), "set_codec before sealing any band");
        self.codec = codec;
    }

    /// Create with the default band height (row-band layout).
    pub fn create_default(path: &Path, layout: Layout, cols: usize) -> Result<Self> {
        Self::create(path, layout, cols, DEFAULT_CHUNK_ROWS)
    }

    /// Stamp the footer with this fingerprint instead of computing one
    /// from the chunk checksums. `repack` uses it to preserve content
    /// identity across re-chunking (the payload bytes differ; the
    /// matrix does not).
    pub fn set_fingerprint(&mut self, fingerprint: u64) {
        self.fingerprint_override = Some(fingerprint);
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Rows appended so far.
    pub fn rows(&self) -> usize {
        self.total_rows
    }

    /// Append one dense row (`row.len()` must equal `cols`).
    pub fn append_dense_row(&mut self, row: &[f32]) -> Result<()> {
        ensure!(self.layout == Layout::Dense, "append_dense_row on a {} store", self.layout.as_str());
        ensure!(row.len() == self.cols, "row has {} values, store has {} columns", row.len(), self.cols);
        self.dense_buf.extend_from_slice(row);
        self.total_nnz += self.cols as u64;
        self.row_done()
    }

    /// Append one sparse row as `(col, value)` entries. Entries may be
    /// in any order but must not repeat a column.
    pub fn append_sparse_row(&mut self, entries: &[(u32, f32)]) -> Result<()> {
        ensure!(self.layout == Layout::Csr, "append_sparse_row on a {} store", self.layout.as_str());
        let mut sorted: Vec<(u32, f32)> = entries.to_vec();
        sorted.sort_unstable_by_key(|&(j, _)| j);
        // Validate the whole row before touching writer state, so a
        // rejected row leaves the ingest resumable.
        for pair in sorted.windows(2) {
            ensure!(pair[0].0 != pair[1].0, "duplicate column {} in sparse row", pair[0].0);
        }
        if let Some(&(j, _)) = sorted.last() {
            ensure!((j as usize) < self.cols, "column {} out of bounds (cols = {})", j, self.cols);
        }
        for &(j, v) in &sorted {
            self.indices.push(j);
            self.values.push(v);
        }
        self.indptr.push(self.indices.len() as u64);
        self.total_nnz += sorted.len() as u64;
        self.row_done()
    }

    fn row_done(&mut self) -> Result<()> {
        self.rows_in_chunk += 1;
        self.total_rows += 1;
        if self.rows_in_chunk == self.chunk_rows {
            self.seal_band()?;
        }
        Ok(())
    }

    /// Encode the open band as dense column tiles:
    /// `(col_lo, tile_cols, payload, nnz)` per tile, one tile spanning
    /// the whole width in row-band mode. Each value is copied once.
    fn encode_dense_tiles(&self, tile_width: usize) -> Vec<(usize, usize, Vec<u8>, u64)> {
        let mut out = Vec::new();
        let mut col_lo = 0usize;
        while col_lo < self.cols {
            let tile_cols = tile_width.min(self.cols - col_lo);
            let mut payload = Vec::with_capacity(self.rows_in_chunk * tile_cols * 4);
            for r in 0..self.rows_in_chunk {
                let row = &self.dense_buf[r * self.cols..(r + 1) * self.cols];
                for &v in &row[col_lo..col_lo + tile_cols] {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
            }
            out.push((col_lo, tile_cols, payload, (self.rows_in_chunk * tile_cols) as u64));
            col_lo += tile_cols;
        }
        out
    }

    /// Encode the open band as CSR column tiles in **one pass over the
    /// band's entries** — each entry is bucketed into its column band,
    /// so sealing costs O(band nnz + rows·tiles), not O(nnz·tiles).
    /// Tile-relative encoding: pointers restart at 0, column indices
    /// are offsets from the tile's `col_lo`.
    fn encode_csr_tiles(&self, tile_width: usize) -> Vec<(usize, usize, Vec<u8>, u64)> {
        let n_tiles = self.cols.div_ceil(tile_width);
        let mut ptrs: Vec<Vec<u64>> = (0..n_tiles)
            .map(|_| {
                let mut v = Vec::with_capacity(self.rows_in_chunk + 1);
                v.push(0u64);
                v
            })
            .collect();
        let mut idx: Vec<Vec<u32>> = vec![Vec::new(); n_tiles];
        let mut val: Vec<Vec<f32>> = vec![Vec::new(); n_tiles];
        for r in 0..self.rows_in_chunk {
            for t in self.indptr[r] as usize..self.indptr[r + 1] as usize {
                let j = self.indices[t] as usize;
                let tb = j / tile_width;
                idx[tb].push((j - tb * tile_width) as u32);
                val[tb].push(self.values[t]);
            }
            for tb in 0..n_tiles {
                ptrs[tb].push(idx[tb].len() as u64);
            }
        }
        let mut out = Vec::with_capacity(n_tiles);
        for tb in 0..n_tiles {
            let col_lo = tb * tile_width;
            let tile_cols = tile_width.min(self.cols - col_lo);
            let nnz = idx[tb].len() as u64;
            let mut payload = Vec::with_capacity(ptrs[tb].len() * 8 + idx[tb].len() * 8);
            for &p in &ptrs[tb] {
                payload.extend_from_slice(&p.to_le_bytes());
            }
            for &j in &idx[tb] {
                payload.extend_from_slice(&j.to_le_bytes());
            }
            for &v in &val[tb] {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            out.push((col_lo, tile_cols, payload, nnz));
        }
        out
    }

    /// Encode, checksum, write and fsync the open band — as one chunk in
    /// row-band mode, as a row of column tiles in tiled mode.
    fn seal_band(&mut self) -> Result<()> {
        if self.rows_in_chunk == 0 {
            return Ok(());
        }
        let row_lo = self.total_rows - self.rows_in_chunk;
        let tile_width = self.chunk_cols.unwrap_or(self.cols);
        let tiles = match self.layout {
            Layout::Dense => self.encode_dense_tiles(tile_width),
            Layout::Csr => self.encode_csr_tiles(tile_width),
        };
        for (col_lo, tile_cols, payload, chunk_nnz) in tiles {
            // Fingerprint chain: always over the uncompressed payload.
            let raw_checksum = checksum_bytes(&payload);
            let raw_len = payload.len() as u64;
            self.raw_checksums.push(raw_checksum);
            self.raw_payload_bytes += raw_len;
            // Store-smaller-of: keep the encoded form only when it is
            // strictly smaller, else store raw and tag the chunk None.
            let (stored, chunk_codec) = if self.codec == Codec::None {
                (payload, Codec::None)
            } else {
                let encoded = codec::encode(self.codec, &payload);
                if encoded.len() < payload.len() {
                    (encoded, self.codec)
                } else {
                    (payload, Codec::None)
                }
            };
            let meta = ChunkMeta {
                offset: self.offset,
                len: stored.len() as u64,
                row_lo,
                rows: self.rows_in_chunk,
                col_lo,
                cols: tile_cols,
                nnz: chunk_nnz,
                // Entry checksum covers the stored bytes — what the
                // read path actually verifies off disk.
                checksum: if chunk_codec == Codec::None {
                    raw_checksum
                } else {
                    checksum_bytes(&stored)
                },
                codec: chunk_codec,
                raw_len,
                raw_checksum,
                gen: self.generation,
            };
            self.file.write_all(&stored)?;
            self.offset += meta.len;
            self.index.push(meta);
        }
        // Durability point: a sealed band survives a crash of the
        // ingesting process (the footer won't, and the reader reports
        // that as Truncated — re-ingest resumes from scratch).
        self.file.flush()?;
        self.file.get_ref().sync_data().with_context(|| format!("fsync {:?}", self.path))?;
        // Reset the band buffers.
        self.dense_buf.clear();
        self.indptr.clear();
        self.indptr.push(0);
        self.indices.clear();
        self.values.clear();
        self.rows_in_chunk = 0;
        Ok(())
    }

    /// Seal any partial band, write the footer, and fsync the file.
    pub fn finish(mut self) -> Result<StoreSummary> {
        self.seal_band()?;
        // Fingerprint over the *uncompressed* chunk checksums: the same
        // matrix fingerprints identically under every codec, so a
        // recompressed store keeps hitting the same result-cache
        // entries (with codec=none the two checksum chains coincide).
        let fingerprint = self.fingerprint_override.unwrap_or_else(|| {
            store_fingerprint(
                self.layout,
                self.total_rows,
                self.cols,
                self.total_nnz,
                self.raw_checksums.iter().copied(),
            )
        });
        let tiled = self.chunk_cols.is_some();
        // A fresh ingest keeps the smallest revision that can express
        // its fields (pre-codec files stay byte-stable); an append
        // always writes the generation revision.
        let version = if self.append_mode {
            if tiled { VERSION_TILED_GEN } else { VERSION_GEN }
        } else {
            match (tiled, self.codec) {
                (false, Codec::None) => VERSION,
                (true, Codec::None) => VERSION_TILED,
                (false, _) => VERSION_CODEC,
                (true, _) => VERSION_TILED_CODEC,
            }
        };
        let header = StoreHeader {
            version,
            layout: self.layout,
            rows: self.total_rows,
            cols: self.cols,
            nnz: self.total_nnz,
            chunk_rows: self.chunk_rows,
            chunk_cols: self.chunk_cols.unwrap_or(self.cols),
            n_chunks: self.index.len(),
            fingerprint,
            codec: self.codec,
            generation: self.generation,
        };
        let footer = encode_footer(&header, &self.index);
        self.file.write_all(&footer)?;
        self.file.write_all(&(footer.len() as u64).to_le_bytes())?;
        self.file.write_all(&checksum_bytes(&footer).to_le_bytes())?;
        self.file.write_all(if tiled { FOOTER_MAGIC_TILED } else { FOOTER_MAGIC })?;
        self.file.flush()?;
        if self.append_mode {
            // Trim any residue of the overwritten old footer (the new
            // end can land short of the old one when the re-sealed
            // partial band stored smaller).
            let end = self.offset + footer.len() as u64 + TRAILER_BYTES;
            self.file.get_ref().set_len(end).with_context(|| format!("truncate {:?}", self.path))?;
        }
        self.file.get_ref().sync_all().with_context(|| format!("fsync {:?}", self.path))?;
        Ok(StoreSummary {
            path: self.path.clone(),
            layout: self.layout,
            rows: self.total_rows,
            cols: self.cols,
            nnz: self.total_nnz,
            chunks: self.index.len(),
            chunk_rows: self.chunk_rows,
            chunk_cols: header.chunk_cols,
            tiled,
            fingerprint,
            file_bytes: self.offset + footer.len() as u64 + TRAILER_BYTES,
            codec: self.codec,
            raw_payload_bytes: self.raw_payload_bytes,
            stored_payload_bytes: self.offset - MAGIC.len() as u64,
        })
    }
}

/// Pack an in-memory matrix into a row-band store file (the `lamc pack`
/// core).
pub fn pack_matrix(matrix: &Matrix, path: &Path, chunk_rows: usize) -> Result<StoreSummary> {
    pack_matrix_with_codec(matrix, path, chunk_rows, Codec::None)
}

/// [`pack_matrix`] with an explicit payload codec.
pub fn pack_matrix_with_codec(
    matrix: &Matrix,
    path: &Path,
    chunk_rows: usize,
    codec: Codec,
) -> Result<StoreSummary> {
    let mut writer = ChunkWriter::create(path, layout_of(matrix), matrix.cols(), chunk_rows)?;
    writer.set_codec(codec);
    pack_into(matrix, writer)
}

/// Pack an in-memory matrix into a tiled (LAMC3) store file.
pub fn pack_matrix_tiled(
    matrix: &Matrix,
    path: &Path,
    chunk_rows: usize,
    chunk_cols: usize,
) -> Result<StoreSummary> {
    pack_matrix_tiled_with_codec(matrix, path, chunk_rows, chunk_cols, Codec::None)
}

/// [`pack_matrix_tiled`] with an explicit payload codec.
pub fn pack_matrix_tiled_with_codec(
    matrix: &Matrix,
    path: &Path,
    chunk_rows: usize,
    chunk_cols: usize,
    codec: Codec,
) -> Result<StoreSummary> {
    let mut writer =
        ChunkWriter::create_tiled(path, layout_of(matrix), matrix.cols(), chunk_rows, chunk_cols)?;
    writer.set_codec(codec);
    pack_into(matrix, writer)
}

fn layout_of(matrix: &Matrix) -> Layout {
    match matrix {
        Matrix::Dense(_) => Layout::Dense,
        Matrix::Sparse(_) => Layout::Csr,
    }
}

fn pack_into(matrix: &Matrix, mut w: ChunkWriter) -> Result<StoreSummary> {
    match matrix {
        Matrix::Dense(d) => {
            for i in 0..d.rows() {
                w.append_dense_row(d.row(i))?;
            }
        }
        Matrix::Sparse(s) => {
            let mut row: Vec<(u32, f32)> = Vec::new();
            for i in 0..s.rows() {
                row.clear();
                row.extend(s.row_iter(i).map(|(j, v)| (j as u32, v)));
                w.append_sparse_row(&row)?;
            }
        }
    }
    w.finish()
}

/// One decoded chunk (a row band or a tile).
pub(crate) enum DecodedChunk {
    Dense {
        values: Vec<f32>,
    },
    /// Zero-copy dense chunk: a view straight into the reader's file
    /// mapping. Only constructed for uncompressed dense payloads on
    /// little-endian targets when the mapped bytes are 4-byte aligned
    /// (the `f32` reinterpretation below needs both); the checksum was
    /// verified against the mapped bytes before construction.
    DenseMapped {
        map: Arc<Mmap>,
        byte_offset: usize,
        n_values: usize,
    },
    Csr {
        indptr: Vec<u64>,
        indices: Vec<u32>,
        values: Vec<f32>,
    },
}

impl DecodedChunk {
    /// The dense value slice, whichever variant backs it.
    pub(crate) fn dense_values(&self) -> Option<&[f32]> {
        match self {
            DecodedChunk::Dense { values } => Some(values),
            DecodedChunk::DenseMapped { map, byte_offset, n_values } => {
                let bytes = &map.as_slice()[*byte_offset..*byte_offset + *n_values * 4];
                debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
                // Alignment and length were checked at construction;
                // f32 LE == native layout (little-endian gate).
                Some(unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, *n_values) })
            }
            DecodedChunk::Csr { .. } => None,
        }
    }

    /// Bytes the cache accounts this chunk at — the *logical* decoded
    /// size, also for mapped chunks (residency there is the kernel's
    /// page cache, but the budget must stay workload-proportional).
    pub(crate) fn resident_bytes(&self) -> usize {
        match self {
            DecodedChunk::Dense { values } => values.len() * 4,
            DecodedChunk::DenseMapped { n_values, .. } => n_values * 4,
            DecodedChunk::Csr { indptr, indices, values } => {
                indptr.len() * 8 + indices.len() * 4 + values.len() * 4
            }
        }
    }
}

/// Point-in-time copy of a reader's I/O + prefetch counters.
///
/// `coordinator::run_rounds` claims these per run via
/// [`StoreReader::take_io_delta`] and folds the delta into that run's
/// [`crate::coordinator::Stats`], which is how reader telemetry reaches
/// the service `STATS` verb and `lamc status` (all zeros for in-memory
/// matrices).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IoCounters {
    /// Chunks read + decoded from disk (checksum-verified).
    pub chunks_read: u64,
    /// **Stored** payload bytes read from disk — compressed size for
    /// compressed chunks, so this is the number the codec shrinks.
    pub bytes_read: u64,
    /// Uncompressed payload bytes those reads decoded into. Equal to
    /// `bytes_read` on a codec=none store; the gap is the I/O the codec
    /// saved.
    pub bytes_decoded: u64,
    /// Chunk requests answered from the hot decoded-chunk cache.
    pub cache_hits: u64,
    /// Chunks the background prefetcher pulled into the prefetch cache.
    pub prefetch_issued: u64,
    /// Chunk requests answered by consuming a prefetched chunk.
    pub prefetch_hits: u64,
    /// Bytes prefetched but pushed out before anything consumed them —
    /// the plan diverged from actual access (0 on a matching plan).
    pub prefetch_wasted_bytes: u64,
}

impl IoCounters {
    /// Counter-wise `self - before` (saturating, so a racing background
    /// prefetch can never produce an underflowed delta).
    pub fn delta_since(&self, before: &IoCounters) -> IoCounters {
        IoCounters {
            chunks_read: self.chunks_read.saturating_sub(before.chunks_read),
            bytes_read: self.bytes_read.saturating_sub(before.bytes_read),
            bytes_decoded: self.bytes_decoded.saturating_sub(before.bytes_decoded),
            cache_hits: self.cache_hits.saturating_sub(before.cache_hits),
            prefetch_issued: self.prefetch_issued.saturating_sub(before.prefetch_issued),
            prefetch_hits: self.prefetch_hits.saturating_sub(before.prefetch_hits),
            prefetch_wasted_bytes: self
                .prefetch_wasted_bytes
                .saturating_sub(before.prefetch_wasted_bytes),
        }
    }
}

/// The reader state shared with the background prefetcher thread:
/// the two decoded-chunk caches, the single-flight registry, and every
/// I/O counter. Lives behind an `Arc` so the prefetcher can outlast any
/// one borrow of the reader (it still ends when the reader drops).
pub(crate) struct ReaderShared {
    /// Hot decoded-chunk cache: filled by demand loads and by promoting
    /// consumed prefetched chunks. The prefetcher never inserts here.
    pub(crate) hot: Mutex<ByteLru<usize, Arc<DecodedChunk>>>,
    pub(crate) hot_budget: usize,
    /// Prefetch cache: filled only by the prefetcher, drained by the
    /// first consumer of each chunk (entries move to `hot` on use).
    pub(crate) prefetched: Mutex<ByteLru<usize, Arc<DecodedChunk>>>,
    /// Paired with `prefetched`: signalled when consumption frees room,
    /// so a throttled prefetcher wakes instead of polling.
    pub(crate) prefetch_room: Condvar,
    pub(crate) prefetch_budget: usize,
    /// Single-flight registry: chunk ids currently being read+decoded
    /// (by a gather *or* the prefetcher). A second party waits on
    /// `inflight_done` instead of duplicating the decode.
    pub(crate) inflight: Mutex<HashSet<usize>>,
    pub(crate) inflight_done: Condvar,
    /// Watermark for [`StoreReader::take_io_delta`]: the counter values
    /// already claimed by a run. Serialized so concurrent runs sharing
    /// this reader partition the counter stream instead of each
    /// claiming the other's reads (aggregates stay exact).
    io_reported: Mutex<IoCounters>,
    /// Whole-file read-only mapping, when the platform granted one.
    /// `None` falls back to pread-into-buffers on the shared handle —
    /// behaviorally identical, just with a copy.
    pub(crate) mmap: Option<Arc<Mmap>>,
    // Telemetry: how much of the file the workload actually touched.
    pub(crate) chunks_read: AtomicU64,
    pub(crate) bytes_read: AtomicU64,
    pub(crate) bytes_decoded: AtomicU64,
    pub(crate) cache_hits: AtomicU64,
    pub(crate) prefetch_issued: AtomicU64,
    pub(crate) prefetch_hits: AtomicU64,
    pub(crate) prefetch_wasted_bytes: AtomicU64,
}

impl ReaderShared {
    fn new(hot_budget: usize, prefetch_budget: usize, mmap: Option<Arc<Mmap>>) -> Self {
        Self {
            hot: Mutex::new(ByteLru::new(hot_budget)),
            hot_budget,
            prefetched: Mutex::new(ByteLru::new(prefetch_budget)),
            prefetch_room: Condvar::new(),
            prefetch_budget,
            inflight: Mutex::new(HashSet::new()),
            inflight_done: Condvar::new(),
            io_reported: Mutex::new(IoCounters::default()),
            mmap,
            chunks_read: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            bytes_decoded: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            prefetch_issued: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            prefetch_wasted_bytes: AtomicU64::new(0),
        }
    }
}

/// Random-access reader over a finished store file (either version).
///
/// Thread-safe: `tile` may be called concurrently from the scheduler's
/// worker pool (reads are serialized on an internal file handle; decode
/// and gather run in parallel). [`StoreReader::prefetch_plan`] feeds a
/// lazily spawned background thread that warms the prefetch cache from
/// its *own* file handle, so prefetch I/O never contends the gathers'.
pub struct StoreReader {
    path: PathBuf,
    header: StoreHeader,
    index: Arc<Vec<ChunkMeta>>,
    file: Mutex<File>,
    shared: Arc<ReaderShared>,
    prefetcher: Mutex<Option<Prefetcher>>,
    tiles_served: AtomicU64,
}

impl StoreReader {
    /// Open with the default decoded-chunk and prefetch cache budgets.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with_budgets(path, DEFAULT_CACHE_BYTES, DEFAULT_PREFETCH_BYTES)
    }

    /// Open with an explicit cache budget (0 disables caching: every
    /// tile re-reads its chunks from disk — the strictest RSS bound).
    /// The prefetch budget follows the cache budget, capped at
    /// [`DEFAULT_PREFETCH_BYTES`] (so 0 disables prefetch too).
    pub fn open_with_cache(path: &Path, cache_budget: usize) -> Result<Self> {
        Self::open_with_budgets(path, cache_budget, cache_budget.min(DEFAULT_PREFETCH_BYTES))
    }

    /// Open with explicit hot-cache and prefetch byte budgets. The two
    /// pools are accounted separately: prefetched chunks can never evict
    /// the hot cache, and vice versa. `prefetch_budget` 0 makes
    /// [`StoreReader::prefetch_plan`] a no-op.
    pub fn open_with_budgets(
        path: &Path,
        cache_budget: usize,
        prefetch_budget: usize,
    ) -> Result<Self> {
        let mut file = File::open(path).with_context(|| format!("open store {path:?}"))?;
        let file_len = file.metadata()?.len();

        if file_len < MAGIC.len() as u64 {
            return Err(StoreError::NotAStore(path.to_path_buf()).into());
        }
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)?;
        // The leading magic pins the *geometry*, not the exact footer
        // revision: LAMC2 covers versions 1 and 3 (row bands, without /
        // with per-chunk codecs), LAMC3 covers 2 and 4 (tiled).
        let magic_tiled = if &magic == MAGIC {
            false
        } else if &magic == MAGIC_TILED {
            true
        } else {
            return Err(StoreError::NotAStore(path.to_path_buf()).into());
        };
        if file_len < MAGIC.len() as u64 + TRAILER_BYTES {
            return Err(StoreError::Truncated {
                path: path.to_path_buf(),
                detail: format!("{file_len} bytes is too short for a footer"),
            }
            .into());
        }

        let mut trailer = [0u8; TRAILER_BYTES as usize];
        file.seek(SeekFrom::End(-(TRAILER_BYTES as i64)))?;
        file.read_exact(&mut trailer)?;
        if &trailer[16..24] != FOOTER_MAGIC && &trailer[16..24] != FOOTER_MAGIC_TILED {
            return Err(StoreError::Truncated {
                path: path.to_path_buf(),
                detail: "footer magic missing (ingest died before finish, or partial copy)".into(),
            }
            .into());
        }
        // The trailer is outside the footer checksum's coverage, so its
        // magic must be checked against the leading magic explicitly — a
        // LAMC2 file ending in the LAMC3 trailer (or vice versa) is
        // damage, not a valid store.
        let want_footer_magic = if magic_tiled { FOOTER_MAGIC_TILED } else { FOOTER_MAGIC };
        if &trailer[16..24] != want_footer_magic {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                detail: "trailer magic does not match the store's leading magic".into(),
            }
            .into());
        }
        let footer_len = u64::from_le_bytes(trailer[0..8].try_into().unwrap());
        let footer_checksum = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
        let payload_end = match (file_len - TRAILER_BYTES).checked_sub(footer_len) {
            Some(end) if end >= MAGIC.len() as u64 => end,
            _ => {
                return Err(StoreError::Truncated {
                    path: path.to_path_buf(),
                    detail: format!("footer length {footer_len} exceeds file size {file_len}"),
                }
                .into())
            }
        };
        let mut footer = vec![0u8; footer_len as usize];
        file.seek(SeekFrom::Start(payload_end))?;
        file.read_exact(&mut footer)?;
        if checksum_bytes(&footer) != footer_checksum {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                detail: "footer checksum mismatch".into(),
            }
            .into());
        }
        let (header, index) = decode_footer(&footer, payload_end, path)?;
        if header.is_tiled() != magic_tiled {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                detail: format!(
                    "leading magic says {} geometry, footer version {} disagrees",
                    if magic_tiled { "tiled" } else { "row-band" },
                    header.version
                ),
            }
            .into());
        }

        // Map the whole (now footer-validated) file once; chunk fetches
        // slice it instead of seeking the shared handle. `None` (non-
        // unix, mapping failure, LAMC_NO_MMAP=1) keeps the pread path.
        let mmap = Mmap::map(&file, file_len as usize).map(Arc::new);

        Ok(Self {
            path: path.to_path_buf(),
            header,
            index: Arc::new(index),
            file: Mutex::new(file),
            shared: Arc::new(ReaderShared::new(cache_budget, prefetch_budget, mmap)),
            prefetcher: Mutex::new(None),
            tiles_served: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn header(&self) -> &StoreHeader {
        &self.header
    }

    pub fn rows(&self) -> usize {
        self.header.rows
    }

    pub fn cols(&self) -> usize {
        self.header.cols
    }

    /// Stored entries (dense stores count every entry).
    pub fn nnz(&self) -> usize {
        self.header.nnz as usize
    }

    pub fn layout(&self) -> Layout {
        self.header.layout
    }

    pub fn is_sparse(&self) -> bool {
        self.header.layout == Layout::Csr
    }

    /// Tiled (LAMC3) vs row-band (LAMC2) geometry.
    pub fn is_tiled(&self) -> bool {
        self.header.is_tiled()
    }

    pub fn chunk_rows(&self) -> usize {
        self.header.chunk_rows
    }

    /// Column-band width (the full width on a row-band store).
    pub fn chunk_cols(&self) -> usize {
        self.header.chunk_cols
    }

    pub fn n_chunks(&self) -> usize {
        self.header.n_chunks
    }

    /// O(1) content fingerprint from the header — see
    /// [`store_fingerprint`](super::format::store_fingerprint).
    pub fn fingerprint(&self) -> u64 {
        self.header.fingerprint
    }

    /// Append generation of this store: 0 for a freshly packed file
    /// (any pre-generation footer revision decodes as 0), bumped once
    /// per [`ChunkWriter::append_to`] session.
    pub fn generation(&self) -> u64 {
        self.header.generation
    }

    /// The chunk index, in row-band-major order.
    pub fn index_entries(&self) -> &[ChunkMeta] {
        &self.index
    }

    /// Merged, sorted `[lo, hi)` row ranges of the bands containing any
    /// chunk sealed *after* `generation` — the rows an incremental
    /// re-cluster based on that generation must treat as changed. Empty
    /// when the store has not been appended to since (in particular,
    /// always empty for `generation >= self.generation()`).
    pub fn dirty_rows_since(&self, generation: u64) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for e in self.index.iter() {
            if e.gen > generation {
                let (lo, hi) = (e.row_lo, e.row_lo + e.rows);
                match out.last_mut() {
                    Some(last) if last.1 >= lo => last.1 = last.1.max(hi),
                    _ => out.push((lo, hi)),
                }
            }
        }
        out
    }

    /// Chunks read from disk so far (checksum-verified decodes, demand
    /// loads and prefetches alike).
    pub fn chunks_read(&self) -> u64 {
        self.shared.chunks_read.load(Ordering::Relaxed)
    }

    /// *Stored* payload bytes read from disk so far — compressed chunks
    /// count their on-disk (post-codec) size, which is the point: a
    /// compressed store doing the same work reads fewer bytes.
    pub fn bytes_read(&self) -> u64 {
        self.shared.bytes_read.load(Ordering::Relaxed)
    }

    /// *Uncompressed* payload bytes produced by chunk decodes so far.
    /// Equal to [`StoreReader::bytes_read`] on a `codec=none` store;
    /// the gap between the two is the I/O the codec saved.
    pub fn bytes_decoded(&self) -> u64 {
        self.shared.bytes_decoded.load(Ordering::Relaxed)
    }

    /// Chunk requests answered from the hot decoded-chunk cache.
    pub fn cache_hits(&self) -> u64 {
        self.shared.cache_hits.load(Ordering::Relaxed)
    }

    /// Chunks the background prefetcher pulled in so far.
    pub fn prefetch_issued(&self) -> u64 {
        self.shared.prefetch_issued.load(Ordering::Relaxed)
    }

    /// Chunk requests answered by consuming a prefetched chunk.
    pub fn prefetch_hits(&self) -> u64 {
        self.shared.prefetch_hits.load(Ordering::Relaxed)
    }

    /// Prefetched bytes that were pushed out before anything consumed
    /// them. Stays 0 while the plan matches actual access.
    pub fn prefetch_wasted_bytes(&self) -> u64 {
        self.shared.prefetch_wasted_bytes.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of every I/O + prefetch counter.
    pub fn io_counters(&self) -> IoCounters {
        IoCounters {
            chunks_read: self.chunks_read(),
            bytes_read: self.bytes_read(),
            bytes_decoded: self.bytes_decoded(),
            cache_hits: self.cache_hits(),
            prefetch_issued: self.prefetch_issued(),
            prefetch_hits: self.prefetch_hits(),
            prefetch_wasted_bytes: self.prefetch_wasted_bytes(),
        }
    }

    /// Claim the counter increments since the last claim (a watermarked
    /// delta). `run_rounds`/`run_baseline` call this once per run to
    /// fold reader I/O into their `Stats`: concurrent runs sharing one
    /// reader *partition* the counter stream between them — each
    /// increment is attributed to exactly one run, so the service-wide
    /// aggregate stays exact (a before/after snapshot per run would
    /// double-count the other run's reads inside its window).
    pub fn take_io_delta(&self) -> IoCounters {
        let mut last = self.shared.io_reported.lock().unwrap();
        let now = self.io_counters();
        let delta = now.delta_since(&last);
        *last = now;
        delta
    }

    /// Tiles gathered so far.
    pub fn tiles_served(&self) -> u64 {
        self.tiles_served.load(Ordering::Relaxed)
    }

    /// High-water mark of decoded bytes resident in the hot chunk cache
    /// — proof the reader respected its byte bound over a whole pass.
    pub fn cache_peak_bytes(&self) -> usize {
        self.shared.hot.lock().unwrap().peak_bytes()
    }

    /// Chunks evicted from the hot decoded-chunk cache so far.
    pub fn cache_evictions(&self) -> u64 {
        self.shared.hot.lock().unwrap().evictions()
    }

    /// Queue the chunks these upcoming sampling rounds will touch for
    /// background prefetch (in job order, deduplicated). Returns
    /// immediately; a lazily spawned prefetcher thread streams the
    /// chunks into the prefetch cache from its own file handle. A no-op
    /// when the prefetch budget is 0. Purely advisory: results, errors
    /// and `tile` semantics are byte-identical with or without it.
    pub fn prefetch_plan(&self, rounds: &[SamplingRound]) {
        if self.shared.prefetch_budget == 0 || self.index.is_empty() {
            return;
        }
        let chunks = plan_chunks(&self.header, rounds);
        if chunks.is_empty() {
            return;
        }
        let mut guard = self.prefetcher.lock().unwrap();
        guard
            .get_or_insert_with(|| {
                Prefetcher::spawn(
                    self.path.clone(),
                    self.header.layout,
                    Arc::clone(&self.index),
                    Arc::clone(&self.shared),
                )
            })
            .send(chunks);
    }

    /// Queue every chunk of the store, in file order, for background
    /// prefetch — the sequential-scan analogue of
    /// [`StoreReader::prefetch_plan`], for whole-store sweeps
    /// (`read_all`, `store::repack_reader`) that consume chunks in
    /// index order: the prefetcher's file handle streams chunk `i+1`
    /// while the consumer decodes chunk `i`. Advisory like every
    /// prefetch path, and a no-op with prefetch disabled.
    pub fn prefetch_scan(&self) {
        if !self.prefetch_enabled() {
            return;
        }
        let chunks: Vec<usize> = (0..self.index.len()).collect();
        let mut guard = self.prefetcher.lock().unwrap();
        guard
            .get_or_insert_with(|| {
                Prefetcher::spawn(
                    self.path.clone(),
                    self.header.layout,
                    Arc::clone(&self.index),
                    Arc::clone(&self.shared),
                )
            })
            .send(chunks);
    }

    /// True when no queued prefetch work remains (every planned chunk
    /// has been fetched or skipped). Trivially true before the first
    /// [`StoreReader::prefetch_plan`] call.
    pub fn prefetch_idle(&self) -> bool {
        self.prefetcher.lock().unwrap().as_ref().map_or(true, |p| p.idle())
    }

    /// Can [`StoreReader::prefetch_plan`] ever do anything on this
    /// reader? False with a zero prefetch budget or an empty store —
    /// callers (the scheduler) skip prefetch-shaped dispatch entirely.
    pub fn prefetch_enabled(&self) -> bool {
        self.shared.prefetch_budget > 0 && !self.index.is_empty()
    }

    /// Pin every column tile of row band `rb` (decoded, column order) —
    /// the shared band-stitching step behind `read_all` and `repack`.
    /// A row-band store yields exactly one (band-wide) tile.
    pub(crate) fn band_tiles(&self, rb: usize) -> Result<Vec<(ChunkMeta, Arc<DecodedChunk>)>> {
        let n_col_bands = self.header.n_col_bands();
        let mut tiles = Vec::with_capacity(n_col_bands);
        for cb in 0..n_col_bands {
            let idx = rb * n_col_bands + cb;
            tiles.push((self.index[idx], self.load_chunk(idx)?));
        }
        Ok(tiles)
    }

    /// One pass over both caches: a hot hit refreshes recency; a
    /// prefetch hit consumes the entry (promoting it into the hot
    /// cache, which is what frees prefetch-budget room).
    fn cached_chunk(&self, idx: usize) -> Option<Arc<DecodedChunk>> {
        let sh = &*self.shared;
        if sh.hot_budget > 0 {
            if let Some(chunk) = sh.hot.lock().unwrap().get(&idx) {
                sh.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Some(Arc::clone(chunk));
            }
        }
        if sh.prefetch_budget > 0 {
            let taken = sh.prefetched.lock().unwrap().remove(&idx);
            if let Some(chunk) = taken {
                sh.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                // Consumption freed prefetch-budget room.
                sh.prefetch_room.notify_all();
                if sh.hot_budget > 0 {
                    let bytes = chunk.resident_bytes();
                    let _ = sh.hot.lock().unwrap().insert(idx, Arc::clone(&chunk), bytes);
                }
                return Some(chunk);
            }
        }
        None
    }

    /// Read, verify and decode chunk `idx` (cache- and prefetch-aware).
    pub(crate) fn load_chunk(&self, idx: usize) -> Result<Arc<DecodedChunk>> {
        let sh = &*self.shared;
        // Single-flight: if the prefetcher (or another gather) is
        // already decoding this chunk, wait for it rather than decoding
        // the same payload twice — then re-probe the caches.
        loop {
            if let Some(chunk) = self.cached_chunk(idx) {
                return Ok(chunk);
            }
            let mut inflight = sh.inflight.lock().unwrap();
            if !inflight.contains(&idx) {
                inflight.insert(idx);
                break;
            }
            // Timed wait: re-checks the registry even on a missed
            // notify (the holder may have errored out).
            let (guard, _) = sh
                .inflight_done
                .wait_timeout(inflight, Duration::from_millis(5))
                .unwrap();
            drop(guard);
        }

        let result = self.read_and_decode(idx).map(Arc::new);
        // Publish to the cache BEFORE clearing the in-flight entry:
        // a waiter that wakes in between must find the chunk resident,
        // or it would re-register and decode the same payload again.
        if let Ok(chunk) = &result {
            if sh.hot_budget > 0 {
                let bytes = chunk.resident_bytes();
                // Evicted/rejected Arcs drop here; live borrows
                // elsewhere keep their chunks alive independently.
                let _ = sh.hot.lock().unwrap().insert(idx, Arc::clone(chunk), bytes);
            }
        }
        sh.inflight.lock().unwrap().remove(&idx);
        sh.inflight_done.notify_all();
        result
    }

    /// The demand-load path: fetch chunk `idx`'s stored bytes (a slice
    /// of the shared mapping when one exists, else a pread off the
    /// shared file handle — the lock covers only the read, decode runs
    /// in parallel), verify its checksum, and decode it.
    fn read_and_decode(&self, idx: usize) -> Result<DecodedChunk> {
        let meta = self.index[idx];
        if let Some(map) = &self.shared.mmap {
            return fetch_chunk_mapped(map, &self.path, self.header.layout, idx, &meta, &self.shared);
        }
        let stored = {
            let mut file = self.file.lock().unwrap();
            read_verified_payload(&mut file, &self.path, idx, &meta, &self.shared)?
        };
        decode_stored_payload(&self.path, self.header.layout, idx, &meta, &stored, &self.shared)
    }

    /// Decode one verified chunk payload into its in-memory form.
    /// Shared by the reader's demand path and the background prefetcher
    /// (which decodes on its own thread, off its own file handle).
    pub(crate) fn decode_chunk_payload(
        path: &Path,
        layout: Layout,
        idx: usize,
        meta: &ChunkMeta,
        payload: &[u8],
    ) -> Result<DecodedChunk> {
        let corrupt = |detail: String| -> anyhow::Error {
            StoreError::Corrupt { path: path.to_path_buf(), detail }.into()
        };
        // The chunk's own width: a tile's column count, or the full
        // matrix width on a row-band store.
        let cols = meta.cols;
        // All size arithmetic is checked: a checksum-valid but crafted
        // footer must surface as Corrupt, never as an overflow panic
        // (the same threat model decode_footer guards against).
        match layout {
            Layout::Dense => {
                let want = meta.rows.checked_mul(cols).and_then(|v| v.checked_mul(4));
                if want != Some(payload.len()) {
                    return Err(corrupt(format!(
                        "dense chunk {idx} has {} bytes, want {} x {} x 4",
                        payload.len(),
                        meta.rows,
                        cols
                    )));
                }
                let values = payload
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(DecodedChunk::Dense { values })
            }
            Layout::Csr => {
                let nnz = meta.nnz as usize;
                let ptrs = meta.rows.checked_add(1).and_then(|v| v.checked_mul(8));
                let total =
                    ptrs.and_then(|p| nnz.checked_mul(8).and_then(|e| p.checked_add(e)));
                let (Some(ptr_bytes), Some(want)) = (ptrs, total) else {
                    return Err(corrupt(format!("csr chunk {idx} dimensions overflow")));
                };
                if payload.len() != want {
                    return Err(corrupt(format!(
                        "csr chunk {idx} has {} bytes, want {want}",
                        payload.len()
                    )));
                }
                let indptr: Vec<u64> = payload[..ptr_bytes]
                    .chunks_exact(8)
                    .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                    .collect();
                if indptr[0] != 0
                    || *indptr.last().unwrap() != nnz as u64
                    || indptr.windows(2).any(|w| w[0] > w[1])
                {
                    return Err(corrupt(format!("csr chunk {idx} row pointers are inconsistent")));
                }
                let indices: Vec<u32> = payload[ptr_bytes..ptr_bytes + nnz * 4]
                    .chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                if indices.iter().any(|&j| j as usize >= cols) {
                    return Err(corrupt(format!("csr chunk {idx} has a column index out of bounds")));
                }
                let values: Vec<f32> = payload[ptr_bytes + nnz * 4..]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(DecodedChunk::Csr { indptr, indices, values })
            }
        }
    }

    /// Gather the dense submatrix `A[rows, cols]` (arbitrary index
    /// order, global ids) — bit-identical to `Matrix::gather_block` on
    /// the matrix the store was packed from, reading only the chunks
    /// that intersect the requested rows **and** columns (on a tiled
    /// store, a narrow column selection skips whole column bands).
    pub fn tile(&self, rows: &[usize], cols: &[usize]) -> Result<DenseMatrix> {
        for &i in rows {
            ensure!(i < self.header.rows, "row {i} out of bounds ({} rows)", self.header.rows);
        }
        for &j in cols {
            ensure!(j < self.header.cols, "col {j} out of bounds ({} cols)", self.header.cols);
        }
        let h = self.header.chunk_rows;
        // `.max(1)` guards a hand-crafted empty store whose header
        // carries a zero extent (decode allows it only with no chunks).
        let w = self.header.chunk_cols.max(1);
        let n_col_bands = self.header.n_col_bands();
        // Group requested rows by row band and columns by column band so
        // each intersecting chunk loads once.
        let mut by_row_band: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for (bi, &gid) in rows.iter().enumerate() {
            by_row_band.entry(gid / h).or_default().push((bi, gid % h));
        }
        let mut by_col_band: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for (bj, &j) in cols.iter().enumerate() {
            by_col_band.entry(j / w).or_default().push((bj, j));
        }

        let mut out = DenseMatrix::zeros(rows.len(), cols.len());
        // Column lookup shared across chunks (CSR scatter).
        let mut col_pos: Vec<i32> = Vec::new();
        if self.header.layout == Layout::Csr {
            col_pos = vec![-1; self.header.cols];
            for (bj, &j) in cols.iter().enumerate() {
                col_pos[j] = bj as i32;
            }
        }

        for (&rb, row_picks) in &by_row_band {
            for (&cb, col_picks) in &by_col_band {
                let cidx = rb * n_col_bands + cb;
                let meta = self.index[cidx];
                let chunk = self.load_chunk(cidx)?;
                if let DecodedChunk::Csr { indptr, indices, values } = &*chunk {
                    for &(bi, local) in row_picks {
                        let dst = out.row_mut(bi);
                        for t in indptr[local] as usize..indptr[local + 1] as usize {
                            let bj = col_pos[meta.col_lo + indices[t] as usize];
                            if bj >= 0 {
                                dst[bj as usize] = values[t];
                            }
                        }
                    }
                } else {
                    // Heap-decoded and mmap-borrowed dense chunks gather
                    // through the same slice view.
                    let values =
                        chunk.dense_values().expect("non-CSR chunks expose dense values");
                    let tw = meta.cols;
                    for &(bi, local) in row_picks {
                        let src = &values[local * tw..(local + 1) * tw];
                        let dst = out.row_mut(bi);
                        for &(bj, j) in col_picks {
                            dst[bj] = src[j - meta.col_lo];
                        }
                    }
                }
            }
        }
        self.tiles_served.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Materialize the whole matrix (baselines and `lamc inspect
    /// --verify` use this; the partitioned pipeline never does).
    pub fn read_all(&self) -> Result<Matrix> {
        // Whole-store sweep in index order: warm the scan so disk I/O
        // overlaps the per-chunk decode below.
        self.prefetch_scan();
        match self.header.layout {
            Layout::Dense => {
                let (rows, cols) = (self.header.rows, self.header.cols);
                // Checked: a crafted header must error, not overflow.
                let n = rows.checked_mul(cols).ok_or_else(|| StoreError::Corrupt {
                    path: self.path.clone(),
                    detail: format!("{rows} x {cols} dense store overflows"),
                })?;
                let mut data = vec![0f32; n];
                for idx in 0..self.index.len() {
                    let meta = self.index[idx];
                    let chunk = self.load_chunk(idx)?;
                    let Some(values) = chunk.dense_values() else {
                        bail!("dense store decoded a csr chunk")
                    };
                    for lr in 0..meta.rows {
                        let dst = (meta.row_lo + lr) * cols + meta.col_lo;
                        data[dst..dst + meta.cols]
                            .copy_from_slice(&values[lr * meta.cols..(lr + 1) * meta.cols]);
                    }
                }
                Ok(Matrix::Dense(DenseMatrix::from_vec(rows, cols, data)))
            }
            Layout::Csr => {
                let n_row_bands = self.header.n_row_bands();
                // Capacity hints are clamped: header-declared sizes are
                // untrusted until each chunk's payload validates, and a
                // hint must never be the thing that aborts.
                let rows_hint = self.header.rows.saturating_add(1).min(1 << 24);
                let nnz_hint = (self.header.nnz as usize).min(1 << 24);
                let mut indptr: Vec<usize> = Vec::with_capacity(rows_hint);
                indptr.push(0);
                let mut all_indices: Vec<u32> = Vec::with_capacity(nnz_hint);
                let mut all_values: Vec<f32> = Vec::with_capacity(nnz_hint);
                for rb in 0..n_row_bands {
                    // Walking a band's tiles in column-band order per row
                    // yields globally sorted column indices.
                    let tiles = self.band_tiles(rb)?;
                    let band_rows = tiles[0].0.rows;
                    for lr in 0..band_rows {
                        for (meta, chunk) in &tiles {
                            let DecodedChunk::Csr { indptr: rel, indices, values } = &**chunk
                            else {
                                bail!("csr store decoded a dense chunk")
                            };
                            for t in rel[lr] as usize..rel[lr + 1] as usize {
                                all_indices.push(meta.col_lo as u32 + indices[t]);
                                all_values.push(values[t]);
                            }
                        }
                        indptr.push(all_indices.len());
                    }
                }
                Ok(Matrix::Sparse(CsrMatrix::new(
                    self.header.rows,
                    self.header.cols,
                    indptr,
                    all_indices,
                    all_values,
                )))
            }
        }
    }

    /// Re-read and checksum-verify every chunk (`lamc inspect --verify`).
    pub fn verify(&self) -> Result<()> {
        for idx in 0..self.index.len() {
            self.load_chunk(idx)?;
        }
        Ok(())
    }
}

/// Read chunk `idx`'s payload off `file` and verify its checksum,
/// bumping the shared I/O counters on a successful read. The one
/// read-verify implementation behind both the demand path (the
/// reader's shared file handle) and the prefetcher (its own handle) —
/// only the error disposition differs at the call sites.
pub(crate) fn read_verified_payload(
    file: &mut File,
    path: &Path,
    idx: usize,
    meta: &ChunkMeta,
    shared: &ReaderShared,
) -> Result<Vec<u8>> {
    let mut payload = vec![0u8; meta.len as usize];
    file.seek(SeekFrom::Start(meta.offset))?;
    file.read_exact(&mut payload).map_err(|e| StoreError::Truncated {
        path: path.to_path_buf(),
        detail: format!("chunk {idx} short read: {e}"),
    })?;
    shared.chunks_read.fetch_add(1, Ordering::Relaxed);
    shared.bytes_read.fetch_add(meta.len, Ordering::Relaxed);
    if checksum_bytes(&payload) != meta.checksum {
        return Err(StoreError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("chunk {idx} checksum mismatch"),
        }
        .into());
    }
    Ok(payload)
}

/// Decompress (when the chunk carries a codec) and decode one
/// checksum-verified *stored* payload — the post-read half shared by
/// the demand path, the mapped path, and the prefetcher.
pub(crate) fn decode_stored_payload(
    path: &Path,
    layout: Layout,
    idx: usize,
    meta: &ChunkMeta,
    stored: &[u8],
    shared: &ReaderShared,
) -> Result<DecodedChunk> {
    shared.bytes_decoded.fetch_add(meta.raw_len, Ordering::Relaxed);
    if meta.codec == Codec::None {
        StoreReader::decode_chunk_payload(path, layout, idx, meta, stored)
    } else {
        let raw = codec::decode(meta.codec, stored, meta.raw_len as usize, path)?;
        StoreReader::decode_chunk_payload(path, layout, idx, meta, &raw)
    }
}

/// Fetch chunk `idx` through the shared file mapping: slice the stored
/// bytes out of the map (no syscall, no copy), verify the stored
/// checksum, then decode — uncompressed dense payloads on
/// little-endian targets come back as a borrowed [`DecodedChunk::
/// DenseMapped`] view, everything else decodes through the usual
/// (decompress +) parse path.
pub(crate) fn fetch_chunk_mapped(
    map: &Arc<Mmap>,
    path: &Path,
    layout: Layout,
    idx: usize,
    meta: &ChunkMeta,
    shared: &ReaderShared,
) -> Result<DecodedChunk> {
    let lo = meta.offset as usize;
    // decode_footer bounds every extent against the payload region, so
    // this only fires if the file shrank after open.
    let stored = meta
        .offset
        .checked_add(meta.len)
        .and_then(|hi| map.as_slice().get(lo..hi as usize))
        .ok_or_else(|| StoreError::Truncated {
            path: path.to_path_buf(),
            detail: format!("chunk {idx} extends past the mapped file"),
        })?;
    shared.chunks_read.fetch_add(1, Ordering::Relaxed);
    shared.bytes_read.fetch_add(meta.len, Ordering::Relaxed);
    if checksum_bytes(stored) != meta.checksum {
        return Err(StoreError::Corrupt {
            path: path.to_path_buf(),
            detail: format!("chunk {idx} checksum mismatch"),
        }
        .into());
    }
    // Zero-copy fast path. Alignment always holds for real stores
    // (payloads start at offset 8 and every chunk length is a multiple
    // of 4), but it is checked, not assumed: a misaligned slice just
    // takes the copying decode below.
    #[cfg(target_endian = "little")]
    if meta.codec == Codec::None
        && layout == Layout::Dense
        && stored.as_ptr() as usize % 4 == 0
        && meta.rows.checked_mul(meta.cols).and_then(|v| v.checked_mul(4)) == Some(stored.len())
    {
        shared.bytes_decoded.fetch_add(meta.raw_len, Ordering::Relaxed);
        return Ok(DecodedChunk::DenseMapped {
            map: Arc::clone(map),
            byte_offset: lo,
            n_values: meta.rows * meta.cols,
        });
    }
    decode_stored_payload(path, layout, idx, meta, stored, shared)
}

impl std::fmt::Debug for StoreReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StoreReader")
            .field("path", &self.path)
            .field("version", &self.header.version)
            .field("layout", &self.header.layout)
            .field("rows", &self.header.rows)
            .field("cols", &self.header.cols)
            .field("n_chunks", &self.header.n_chunks)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lamc_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn random_dense(rows: usize, cols: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::seed_from(seed);
        DenseMatrix::randn(rows, cols, &mut rng)
    }

    fn random_sparse(rows: usize, cols: usize, nnz: usize, seed: u64) -> CsrMatrix {
        let mut rng = Xoshiro256::seed_from(seed);
        let mut trip = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            trip.push((rng.next_below(rows), rng.next_below(cols), rng.next_f32() + 0.01));
        }
        CsrMatrix::from_triplets(rows, cols, trip)
    }

    #[test]
    fn dense_pack_read_all_round_trip() {
        let d = random_dense(37, 11, 1);
        let path = tmp("dense_rt.lamc2");
        let summary = pack_matrix(&Matrix::Dense(d.clone()), &path, 8).unwrap();
        assert_eq!(summary.rows, 37);
        assert_eq!(summary.chunks, 5, "37 rows / 8-row bands");
        assert!(!summary.tiled);
        let r = StoreReader::open(&path).unwrap();
        assert_eq!((r.rows(), r.cols()), (37, 11));
        assert_eq!(r.fingerprint(), summary.fingerprint);
        match r.read_all().unwrap() {
            Matrix::Dense(got) => assert_eq!(got, d),
            _ => panic!("layout mismatch"),
        }
    }

    #[test]
    fn sparse_pack_read_all_round_trip() {
        let s = random_sparse(50, 23, 300, 2);
        let path = tmp("sparse_rt.lamc2");
        pack_matrix(&Matrix::Sparse(s.clone()), &path, 7).unwrap();
        let r = StoreReader::open(&path).unwrap();
        assert!(r.is_sparse());
        assert_eq!(r.nnz(), s.nnz());
        match r.read_all().unwrap() {
            Matrix::Sparse(got) => assert_eq!(got, s),
            _ => panic!("layout mismatch"),
        }
    }

    #[test]
    fn tiled_dense_pack_read_all_round_trip() {
        let d = random_dense(37, 11, 21);
        let path = tmp("dense_rt.lamc3");
        let summary = pack_matrix_tiled(&Matrix::Dense(d.clone()), &path, 8, 4).unwrap();
        assert!(summary.tiled);
        assert_eq!(summary.chunks, 5 * 3, "5 row bands x 3 col bands");
        let r = StoreReader::open(&path).unwrap();
        assert!(r.is_tiled());
        assert_eq!((r.chunk_rows(), r.chunk_cols()), (8, 4));
        match r.read_all().unwrap() {
            Matrix::Dense(got) => assert_eq!(got, d),
            _ => panic!("layout mismatch"),
        }
    }

    #[test]
    fn tiled_sparse_pack_read_all_round_trip() {
        let s = random_sparse(50, 23, 300, 22);
        let path = tmp("sparse_rt.lamc3");
        let summary = pack_matrix_tiled(&Matrix::Sparse(s.clone()), &path, 7, 6).unwrap();
        assert_eq!(summary.nnz as usize, s.nnz(), "tiling never drops entries");
        let r = StoreReader::open(&path).unwrap();
        assert!(r.is_tiled() && r.is_sparse());
        match r.read_all().unwrap() {
            Matrix::Sparse(got) => assert_eq!(got, s),
            _ => panic!("layout mismatch"),
        }
    }

    #[test]
    fn tile_matches_gather_block_randomized() {
        let mut rng = Xoshiro256::seed_from(3);
        for (case, matrix) in [
            Matrix::Dense(random_dense(41, 17, 31)),
            Matrix::Sparse(random_sparse(41, 17, 200, 32)),
        ]
        .into_iter()
        .enumerate()
        {
            let band_path = tmp(&format!("tile_{case}.lamc2"));
            let tiled_path = tmp(&format!("tile_{case}.lamc3"));
            pack_matrix(&matrix, &band_path, 6).unwrap();
            pack_matrix_tiled(&matrix, &tiled_path, 6, 5).unwrap();
            let band = StoreReader::open(&band_path).unwrap();
            let tiled = StoreReader::open(&tiled_path).unwrap();
            for _ in 0..20 {
                let nr = rng.next_range(1, 15);
                let nc = rng.next_range(1, 12);
                let rows = rng.sample_indices(41, nr);
                let cols = rng.sample_indices(17, nc);
                let want = matrix.gather_block(&rows, &cols);
                let got_band = band.tile(&rows, &cols).unwrap();
                let got_tiled = tiled.tile(&rows, &cols).unwrap();
                assert_eq!(got_band.data(), want.data(), "case {case} rows {rows:?} cols {cols:?}");
                assert_eq!(got_tiled.data(), want.data(), "case {case} rows {rows:?} cols {cols:?}");
            }
        }
    }

    #[test]
    fn contiguous_tile_touches_only_covering_bands() {
        let d = random_dense(64, 9, 4);
        let path = tmp("touch.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 16).unwrap();
        // Cache disabled: every chunk access is a disk read we can count.
        let r = StoreReader::open_with_cache(&path, 0).unwrap();
        assert_eq!(r.n_chunks(), 4);
        // Rows 16..32 live entirely in band 1.
        let rows: Vec<usize> = (16..32).collect();
        let cols: Vec<usize> = (0..9).collect();
        r.tile(&rows, &cols).unwrap();
        assert_eq!(r.chunks_read(), 1, "one band covers rows 16..32");
        // Rows 10..20 straddle bands 0 and 1.
        let rows: Vec<usize> = (10..20).collect();
        r.tile(&rows, &cols).unwrap();
        assert_eq!(r.chunks_read(), 3, "two more bands");
        assert_eq!(r.cache_hits(), 0);
    }

    #[test]
    fn column_heavy_query_reads_fewer_bytes_on_tiled_store() {
        // The acceptance shape: all rows, few columns. The row-band
        // store must decode full bands; the tiled store reads one
        // column band per row band — strictly fewer payload bytes.
        let d = Matrix::Dense(random_dense(64, 32, 9));
        let band_path = tmp("colheavy.lamc2");
        let tiled_path = tmp("colheavy.lamc3");
        pack_matrix(&d, &band_path, 16).unwrap();
        pack_matrix_tiled(&d, &tiled_path, 16, 8).unwrap();
        let band = StoreReader::open_with_cache(&band_path, 0).unwrap();
        let tiled = StoreReader::open_with_cache(&tiled_path, 0).unwrap();
        let rows: Vec<usize> = (0..64).collect();
        let cols: Vec<usize> = (0..4).collect(); // inside column band 0
        let a = band.tile(&rows, &cols).unwrap();
        let b = tiled.tile(&rows, &cols).unwrap();
        assert_eq!(a.data(), b.data());
        assert!(
            tiled.bytes_read() < band.bytes_read(),
            "tiled read {} bytes, row-band {}",
            tiled.bytes_read(),
            band.bytes_read()
        );
        assert_eq!(band.bytes_read(), 64 * 32 * 4, "row bands decode the full width");
        assert_eq!(tiled.bytes_read(), 64 * 8 * 4, "tiles decode one column band");
    }

    #[test]
    fn cache_absorbs_repeated_tiles() {
        let d = random_dense(32, 8, 5);
        let path = tmp("cache.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let r = StoreReader::open(&path).unwrap(); // default budget ≫ file
        let rows: Vec<usize> = (0..32).collect();
        let cols: Vec<usize> = (0..8).collect();
        r.tile(&rows, &cols).unwrap();
        r.tile(&rows, &cols).unwrap();
        assert_eq!(r.chunks_read(), 4, "second pass served from cache");
        assert_eq!(r.cache_hits(), 4);
        assert!(r.cache_peak_bytes() <= DEFAULT_CACHE_BYTES);
    }

    fn one_round(rows: Vec<usize>, cols: Vec<usize>) -> Vec<SamplingRound> {
        let job = crate::partition::BlockJob { round: 0, grid: (0, 0), rows, cols };
        vec![SamplingRound { round: 0, jobs: vec![job] }]
    }

    fn wait_prefetch_idle(r: &StoreReader) {
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while !r.prefetch_idle() {
            assert!(std::time::Instant::now() < deadline, "prefetch never drained");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn prefetch_warms_then_serves_tiles() {
        let d = random_dense(40, 12, 50);
        let path = tmp("prefetch_warm.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let r = StoreReader::open_with_budgets(&path, 1 << 20, 1 << 20).unwrap();
        // Rows 0 and 20 live in bands 0 and 2: a two-chunk plan.
        r.prefetch_plan(&one_round(vec![0, 20], vec![1, 5]));
        wait_prefetch_idle(&r);
        assert_eq!(r.prefetch_issued(), 2, "bands 0 and 2 fetched ahead");
        let tile = r.tile(&[0, 20], &[1, 5]).unwrap();
        assert_eq!(tile.data().len(), 4);
        assert_eq!(r.prefetch_hits(), 2, "both chunk requests consumed prefetched chunks");
        assert_eq!(r.prefetch_wasted_bytes(), 0, "plan matched access exactly");
        // The consumed chunks were promoted: a repeat tile is all hot hits.
        r.tile(&[0, 20], &[1, 5]).unwrap();
        assert_eq!(r.cache_hits(), 2);
        assert_eq!(r.chunks_read(), 2, "no demand load ever touched the disk");
    }

    #[test]
    fn prefetch_results_identical_and_planless_chunks_still_load() {
        let d = random_dense(30, 9, 51);
        let path = tmp("prefetch_equiv.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let plain = StoreReader::open_with_budgets(&path, 1 << 20, 0).unwrap();
        let warmed = StoreReader::open_with_budgets(&path, 1 << 20, 1 << 20).unwrap();
        // Plan covers band 0 only; the tile also needs bands 1..4 —
        // those fall back to the demand path transparently.
        warmed.prefetch_plan(&one_round(vec![0], vec![0]));
        wait_prefetch_idle(&warmed);
        let rows: Vec<usize> = (0..30).collect();
        let cols: Vec<usize> = (0..9).collect();
        let a = plain.tile(&rows, &cols).unwrap();
        let b = warmed.tile(&rows, &cols).unwrap();
        assert_eq!(a.data(), b.data(), "prefetch is advisory: bytes identical");
        assert_eq!(warmed.prefetch_hits(), 1);
        assert_eq!(warmed.chunks_read(), plain.chunks_read(), "same total disk reads");
    }

    #[test]
    fn zero_prefetch_budget_disables_planning() {
        let d = random_dense(20, 5, 52);
        let path = tmp("prefetch_off.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let r = StoreReader::open_with_cache(&path, 0).unwrap();
        r.prefetch_plan(&one_round(vec![0, 19], vec![0]));
        assert!(r.prefetch_idle(), "no thread ever spawns");
        assert_eq!(r.prefetch_issued(), 0);
        assert_eq!(r.chunks_read(), 0);
    }

    #[test]
    fn corrupted_chunk_is_a_typed_error() {
        let d = random_dense(20, 5, 6);
        let path = tmp("corrupt.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        // Flip one payload byte (inside chunk 0, right after the magic).
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let r = StoreReader::open_with_cache(&path, 0).unwrap();
        let err = r.tile(&[0], &[0]).unwrap_err();
        let store_err = err.downcast_ref::<StoreError>().expect("typed error");
        assert!(matches!(store_err, StoreError::Corrupt { .. }), "{store_err}");
        // Untouched bands still read fine.
        assert!(r.tile(&[15], &[0]).is_ok());
    }

    #[test]
    fn truncated_store_is_a_typed_error() {
        let d = random_dense(20, 5, 7);
        let path = tmp("trunc.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
        let err = StoreReader::open(&path).unwrap_err();
        let store_err = err.downcast_ref::<StoreError>().expect("typed error");
        assert!(matches!(store_err, StoreError::Truncated { .. }), "{store_err}");
    }

    #[test]
    fn non_store_is_a_typed_error() {
        let path = tmp("not_a_store.lamc2");
        std::fs::write(&path, b"definitely not a matrix store").unwrap();
        let err = StoreReader::open(&path).unwrap_err();
        let store_err = err.downcast_ref::<StoreError>().expect("typed error");
        assert!(matches!(store_err, StoreError::NotAStore(_)), "{store_err}");
    }

    #[test]
    fn streaming_ingest_partial_last_band() {
        let path = tmp("stream.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Dense, 3, 4).unwrap();
        for i in 0..10 {
            w.append_dense_row(&[i as f32, 0.0, -(i as f32)]).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.rows, 10);
        assert_eq!(summary.chunks, 3, "4 + 4 + 2");
        let r = StoreReader::open(&path).unwrap();
        let tile = r.tile(&[9, 0], &[0, 2]).unwrap();
        assert_eq!(tile.data(), &[9.0, -9.0, 0.0, 0.0]);
    }

    #[test]
    fn streaming_tiled_ingest_partial_edges() {
        // 10 rows x 5 cols in 4x2 tiles: 3 row bands (last short), 3 col
        // bands (last short) = 9 tiles.
        let path = tmp("stream.lamc3");
        let mut w = ChunkWriter::create_tiled(&path, Layout::Dense, 5, 4, 2).unwrap();
        for i in 0..10 {
            let i = i as f32;
            w.append_dense_row(&[i, 10.0 + i, 20.0 + i, 30.0 + i, 40.0 + i]).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.chunks, 9);
        let r = StoreReader::open(&path).unwrap();
        // Pick cells across tile boundaries, arbitrary order.
        let tile = r.tile(&[9, 0, 4], &[4, 0, 3]).unwrap();
        assert_eq!(tile.data(), &[49.0, 9.0, 39.0, 40.0, 0.0, 30.0, 44.0, 4.0, 34.0]);
    }

    #[test]
    fn writer_rejects_bad_rows() {
        let path = tmp("bad_rows.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Dense, 3, 4).unwrap();
        assert!(w.append_dense_row(&[1.0, 2.0]).is_err(), "wrong width");
        assert!(w.append_sparse_row(&[(0, 1.0)]).is_err(), "wrong layout");
        let path2 = tmp("bad_rows2.lamc2");
        let mut w2 = ChunkWriter::create(&path2, Layout::Csr, 3, 4).unwrap();
        assert!(w2.append_sparse_row(&[(7, 1.0)]).is_err(), "col out of bounds");
        assert!(w2.append_sparse_row(&[(1, 1.0), (1, 2.0)]).is_err(), "duplicate col");
        assert!(w2.append_sparse_row(&[(2, 1.0), (0, 2.0)]).is_ok(), "unsorted ok");
        let s = w2.finish().unwrap();
        assert_eq!(s.nnz, 2);
    }

    #[test]
    fn empty_sparse_rows_round_trip() {
        let path = tmp("empty_rows.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Csr, 4, 2).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.append_sparse_row(&[(3, 2.5)]).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        match r.read_all().unwrap() {
            Matrix::Sparse(s) => {
                assert_eq!(s.nnz(), 1);
                assert_eq!(s.to_dense().get(1, 3), 2.5);
            }
            _ => panic!("layout"),
        }
    }

    #[test]
    fn append_resumes_partial_band_and_matches_fresh_pack() {
        let d = random_dense(17, 7, 77);
        let path = tmp("append_rt.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Dense, 7, 4).unwrap();
        for i in 0..10 {
            w.append_dense_row(d.row(i)).unwrap();
        }
        let s0 = w.finish().unwrap();
        assert_eq!((s0.rows, s0.chunks), (10, 3), "partial 2-row band sealed last");
        let mut w = ChunkWriter::append_to(&path).unwrap();
        assert_eq!(w.rows(), 10);
        for i in 10..17 {
            w.append_dense_row(d.row(i)).unwrap();
        }
        let s1 = w.finish().unwrap();
        assert_eq!((s1.rows, s1.chunks), (17, 5));
        // Byte-identical content and identical fingerprint to a
        // from-scratch pack of the concatenated matrix.
        let fresh = tmp("append_rt_fresh.lamc2");
        let sf = pack_matrix(&Matrix::Dense(d.clone()), &fresh, 4).unwrap();
        assert_eq!(s1.fingerprint, sf.fingerprint);
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.generation(), 1);
        match r.read_all().unwrap() {
            Matrix::Dense(got) => assert_eq!(got, d),
            _ => panic!("layout"),
        }
        // The re-sealed partial band (rows 8..10) counts as dirty too.
        assert_eq!(r.dirty_rows_since(0), vec![(8, 17)]);
        assert!(r.dirty_rows_since(1).is_empty());
    }

    #[test]
    fn tiled_append_with_codec_matches_fresh_pack() {
        let s = random_sparse(23, 9, 150, 78);
        let path = tmp("append_rt.lamc3");
        let mut w = ChunkWriter::create_tiled(&path, Layout::Csr, 9, 5, 4).unwrap();
        w.set_codec(Codec::ShuffleLz);
        let mut row: Vec<(u32, f32)> = Vec::new();
        for i in 0..12 {
            row.clear();
            row.extend(s.row_iter(i).map(|(j, v)| (j as u32, v)));
            w.append_sparse_row(&row).unwrap();
        }
        w.finish().unwrap();
        // Appending to a pre-generation codec store exercises the
        // raw-checksum recovery path (compressed chunks re-read once).
        let mut w = ChunkWriter::append_to(&path).unwrap();
        for i in 12..23 {
            row.clear();
            row.extend(s.row_iter(i).map(|(j, v)| (j as u32, v)));
            w.append_sparse_row(&row).unwrap();
        }
        let s1 = w.finish().unwrap();
        let fresh = tmp("append_rt_fresh.lamc3");
        let sf = pack_matrix_tiled_with_codec(
            &Matrix::Sparse(s.clone()),
            &fresh,
            5,
            4,
            Codec::ShuffleLz,
        )
        .unwrap();
        assert_eq!(s1.fingerprint, sf.fingerprint);
        let r = StoreReader::open(&path).unwrap();
        assert!(r.is_tiled());
        assert_eq!(r.generation(), 1);
        match r.read_all().unwrap() {
            Matrix::Sparse(got) => assert_eq!(got, s),
            _ => panic!("layout"),
        }
        assert_eq!(r.dirty_rows_since(0), vec![(10, 23)], "partial band [10,12) re-sealed");
    }

    #[test]
    fn second_append_bumps_generation_and_narrows_dirty_bands() {
        let d = random_dense(16, 5, 79);
        let path = tmp("append_twice.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Dense, 5, 4).unwrap();
        for i in 0..8 {
            w.append_dense_row(d.row(i)).unwrap();
        }
        w.finish().unwrap();
        let mut w = ChunkWriter::append_to(&path).unwrap();
        for i in 8..12 {
            w.append_dense_row(d.row(i)).unwrap();
        }
        assert_eq!(w.finish().unwrap().rows, 12);
        let mut w = ChunkWriter::append_to(&path).unwrap();
        for i in 12..16 {
            w.append_dense_row(d.row(i)).unwrap();
        }
        let s2 = w.finish().unwrap();
        let fresh = tmp("append_twice_fresh.lamc2");
        let sf = pack_matrix(&Matrix::Dense(d.clone()), &fresh, 4).unwrap();
        assert_eq!(s2.fingerprint, sf.fingerprint);
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.generation(), 2);
        assert_eq!(r.dirty_rows_since(0), vec![(8, 16)]);
        assert_eq!(r.dirty_rows_since(1), vec![(12, 16)]);
        assert!(r.dirty_rows_since(2).is_empty());
        match r.read_all().unwrap() {
            Matrix::Dense(got) => assert_eq!(got, d),
            _ => panic!("layout"),
        }
    }

    #[test]
    fn empty_sparse_rows_round_trip_tiled() {
        let path = tmp("empty_rows.lamc3");
        let mut w = ChunkWriter::create_tiled(&path, Layout::Csr, 4, 2, 2).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.append_sparse_row(&[(3, 2.5), (0, -1.0)]).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        match r.read_all().unwrap() {
            Matrix::Sparse(s) => {
                assert_eq!(s.nnz(), 2);
                assert_eq!(s.to_dense().get(1, 3), 2.5);
                assert_eq!(s.to_dense().get(1, 0), -1.0);
            }
            _ => panic!("layout"),
        }
    }
}
