//! Streaming writer and random-access reader for LAMC2 stores.
//!
//! [`ChunkWriter`] is the ingest side: rows arrive one at a time
//! (`append_dense_row` / `append_sparse_row`), are buffered into the
//! current row band, and each band is sealed — encoded, checksummed,
//! written, fsynced — the moment it fills. Peak writer memory is one
//! band, never the matrix; total row count need not be known up front
//! (the self-description lives in the footer, written by `finish`).
//!
//! [`StoreReader`] is the serving side: `tile(rows, cols)` gathers an
//! arbitrary-order submatrix by reading **only the row bands the
//! requested rows touch**, verifying each band's checksum before use.
//! An optional byte-bounded LRU of decoded bands absorbs the re-reads a
//! partitioned co-clustering round generates; with the cache disabled,
//! peak reader memory is one decoded band plus the gathered tile.

use std::collections::{BTreeMap, HashMap};
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::matrix::{CsrMatrix, DenseMatrix, Matrix};

use super::format::{
    checksum_bytes, decode_footer, encode_footer, store_fingerprint, ChunkMeta, Layout,
    StoreError, StoreHeader, DEFAULT_CHUNK_ROWS, FOOTER_MAGIC, MAGIC, TRAILER_BYTES,
};

/// Default byte budget for the decoded-band cache of [`StoreReader::open`].
pub const DEFAULT_CACHE_BYTES: usize = 64 << 20;

/// What a finished ingest produced (printed by `lamc pack` / `ingest`).
#[derive(Clone, Debug)]
pub struct StoreSummary {
    pub path: PathBuf,
    pub layout: Layout,
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub chunks: usize,
    pub chunk_rows: usize,
    pub fingerprint: u64,
    /// Total file size, footer included.
    pub file_bytes: u64,
}

/// Streaming row-append writer. See the module docs for the protocol.
pub struct ChunkWriter {
    path: PathBuf,
    file: BufWriter<File>,
    layout: Layout,
    cols: usize,
    chunk_rows: usize,
    /// Bytes written so far (leading magic included) = next chunk offset.
    offset: u64,
    index: Vec<ChunkMeta>,
    // Current (open) band.
    dense_buf: Vec<f32>,
    indptr: Vec<u64>,
    indices: Vec<u32>,
    values: Vec<f32>,
    rows_in_chunk: usize,
    total_rows: usize,
    total_nnz: u64,
}

impl ChunkWriter {
    /// Create a store file and start an ingest. `cols` is fixed up
    /// front (every row must have this width); the row count is not.
    pub fn create(path: &Path, layout: Layout, cols: usize, chunk_rows: usize) -> Result<Self> {
        ensure!(cols > 0, "store needs at least one column");
        ensure!(chunk_rows > 0, "chunk height must be positive");
        let mut file = BufWriter::new(
            File::create(path).with_context(|| format!("create store {path:?}"))?,
        );
        file.write_all(MAGIC)?;
        Ok(Self {
            path: path.to_path_buf(),
            file,
            layout,
            cols,
            chunk_rows,
            offset: MAGIC.len() as u64,
            index: Vec::new(),
            dense_buf: Vec::new(),
            indptr: vec![0],
            indices: Vec::new(),
            values: Vec::new(),
            rows_in_chunk: 0,
            total_rows: 0,
            total_nnz: 0,
        })
    }

    /// Create with the default band height.
    pub fn create_default(path: &Path, layout: Layout, cols: usize) -> Result<Self> {
        Self::create(path, layout, cols, DEFAULT_CHUNK_ROWS)
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Rows appended so far.
    pub fn rows(&self) -> usize {
        self.total_rows
    }

    /// Append one dense row (`row.len()` must equal `cols`).
    pub fn append_dense_row(&mut self, row: &[f32]) -> Result<()> {
        ensure!(self.layout == Layout::Dense, "append_dense_row on a {} store", self.layout.as_str());
        ensure!(row.len() == self.cols, "row has {} values, store has {} columns", row.len(), self.cols);
        self.dense_buf.extend_from_slice(row);
        self.total_nnz += self.cols as u64;
        self.row_done()
    }

    /// Append one sparse row as `(col, value)` entries. Entries may be
    /// in any order but must not repeat a column.
    pub fn append_sparse_row(&mut self, entries: &[(u32, f32)]) -> Result<()> {
        ensure!(self.layout == Layout::Csr, "append_sparse_row on a {} store", self.layout.as_str());
        let mut sorted: Vec<(u32, f32)> = entries.to_vec();
        sorted.sort_unstable_by_key(|&(j, _)| j);
        // Validate the whole row before touching writer state, so a
        // rejected row leaves the ingest resumable.
        for pair in sorted.windows(2) {
            ensure!(pair[0].0 != pair[1].0, "duplicate column {} in sparse row", pair[0].0);
        }
        if let Some(&(j, _)) = sorted.last() {
            ensure!((j as usize) < self.cols, "column {} out of bounds (cols = {})", j, self.cols);
        }
        for &(j, v) in &sorted {
            self.indices.push(j);
            self.values.push(v);
        }
        self.indptr.push(self.indices.len() as u64);
        self.total_nnz += sorted.len() as u64;
        self.row_done()
    }

    fn row_done(&mut self) -> Result<()> {
        self.rows_in_chunk += 1;
        self.total_rows += 1;
        if self.rows_in_chunk == self.chunk_rows {
            self.seal_chunk()?;
        }
        Ok(())
    }

    /// Encode, checksum, write and fsync the open band.
    fn seal_chunk(&mut self) -> Result<()> {
        if self.rows_in_chunk == 0 {
            return Ok(());
        }
        let (payload, chunk_nnz) = match self.layout {
            Layout::Dense => {
                let mut payload = Vec::with_capacity(self.dense_buf.len() * 4);
                for &v in &self.dense_buf {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                let nnz = self.dense_buf.len() as u64;
                self.dense_buf.clear();
                (payload, nnz)
            }
            Layout::Csr => {
                let nnz = self.indices.len() as u64;
                let mut payload =
                    Vec::with_capacity(self.indptr.len() * 8 + self.indices.len() * 8);
                for &p in &self.indptr {
                    payload.extend_from_slice(&p.to_le_bytes());
                }
                for &j in &self.indices {
                    payload.extend_from_slice(&j.to_le_bytes());
                }
                for &v in &self.values {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                self.indptr.clear();
                self.indptr.push(0);
                self.indices.clear();
                self.values.clear();
                (payload, nnz)
            }
        };
        let meta = ChunkMeta {
            offset: self.offset,
            len: payload.len() as u64,
            row_lo: self.total_rows - self.rows_in_chunk,
            rows: self.rows_in_chunk,
            nnz: chunk_nnz,
            checksum: checksum_bytes(&payload),
        };
        self.file.write_all(&payload)?;
        // Durability point: a sealed band survives a crash of the
        // ingesting process (the footer won't, and the reader reports
        // that as Truncated — re-ingest resumes from scratch).
        self.file.flush()?;
        self.file.get_ref().sync_data().with_context(|| format!("fsync {:?}", self.path))?;
        self.offset += meta.len;
        self.index.push(meta);
        self.rows_in_chunk = 0;
        Ok(())
    }

    /// Seal any partial band, write the footer, and fsync the file.
    pub fn finish(mut self) -> Result<StoreSummary> {
        self.seal_chunk()?;
        let fingerprint = store_fingerprint(
            self.layout,
            self.total_rows,
            self.cols,
            self.total_nnz,
            self.index.iter().map(|e| e.checksum),
        );
        let header = StoreHeader {
            layout: self.layout,
            rows: self.total_rows,
            cols: self.cols,
            nnz: self.total_nnz,
            chunk_rows: self.chunk_rows,
            n_chunks: self.index.len(),
            fingerprint,
        };
        let footer = encode_footer(&header, &self.index);
        self.file.write_all(&footer)?;
        self.file.write_all(&(footer.len() as u64).to_le_bytes())?;
        self.file.write_all(&checksum_bytes(&footer).to_le_bytes())?;
        self.file.write_all(FOOTER_MAGIC)?;
        self.file.flush()?;
        self.file.get_ref().sync_all().with_context(|| format!("fsync {:?}", self.path))?;
        Ok(StoreSummary {
            path: self.path.clone(),
            layout: self.layout,
            rows: self.total_rows,
            cols: self.cols,
            nnz: self.total_nnz,
            chunks: self.index.len(),
            chunk_rows: self.chunk_rows,
            fingerprint,
            file_bytes: self.offset + footer.len() as u64 + TRAILER_BYTES,
        })
    }
}

/// Pack an in-memory matrix into a store file (the `lamc pack` core).
pub fn pack_matrix(matrix: &Matrix, path: &Path, chunk_rows: usize) -> Result<StoreSummary> {
    match matrix {
        Matrix::Dense(d) => {
            let mut w = ChunkWriter::create(path, Layout::Dense, d.cols(), chunk_rows)?;
            for i in 0..d.rows() {
                w.append_dense_row(d.row(i))?;
            }
            w.finish()
        }
        Matrix::Sparse(s) => {
            let mut w = ChunkWriter::create(path, Layout::Csr, s.cols(), chunk_rows)?;
            let mut row: Vec<(u32, f32)> = Vec::new();
            for i in 0..s.rows() {
                row.clear();
                row.extend(s.row_iter(i).map(|(j, v)| (j as u32, v)));
                w.append_sparse_row(&row)?;
            }
            w.finish()
        }
    }
}

/// One decoded row band.
enum DecodedChunk {
    Dense { values: Vec<f32> },
    Csr { indptr: Vec<u64>, indices: Vec<u32>, values: Vec<f32> },
}

impl DecodedChunk {
    fn resident_bytes(&self) -> usize {
        match self {
            DecodedChunk::Dense { values } => values.len() * 4,
            DecodedChunk::Csr { indptr, indices, values } => {
                indptr.len() * 8 + indices.len() * 4 + values.len() * 4
            }
        }
    }
}

struct CacheSlot {
    chunk: Arc<DecodedChunk>,
    bytes: usize,
    last_used: u64,
}

struct ChunkCache {
    map: HashMap<usize, CacheSlot>,
    bytes: usize,
    tick: u64,
}

/// Random-access reader over a finished store file.
///
/// Thread-safe: `tile` may be called concurrently from the scheduler's
/// worker pool (reads are serialized on an internal file handle; decode
/// and gather run in parallel).
pub struct StoreReader {
    path: PathBuf,
    header: StoreHeader,
    index: Vec<ChunkMeta>,
    file: Mutex<File>,
    cache: Mutex<ChunkCache>,
    cache_budget: usize,
    // Telemetry: how much of the file the workload actually touched.
    chunks_read: AtomicU64,
    bytes_read: AtomicU64,
    cache_hits: AtomicU64,
    tiles_served: AtomicU64,
}

impl StoreReader {
    /// Open with the default decoded-band cache budget.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with_cache(path, DEFAULT_CACHE_BYTES)
    }

    /// Open with an explicit cache budget (0 disables caching: every
    /// tile re-reads its bands from disk — the strictest RSS bound).
    pub fn open_with_cache(path: &Path, cache_budget: usize) -> Result<Self> {
        let mut file = File::open(path).with_context(|| format!("open store {path:?}"))?;
        let file_len = file.metadata()?.len();

        if file_len < MAGIC.len() as u64 {
            return Err(StoreError::NotAStore(path.to_path_buf()).into());
        }
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(StoreError::NotAStore(path.to_path_buf()).into());
        }
        if file_len < MAGIC.len() as u64 + TRAILER_BYTES {
            return Err(StoreError::Truncated {
                path: path.to_path_buf(),
                detail: format!("{file_len} bytes is too short for a footer"),
            }
            .into());
        }

        let mut trailer = [0u8; TRAILER_BYTES as usize];
        file.seek(SeekFrom::End(-(TRAILER_BYTES as i64)))?;
        file.read_exact(&mut trailer)?;
        if &trailer[16..24] != FOOTER_MAGIC {
            return Err(StoreError::Truncated {
                path: path.to_path_buf(),
                detail: "footer magic missing (ingest died before finish, or partial copy)".into(),
            }
            .into());
        }
        let footer_len = u64::from_le_bytes(trailer[0..8].try_into().unwrap());
        let footer_checksum = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
        let payload_end = match (file_len - TRAILER_BYTES).checked_sub(footer_len) {
            Some(end) if end >= MAGIC.len() as u64 => end,
            _ => {
                return Err(StoreError::Truncated {
                    path: path.to_path_buf(),
                    detail: format!("footer length {footer_len} exceeds file size {file_len}"),
                }
                .into())
            }
        };
        let mut footer = vec![0u8; footer_len as usize];
        file.seek(SeekFrom::Start(payload_end))?;
        file.read_exact(&mut footer)?;
        if checksum_bytes(&footer) != footer_checksum {
            return Err(StoreError::Corrupt {
                path: path.to_path_buf(),
                detail: "footer checksum mismatch".into(),
            }
            .into());
        }
        let (header, index) = decode_footer(&footer, payload_end, path)?;

        Ok(Self {
            path: path.to_path_buf(),
            header,
            index,
            file: Mutex::new(file),
            cache: Mutex::new(ChunkCache { map: HashMap::new(), bytes: 0, tick: 0 }),
            cache_budget,
            chunks_read: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            tiles_served: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn header(&self) -> &StoreHeader {
        &self.header
    }

    pub fn rows(&self) -> usize {
        self.header.rows
    }

    pub fn cols(&self) -> usize {
        self.header.cols
    }

    /// Stored entries (dense stores count every entry).
    pub fn nnz(&self) -> usize {
        self.header.nnz as usize
    }

    pub fn layout(&self) -> Layout {
        self.header.layout
    }

    pub fn is_sparse(&self) -> bool {
        self.header.layout == Layout::Csr
    }

    pub fn chunk_rows(&self) -> usize {
        self.header.chunk_rows
    }

    pub fn n_chunks(&self) -> usize {
        self.header.n_chunks
    }

    /// O(1) content fingerprint from the header — see
    /// [`store_fingerprint`](super::format::store_fingerprint).
    pub fn fingerprint(&self) -> u64 {
        self.header.fingerprint
    }

    /// Bands read from disk so far (checksum-verified decodes).
    pub fn chunks_read(&self) -> u64 {
        self.chunks_read.load(Ordering::Relaxed)
    }

    /// Payload bytes read from disk so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Band requests answered from the decoded-band cache.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Tiles gathered so far.
    pub fn tiles_served(&self) -> u64 {
        self.tiles_served.load(Ordering::Relaxed)
    }

    /// Read, verify and decode band `idx` (cache-aware).
    fn load_chunk(&self, idx: usize) -> Result<Arc<DecodedChunk>> {
        if self.cache_budget > 0 {
            let mut cache = self.cache.lock().unwrap();
            cache.tick += 1;
            let tick = cache.tick;
            if let Some(slot) = cache.map.get_mut(&idx) {
                slot.last_used = tick;
                self.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&slot.chunk));
            }
        }

        let meta = self.index[idx];
        let mut payload = vec![0u8; meta.len as usize];
        {
            let mut file = self.file.lock().unwrap();
            file.seek(SeekFrom::Start(meta.offset))?;
            file.read_exact(&mut payload).map_err(|e| StoreError::Truncated {
                path: self.path.clone(),
                detail: format!("chunk {idx} short read: {e}"),
            })?;
        }
        self.chunks_read.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(meta.len, Ordering::Relaxed);
        if checksum_bytes(&payload) != meta.checksum {
            return Err(StoreError::Corrupt {
                path: self.path.clone(),
                detail: format!("chunk {idx} checksum mismatch"),
            }
            .into());
        }
        let chunk = Arc::new(self.decode_chunk(idx, &meta, &payload)?);

        if self.cache_budget > 0 {
            let bytes = chunk.resident_bytes();
            if bytes <= self.cache_budget {
                let mut cache = self.cache.lock().unwrap();
                cache.tick += 1;
                let tick = cache.tick;
                let slot = CacheSlot { chunk: Arc::clone(&chunk), bytes, last_used: tick };
                if let Some(old) = cache.map.insert(idx, slot) {
                    cache.bytes -= old.bytes;
                }
                cache.bytes += bytes;
                while cache.bytes > self.cache_budget {
                    let Some((&victim, _)) = cache
                        .map
                        .iter()
                        .filter(|(k, _)| **k != idx)
                        .min_by_key(|(_, s)| s.last_used)
                    else {
                        break;
                    };
                    let old = cache.map.remove(&victim).unwrap();
                    cache.bytes -= old.bytes;
                }
            }
        }
        Ok(chunk)
    }

    fn decode_chunk(&self, idx: usize, meta: &ChunkMeta, payload: &[u8]) -> Result<DecodedChunk> {
        let corrupt = |detail: String| -> anyhow::Error {
            StoreError::Corrupt { path: self.path.clone(), detail }.into()
        };
        let cols = self.header.cols;
        match self.header.layout {
            Layout::Dense => {
                let want = meta.rows * cols * 4;
                if payload.len() != want {
                    return Err(corrupt(format!(
                        "dense chunk {idx} has {} bytes, want {want}",
                        payload.len()
                    )));
                }
                let values = payload
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(DecodedChunk::Dense { values })
            }
            Layout::Csr => {
                let nnz = meta.nnz as usize;
                let ptr_bytes = (meta.rows + 1) * 8;
                let want = ptr_bytes + nnz * 8;
                if payload.len() != want {
                    return Err(corrupt(format!(
                        "csr chunk {idx} has {} bytes, want {want}",
                        payload.len()
                    )));
                }
                let indptr: Vec<u64> = payload[..ptr_bytes]
                    .chunks_exact(8)
                    .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                    .collect();
                if indptr[0] != 0
                    || *indptr.last().unwrap() != nnz as u64
                    || indptr.windows(2).any(|w| w[0] > w[1])
                {
                    return Err(corrupt(format!("csr chunk {idx} row pointers are inconsistent")));
                }
                let indices: Vec<u32> = payload[ptr_bytes..ptr_bytes + nnz * 4]
                    .chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                if indices.iter().any(|&j| j as usize >= cols) {
                    return Err(corrupt(format!("csr chunk {idx} has a column index out of bounds")));
                }
                let values: Vec<f32> = payload[ptr_bytes + nnz * 4..]
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                Ok(DecodedChunk::Csr { indptr, indices, values })
            }
        }
    }

    /// Gather the dense submatrix `A[rows, cols]` (arbitrary index
    /// order, global ids) — bit-identical to `Matrix::gather_block` on
    /// the matrix the store was packed from, reading only the row bands
    /// the requested rows cover.
    pub fn tile(&self, rows: &[usize], cols: &[usize]) -> Result<DenseMatrix> {
        for &i in rows {
            ensure!(i < self.header.rows, "row {i} out of bounds ({} rows)", self.header.rows);
        }
        for &j in cols {
            ensure!(j < self.header.cols, "col {j} out of bounds ({} cols)", self.header.cols);
        }
        let h = self.header.chunk_rows;
        // Group requested rows by band so each touched band loads once.
        let mut by_chunk: BTreeMap<usize, Vec<(usize, usize)>> = BTreeMap::new();
        for (bi, &gid) in rows.iter().enumerate() {
            by_chunk.entry(gid / h).or_default().push((bi, gid % h));
        }

        let mut out = DenseMatrix::zeros(rows.len(), cols.len());
        // Column lookup shared across bands (CSR scatter).
        let mut col_pos: Vec<i32> = Vec::new();
        if self.header.layout == Layout::Csr {
            col_pos = vec![-1; self.header.cols];
            for (bj, &j) in cols.iter().enumerate() {
                col_pos[j] = bj as i32;
            }
        }

        for (&cidx, picks) in &by_chunk {
            let chunk = self.load_chunk(cidx)?;
            match &*chunk {
                DecodedChunk::Dense { values } => {
                    let w = self.header.cols;
                    for &(bi, local) in picks {
                        let src = &values[local * w..(local + 1) * w];
                        let dst = out.row_mut(bi);
                        for (bj, &j) in cols.iter().enumerate() {
                            dst[bj] = src[j];
                        }
                    }
                }
                DecodedChunk::Csr { indptr, indices, values } => {
                    for &(bi, local) in picks {
                        let dst = out.row_mut(bi);
                        for t in indptr[local] as usize..indptr[local + 1] as usize {
                            let bj = col_pos[indices[t] as usize];
                            if bj >= 0 {
                                dst[bj as usize] = values[t];
                            }
                        }
                    }
                }
            }
        }
        self.tiles_served.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Materialize the whole matrix (baselines and `lamc inspect
    /// --verify` use this; the partitioned pipeline never does).
    pub fn read_all(&self) -> Result<Matrix> {
        match self.header.layout {
            Layout::Dense => {
                let mut data = Vec::with_capacity(self.header.rows * self.header.cols);
                for idx in 0..self.index.len() {
                    let chunk = self.load_chunk(idx)?;
                    match &*chunk {
                        DecodedChunk::Dense { values } => data.extend_from_slice(values),
                        DecodedChunk::Csr { .. } => bail!("dense store decoded a csr chunk"),
                    }
                }
                Ok(Matrix::Dense(DenseMatrix::from_vec(self.header.rows, self.header.cols, data)))
            }
            Layout::Csr => {
                let mut indptr: Vec<usize> = Vec::with_capacity(self.header.rows + 1);
                indptr.push(0);
                let mut all_indices: Vec<u32> = Vec::with_capacity(self.header.nnz as usize);
                let mut all_values: Vec<f32> = Vec::with_capacity(self.header.nnz as usize);
                for idx in 0..self.index.len() {
                    let chunk = self.load_chunk(idx)?;
                    match &*chunk {
                        DecodedChunk::Csr { indptr: rel, indices, values } => {
                            let base = all_indices.len();
                            for &p in &rel[1..] {
                                indptr.push(base + p as usize);
                            }
                            all_indices.extend_from_slice(indices);
                            all_values.extend_from_slice(values);
                        }
                        DecodedChunk::Dense { .. } => bail!("csr store decoded a dense chunk"),
                    }
                }
                Ok(Matrix::Sparse(CsrMatrix::new(
                    self.header.rows,
                    self.header.cols,
                    indptr,
                    all_indices,
                    all_values,
                )))
            }
        }
    }

    /// Re-read and checksum-verify every band (`lamc inspect --verify`).
    pub fn verify(&self) -> Result<()> {
        for idx in 0..self.index.len() {
            self.load_chunk(idx)?;
        }
        Ok(())
    }
}

impl std::fmt::Debug for StoreReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StoreReader")
            .field("path", &self.path)
            .field("layout", &self.header.layout)
            .field("rows", &self.header.rows)
            .field("cols", &self.header.cols)
            .field("n_chunks", &self.header.n_chunks)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lamc_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn random_dense(rows: usize, cols: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::seed_from(seed);
        DenseMatrix::randn(rows, cols, &mut rng)
    }

    fn random_sparse(rows: usize, cols: usize, nnz: usize, seed: u64) -> CsrMatrix {
        let mut rng = Xoshiro256::seed_from(seed);
        let mut trip = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            trip.push((rng.next_below(rows), rng.next_below(cols), rng.next_f32() + 0.01));
        }
        CsrMatrix::from_triplets(rows, cols, trip)
    }

    #[test]
    fn dense_pack_read_all_round_trip() {
        let d = random_dense(37, 11, 1);
        let path = tmp("dense_rt.lamc2");
        let summary = pack_matrix(&Matrix::Dense(d.clone()), &path, 8).unwrap();
        assert_eq!(summary.rows, 37);
        assert_eq!(summary.chunks, 5, "37 rows / 8-row bands");
        let r = StoreReader::open(&path).unwrap();
        assert_eq!((r.rows(), r.cols()), (37, 11));
        assert_eq!(r.fingerprint(), summary.fingerprint);
        match r.read_all().unwrap() {
            Matrix::Dense(got) => assert_eq!(got, d),
            _ => panic!("layout mismatch"),
        }
    }

    #[test]
    fn sparse_pack_read_all_round_trip() {
        let s = random_sparse(50, 23, 300, 2);
        let path = tmp("sparse_rt.lamc2");
        pack_matrix(&Matrix::Sparse(s.clone()), &path, 7).unwrap();
        let r = StoreReader::open(&path).unwrap();
        assert!(r.is_sparse());
        assert_eq!(r.nnz(), s.nnz());
        match r.read_all().unwrap() {
            Matrix::Sparse(got) => assert_eq!(got, s),
            _ => panic!("layout mismatch"),
        }
    }

    #[test]
    fn tile_matches_gather_block_randomized() {
        let mut rng = Xoshiro256::seed_from(3);
        for (case, matrix) in [
            Matrix::Dense(random_dense(41, 17, 31)),
            Matrix::Sparse(random_sparse(41, 17, 200, 32)),
        ]
        .into_iter()
        .enumerate()
        {
            let path = tmp(&format!("tile_{case}.lamc2"));
            pack_matrix(&matrix, &path, 6).unwrap();
            let r = StoreReader::open(&path).unwrap();
            for _ in 0..20 {
                let nr = rng.next_range(1, 15);
                let nc = rng.next_range(1, 12);
                let rows = rng.sample_indices(41, nr);
                let cols = rng.sample_indices(17, nc);
                let want = matrix.gather_block(&rows, &cols);
                let got = r.tile(&rows, &cols).unwrap();
                assert_eq!(got.data(), want.data(), "case {case} rows {rows:?} cols {cols:?}");
            }
        }
    }

    #[test]
    fn contiguous_tile_touches_only_covering_bands() {
        let d = random_dense(64, 9, 4);
        let path = tmp("touch.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 16).unwrap();
        // Cache disabled: every band access is a disk read we can count.
        let r = StoreReader::open_with_cache(&path, 0).unwrap();
        assert_eq!(r.n_chunks(), 4);
        // Rows 16..32 live entirely in band 1.
        let rows: Vec<usize> = (16..32).collect();
        let cols: Vec<usize> = (0..9).collect();
        r.tile(&rows, &cols).unwrap();
        assert_eq!(r.chunks_read(), 1, "one band covers rows 16..32");
        // Rows 10..20 straddle bands 0 and 1.
        let rows: Vec<usize> = (10..20).collect();
        r.tile(&rows, &cols).unwrap();
        assert_eq!(r.chunks_read(), 3, "two more bands");
        assert_eq!(r.cache_hits(), 0);
    }

    #[test]
    fn cache_absorbs_repeated_tiles() {
        let d = random_dense(32, 8, 5);
        let path = tmp("cache.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let r = StoreReader::open(&path).unwrap(); // default budget ≫ file
        let rows: Vec<usize> = (0..32).collect();
        let cols: Vec<usize> = (0..8).collect();
        r.tile(&rows, &cols).unwrap();
        r.tile(&rows, &cols).unwrap();
        assert_eq!(r.chunks_read(), 4, "second pass served from cache");
        assert_eq!(r.cache_hits(), 4);
    }

    #[test]
    fn corrupted_chunk_is_a_typed_error() {
        let d = random_dense(20, 5, 6);
        let path = tmp("corrupt.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        // Flip one payload byte (inside chunk 0, right after the magic).
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[12] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let r = StoreReader::open_with_cache(&path, 0).unwrap();
        let err = r.tile(&[0], &[0]).unwrap_err();
        let store_err = err.downcast_ref::<StoreError>().expect("typed error");
        assert!(matches!(store_err, StoreError::Corrupt { .. }), "{store_err}");
        // Untouched bands still read fine.
        assert!(r.tile(&[15], &[0]).is_ok());
    }

    #[test]
    fn truncated_store_is_a_typed_error() {
        let d = random_dense(20, 5, 7);
        let path = tmp("trunc.lamc2");
        pack_matrix(&Matrix::Dense(d), &path, 8).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
        let err = StoreReader::open(&path).unwrap_err();
        let store_err = err.downcast_ref::<StoreError>().expect("typed error");
        assert!(matches!(store_err, StoreError::Truncated { .. }), "{store_err}");
    }

    #[test]
    fn non_store_is_a_typed_error() {
        let path = tmp("not_a_store.lamc2");
        std::fs::write(&path, b"definitely not a matrix store").unwrap();
        let err = StoreReader::open(&path).unwrap_err();
        let store_err = err.downcast_ref::<StoreError>().expect("typed error");
        assert!(matches!(store_err, StoreError::NotAStore(_)), "{store_err}");
    }

    #[test]
    fn streaming_ingest_partial_last_band() {
        let path = tmp("stream.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Dense, 3, 4).unwrap();
        for i in 0..10 {
            w.append_dense_row(&[i as f32, 0.0, -(i as f32)]).unwrap();
        }
        let summary = w.finish().unwrap();
        assert_eq!(summary.rows, 10);
        assert_eq!(summary.chunks, 3, "4 + 4 + 2");
        let r = StoreReader::open(&path).unwrap();
        let tile = r.tile(&[9, 0], &[0, 2]).unwrap();
        assert_eq!(tile.data(), &[9.0, -9.0, 0.0, 0.0]);
    }

    #[test]
    fn writer_rejects_bad_rows() {
        let path = tmp("bad_rows.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Dense, 3, 4).unwrap();
        assert!(w.append_dense_row(&[1.0, 2.0]).is_err(), "wrong width");
        assert!(w.append_sparse_row(&[(0, 1.0)]).is_err(), "wrong layout");
        let path2 = tmp("bad_rows2.lamc2");
        let mut w2 = ChunkWriter::create(&path2, Layout::Csr, 3, 4).unwrap();
        assert!(w2.append_sparse_row(&[(7, 1.0)]).is_err(), "col out of bounds");
        assert!(w2.append_sparse_row(&[(1, 1.0), (1, 2.0)]).is_err(), "duplicate col");
        assert!(w2.append_sparse_row(&[(2, 1.0), (0, 2.0)]).is_ok(), "unsorted ok");
        let s = w2.finish().unwrap();
        assert_eq!(s.nnz, 2);
    }

    #[test]
    fn empty_sparse_rows_round_trip() {
        let path = tmp("empty_rows.lamc2");
        let mut w = ChunkWriter::create(&path, Layout::Csr, 4, 2).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.append_sparse_row(&[(3, 2.5)]).unwrap();
        w.append_sparse_row(&[]).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        match r.read_all().unwrap() {
            Matrix::Sparse(s) => {
                assert_eq!(s.nnz(), 1);
                assert_eq!(s.to_dense().get(1, 3), 2.5);
            }
            _ => panic!("layout"),
        }
    }
}
