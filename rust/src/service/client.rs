//! Blocking TCP client for the service line protocol.
//!
//! One [`ServiceClient`] wraps one connection; requests are serialized
//! on it (the protocol is strict request–response). The `lamc submit` /
//! `lamc status` CLI commands and the integration tests are the two
//! in-tree users.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::merge::Cocluster;
use crate::trace::SpanRecord;

use super::manager::{JobSpec, JobState};
use super::protocol::{self, ShardSetInfo, PROTO_VERSION};

/// A job's status as reported by `STATUS`.
#[derive(Clone, Debug)]
pub struct StatusReply {
    pub id: u64,
    pub state: JobState,
    pub cached: bool,
    pub error: Option<String>,
}

/// A job's labelling as reported by `RESULT`.
#[derive(Clone, Debug, PartialEq)]
pub struct ResultReply {
    pub id: u64,
    pub k: usize,
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    pub cached: bool,
}

/// Outcome of an `APPEND`, as reported by the server.
#[derive(Clone, Copy, Debug)]
pub struct AppendReply {
    /// Row count of the grown matrix.
    pub total_rows: usize,
    /// Store generation after the append.
    pub generation: u64,
    /// Incremental re-clustering job the append queued, if any.
    pub job: Option<u64>,
}

pub struct ServiceClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Unified framing, negotiated once by [`ServiceClient::hello`]
    /// with `framing=binary`: when set, `RESULT`/`EVENTS`/`SPANS`
    /// answer in binary directly and `SUBSCRIBE` is available.
    binary: bool,
    /// Pre-handshake fallback for result framing: starts optimistic
    /// (binary `RESULTB`); a server that answers "unknown verb"
    /// downgrades this connection to the text `RESULT` path
    /// permanently. Only consulted when `binary` is off.
    binary_results: bool,
    /// Same per-verb fallback for event pages (`EVENTSB` vs `EVENTS`).
    binary_events: bool,
}

impl ServiceClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect to lamc service")?;
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(Self { reader, writer: stream, binary: false, binary_results: true, binary_events: true })
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Ok(line)
    }

    /// One-line request → one-line response; returns the text after `OK`.
    fn roundtrip(&mut self, line: &str) -> Result<String> {
        self.send_line(line)?;
        let reply = self.read_line()?;
        Ok(protocol::check_ok(&reply)?.to_string())
    }

    fn kv_reply(&mut self, line: &str) -> Result<BTreeMap<String, String>> {
        let rest = self.roundtrip(line)?;
        let tokens: Vec<&str> = rest.split_whitespace().collect();
        protocol::kv_pairs(&tokens)
    }

    /// Submit a job; returns its id.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<u64> {
        let line = protocol::encode_submit(spec)?;
        let map = self.kv_reply(&line)?;
        map.get("id").context("missing id in reply")?.parse().context("bad id in reply")
    }

    pub fn status(&mut self, id: u64) -> Result<StatusReply> {
        let map = self.kv_reply(&format!("STATUS id={id}"))?;
        Ok(StatusReply {
            id,
            state: map.get("state").context("missing state")?.parse()?,
            cached: map.get("cached").map(|v| v == "true").unwrap_or(false),
            error: map.get("error").cloned(),
        })
    }

    /// Fetch a finished job's labels (errors while the job is queued or
    /// running — use [`ServiceClient::wait`] to block until done).
    ///
    /// On the unified framing (negotiated by [`ServiceClient::hello`])
    /// `RESULT` itself answers in binary. Otherwise tries the binary
    /// `RESULTB` compat verb first — length-prefixed `u32` labels with
    /// a checksum, no line-length ceiling — and falls back to the text
    /// `RESULT` protocol against servers that predate it.
    pub fn result(&mut self, id: u64) -> Result<ResultReply> {
        if self.binary {
            return self.result_framed("RESULT", id);
        }
        if self.binary_results {
            match self.result_framed("RESULTB", id) {
                Ok(reply) => return Ok(reply),
                Err(e) if e.to_string().contains("unknown verb") => {
                    // Legacy server: downgrade once, then use text.
                    self.binary_results = false;
                }
                Err(e) => return Err(e),
            }
        }
        self.result_text(id)
    }

    /// One header line, then `4·(rows+cols)+8` bytes of labels+checksum.
    fn result_framed(&mut self, verb: &str, id: u64) -> Result<ResultReply> {
        self.send_line(&format!("{verb} id={id}"))?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let k: usize = map.get("k").context("missing k")?.parse()?;
        let rows: usize = map.get("rows").context("missing rows")?.parse()?;
        let cols: usize = map.get("cols").context("missing cols")?.parse()?;
        let cached = map.get("cached").map(|v| v == "true").unwrap_or(false);
        let mut payload = vec![0u8; (rows + cols) * 4 + 8];
        self.reader.read_exact(&mut payload).context("read binary result payload")?;
        let (row_labels, col_labels) = protocol::decode_labels_binary(&payload, rows, cols)?;
        Ok(ResultReply { id, k, row_labels, col_labels, cached })
    }

    fn result_text(&mut self, id: u64) -> Result<ResultReply> {
        self.send_line(&format!("RESULT id={id}"))?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let k: usize = map.get("k").context("missing k")?.parse()?;
        let cached = map.get("cached").map(|v| v == "true").unwrap_or(false);

        let rows_line = self.read_line()?;
        let row_labels = protocol::decode_labels(
            rows_line.strip_prefix("ROWS").context("expected ROWS line")?,
        )?;
        let cols_line = self.read_line()?;
        let col_labels = protocol::decode_labels(
            cols_line.strip_prefix("COLS").context("expected COLS line")?,
        )?;
        let end = self.read_line()?;
        if end.trim() != "END" {
            bail!("expected END terminator, got '{}'", end.trim());
        }
        Ok(ResultReply { id, k, row_labels, col_labels, cached })
    }

    fn header_map(header: &str) -> Result<BTreeMap<String, String>> {
        let rest = protocol::check_ok(header)?.to_string();
        let tokens: Vec<&str> = rest.split_whitespace().collect();
        protocol::kv_pairs(&tokens)
    }

    /// Poll `STATUS` until the job is done (then fetch the result) or
    /// failed (then error), up to `timeout`.
    pub fn wait(&mut self, id: u64, timeout: Duration) -> Result<ResultReply> {
        let deadline = Instant::now() + timeout;
        loop {
            let status = self.status(id)?;
            match status.state {
                JobState::Done => return self.result(id),
                JobState::Failed => {
                    bail!("job {id} failed: {}", status.error.unwrap_or_else(|| "unknown".into()))
                }
                _ if Instant::now() >= deadline => bail!("timed out waiting for job {id}"),
                _ => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    }

    /// Fetch the server's counters as a key→value map.
    pub fn stats(&mut self) -> Result<BTreeMap<String, String>> {
        self.kv_reply("STATS")
    }

    /// Load a built-in dataset spec under a name; returns (rows, cols).
    pub fn load_dataset(&mut self, name: &str, dataset: &str, rows: Option<usize>, seed: u64) -> Result<(usize, usize)> {
        protocol::ensure_token("name", name)?;
        protocol::ensure_token("dataset", dataset)?;
        let mut line = format!("LOAD name={name} dataset={dataset} seed={seed}");
        if let Some(r) = rows {
            line.push_str(&format!(" rows={r}"));
        }
        let map = self.kv_reply(&line)?;
        let r: usize = map.get("rows").context("missing rows")?.parse()?;
        let c: usize = map.get("cols").context("missing cols")?.parse()?;
        Ok((r, c))
    }

    /// Load a matrix file on the server; returns (rows, cols). The path
    /// must be space-free (a line-protocol limitation, see docs/SERVICE.md).
    pub fn load_file(&mut self, name: &str, path: &str) -> Result<(usize, usize)> {
        protocol::ensure_token("name", name)?;
        protocol::ensure_token("path", path)?;
        let map = self.kv_reply(&format!("LOAD name={name} path={path}"))?;
        let r: usize = map.get("rows").context("missing rows")?.parse()?;
        let c: usize = map.get("cols").context("missing cols")?.parse()?;
        Ok((r, c))
    }

    /// Register a LAMC2/LAMC3 store file on the server as a disk-resident
    /// matrix (jobs against it stream tiles out-of-core); returns
    /// (rows, cols). Space-free path, as with [`ServiceClient::load_file`].
    pub fn load_store(&mut self, name: &str, path: &str) -> Result<(usize, usize)> {
        protocol::ensure_token("name", name)?;
        protocol::ensure_token("store", path)?;
        let map = self.kv_reply(&format!("LOAD name={name} store={path}"))?;
        let r: usize = map.get("rows").context("missing rows")?.parse()?;
        let c: usize = map.get("cols").context("missing cols")?.parse()?;
        Ok((r, c))
    }

    /// Ask the server to stop accepting connections.
    pub fn shutdown(&mut self) -> Result<()> {
        self.roundtrip("SHUTDOWN")?;
        Ok(())
    }

    /// Apply a read+write timeout to this connection (None = blocking).
    ///
    /// `SO_RCVTIMEO`/`SO_SNDTIMEO` are socket-level options, so setting
    /// them on the writer half also covers the `try_clone`d reader.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout).context("set read timeout")?;
        self.writer.set_write_timeout(timeout).context("set write timeout")?;
        Ok(())
    }

    /// Protocol handshake: returns the peer's `(proto, version)`.
    ///
    /// Negotiates the unified binary framing in the same exchange
    /// (`framing=binary`): a peer that acknowledges it answers
    /// `RESULT`/`EVENTS`/`SPANS` in binary on this connection and
    /// accepts `SUBSCRIBE`. A server that predates the field rejects
    /// the greeting; the client re-greets without it and stays on the
    /// per-verb `RESULTB`/`EVENTSB` fallbacks.
    pub fn hello(&mut self) -> Result<(u64, String)> {
        let map = match self.kv_reply(&format!(
            "HELLO proto={PROTO_VERSION} version={} framing=binary",
            env!("CARGO_PKG_VERSION")
        )) {
            Ok(map) => {
                self.binary = map.get("framing").map(|f| f == "binary").unwrap_or(false);
                map
            }
            Err(e) if e.to_string().contains("unknown field") => {
                self.binary = false;
                self.kv_reply(&format!(
                    "HELLO proto={PROTO_VERSION} version={}",
                    env!("CARGO_PKG_VERSION")
                ))?
            }
            Err(e) => return Err(e),
        };
        let proto: u64 = map.get("proto").context("missing proto")?.parse()?;
        let version = map.get("version").context("missing version")?.clone();
        Ok((proto, version))
    }

    /// Did [`ServiceClient::hello`] land the unified binary framing on
    /// this connection? `SUBSCRIBE` requires it.
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Append dense rows to a store-backed matrix (`APPEND`): ships
    /// `rows * cols` row-major f32 values in the block codec and
    /// returns the grown row count, the new store generation, and the
    /// incremental re-clustering job the server queued (if an earlier
    /// run left a basis to extend).
    pub fn append(
        &mut self,
        name: &str,
        rows: usize,
        cols: usize,
        values: &[f32],
    ) -> Result<AppendReply> {
        protocol::ensure_token("name", name)?;
        ensure!(
            values.len() == rows * cols,
            "append payload has {} values, want {rows} x {cols}",
            values.len()
        );
        let payload = protocol::encode_append_rows(values);
        self.send_line(&format!("APPEND name={name} rows={rows} cols={cols}"))?;
        self.writer.write_all(&payload)?;
        self.writer.flush()?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let total_rows: usize = map.get("rows").context("missing rows")?.parse()?;
        let generation: u64 = map.get("generation").context("missing generation")?.parse()?;
        let job = match map.get("job").map(String::as_str) {
            Some("none") | None => None,
            Some(id) => Some(id.parse().context("bad job id in reply")?),
        };
        Ok(AppendReply { total_rows, generation, job })
    }

    /// Page through a matrix's feed journal (`SUBSCRIBE`): append and
    /// label-update event bodies with `seq > after`, plus the cursor
    /// for the next poll (`None` when the page is empty — keep the
    /// previous cursor). Ships only on the unified framing: call
    /// [`ServiceClient::hello`] first.
    pub fn subscribe(&mut self, name: &str, after: Option<u64>) -> Result<(Vec<String>, Option<u64>)> {
        protocol::ensure_token("name", name)?;
        ensure!(
            self.binary,
            "SUBSCRIBE ships only on the unified framing: call hello() first (a server that \
             predates HELLO framing=binary cannot stream)"
        );
        let line = match after {
            Some(a) => format!("SUBSCRIBE name={name} after={a}"),
            None => format!("SUBSCRIBE name={name}"),
        };
        self.send_line(&line)?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let (count, next) = Self::events_header(&map)?;
        let bytes: usize = map.get("bytes").context("missing bytes")?.parse()?;
        let mut payload = vec![0u8; bytes + 8];
        self.reader.read_exact(&mut payload).context("read subscribe payload")?;
        Ok((protocol::decode_events_binary(&payload, count)?, next))
    }

    /// Discover the shard sets a worker node owns (`SHARDS`).
    pub fn shard_sets(&mut self) -> Result<Vec<ShardSetInfo>> {
        let rest = self.roundtrip("SHARDS")?;
        let tokens: Vec<&str> = rest.split_whitespace().collect();
        let map = protocol::kv_pairs(&tokens)?;
        let n: usize = map.get("sets").context("missing sets count")?.parse()?;
        let mut sets = Vec::with_capacity(n);
        for _ in 0..n {
            let line = self.read_line()?;
            sets.push(protocol::parse_shard_set(&line)?);
        }
        let end = self.read_line()?;
        ensure!(end.trim() == "END", "expected END terminator, got '{}'", end.trim());
        Ok(sets)
    }

    /// Fetch the listed global rows × cols of shard set `name` from a
    /// worker (`GATHERB`): returns row-major f32 values.
    pub fn gather_block(&mut self, name: &str, rows: &[usize], cols: &[usize]) -> Result<Vec<f32>> {
        Ok(self.gather_block_traced(name, rows, cols, None, None)?.0)
    }

    /// [`ServiceClient::gather_block`] with optional trace context. When
    /// both `trace_id` and `parent_span` are given, the worker times the
    /// gather and ships its span sheet back alongside the block (empty
    /// sheet against servers that predate span framing or when the
    /// context is absent).
    pub fn gather_block_traced(
        &mut self,
        name: &str,
        rows: &[usize],
        cols: &[usize],
        trace_id: Option<u64>,
        parent_span: Option<u64>,
    ) -> Result<(Vec<f32>, Vec<SpanRecord>)> {
        protocol::ensure_token("name", name)?;
        let ids = protocol::encode_labels_binary(rows, cols)?;
        let mut line = format!("GATHERB name={name} rows={} cols={}", rows.len(), cols.len());
        if let (Some(t), Some(p)) = (trace_id, parent_span) {
            line.push_str(&format!(" trace_id={t} parent_span={p}"));
        }
        self.send_line(&line)?;
        self.writer.write_all(&ids)?;
        self.writer.flush()?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let bytes: usize = map.get("bytes").context("missing bytes")?.parse()?;
        let mut payload = vec![0u8; bytes];
        self.reader.read_exact(&mut payload).context("read gathered block payload")?;
        let spans = self.read_span_block(&map)?;
        Ok((protocol::decode_block(&payload, rows.len() * cols.len())?, spans))
    }

    /// Run one block job on a worker (`EXECB`): the worker assembles the
    /// block from its own bands plus the `inline` rows (positions into
    /// `rows` it does not own), runs the atom co-clustering, and returns
    /// the resulting atoms over global ids.
    pub fn exec_block(
        &mut self,
        name: &str,
        method: &str,
        k: usize,
        seed: u64,
        rows: &[usize],
        cols: &[usize],
        inline: &[(u32, Vec<f32>)],
    ) -> Result<Vec<Cocluster>> {
        Ok(self.exec_block_traced(name, method, k, seed, rows, cols, inline, None, None)?.0)
    }

    /// [`ServiceClient::exec_block`] with optional trace context: when
    /// both `trace_id` and `parent_span` are present the worker returns
    /// its gather/exec span sheet (ids local to the request, times
    /// relative to request receipt) for the router to stitch.
    #[allow(clippy::too_many_arguments)]
    pub fn exec_block_traced(
        &mut self,
        name: &str,
        method: &str,
        k: usize,
        seed: u64,
        rows: &[usize],
        cols: &[usize],
        inline: &[(u32, Vec<f32>)],
        trace_id: Option<u64>,
        parent_span: Option<u64>,
    ) -> Result<(Vec<Cocluster>, Vec<SpanRecord>)> {
        protocol::ensure_token("name", name)?;
        protocol::ensure_token("method", method)?;
        let payload = protocol::encode_exec_payload(rows, cols, inline)?;
        let mut line = format!(
            "EXECB name={name} method={method} k={k} seed={seed} rows={} cols={} inline={}",
            rows.len(),
            cols.len(),
            inline.len()
        );
        if let (Some(t), Some(p)) = (trace_id, parent_span) {
            line.push_str(&format!(" trace_id={t} parent_span={p}"));
        }
        self.send_line(&line)?;
        self.writer.write_all(&payload)?;
        self.writer.flush()?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let clusters: usize = map.get("clusters").context("missing clusters")?.parse()?;
        let bytes: usize = map.get("bytes").context("missing bytes")?.parse()?;
        let mut body = vec![0u8; bytes];
        self.reader.read_exact(&mut body).context("read exec atoms payload")?;
        let spans = self.read_span_block(&map)?;
        Ok((protocol::decode_atoms(&body, clusters)?, spans))
    }

    /// Read the optional span block a worker appends after a binary
    /// payload when the request carried trace context (`span_bytes=` in
    /// the reply header names the text length; a mix64 checksum trails).
    fn read_span_block(&mut self, map: &BTreeMap<String, String>) -> Result<Vec<SpanRecord>> {
        let Some(len) = map.get("span_bytes") else {
            return Ok(Vec::new());
        };
        let len: usize = len.parse().context("bad span_bytes")?;
        let mut block = vec![0u8; len + 8];
        self.reader.read_exact(&mut block).context("read span block")?;
        protocol::decode_spans_binary(&block)
    }

    /// Fetch a job's recorded span tree (`SPANS`) — empty until the job
    /// starts running; errors on unknown ids. Binary on the unified
    /// framing, text lines otherwise.
    pub fn spans(&mut self, id: u64) -> Result<Vec<SpanRecord>> {
        if self.binary {
            self.send_line(&format!("SPANS id={id}"))?;
            let header = self.read_line()?;
            let map = Self::header_map(&header)?;
            let bytes: usize = map.get("bytes").context("missing bytes")?.parse()?;
            let mut payload = vec![0u8; bytes + 8];
            self.reader.read_exact(&mut payload).context("read binary span payload")?;
            return protocol::decode_spans_binary(&payload);
        }
        let rest = self.roundtrip(&format!("SPANS id={id}"))?;
        let tokens: Vec<&str> = rest.split_whitespace().collect();
        let map = protocol::kv_pairs(&tokens)?;
        let count: usize = map.get("count").context("missing count")?.parse()?;
        let mut spans = Vec::with_capacity(count);
        for _ in 0..count {
            let line = self.read_line()?;
            spans.push(SpanRecord::from_wire(&line)?);
        }
        let end = self.read_line()?;
        ensure!(end.trim() == "END", "expected END terminator, got '{}'", end.trim());
        Ok(spans)
    }

    /// Ask a shard router about its topology (`ROUTE`); a worker node
    /// answers this with a typed error.
    pub fn route(&mut self) -> Result<BTreeMap<String, String>> {
        self.kv_reply("ROUTE")
    }

    /// Page through a job's lifecycle events: `EVENT` line bodies with
    /// `seq > after`, plus the cursor to pass on the next poll (`None`
    /// when the page is empty — keep the previous cursor and poll
    /// again). On the unified framing `EVENTS` itself answers in
    /// binary; otherwise tries the `EVENTSB` compat verb first and
    /// falls back to text `EVENTS` against servers that predate it.
    pub fn events(&mut self, id: u64, after: Option<u64>) -> Result<(Vec<String>, Option<u64>)> {
        if self.binary {
            return self.events_framed(id, after, "EVENTS");
        }
        if self.binary_events {
            match self.events_framed(id, after, "EVENTSB") {
                Ok(page) => return Ok(page),
                Err(e) if e.to_string().contains("unknown verb") => {
                    self.binary_events = false;
                }
                Err(e) => return Err(e),
            }
        }
        self.events_text(id, after)
    }

    fn events_request(id: u64, after: Option<u64>, verb: &str) -> String {
        match after {
            Some(a) => format!("{verb} id={id} after={a}"),
            None => format!("{verb} id={id}"),
        }
    }

    /// Parse the shared `EVENTS`/`EVENTSB` header: `(count, next)`.
    fn events_header(map: &BTreeMap<String, String>) -> Result<(usize, Option<u64>)> {
        let count: usize = map.get("count").context("missing count")?.parse()?;
        let next = match map.get("next") {
            Some(v) => Some(v.parse::<u64>().context("bad next cursor")?),
            None => None,
        };
        ensure!(next.is_some() || count == 0, "non-empty event page without a next cursor");
        Ok((count, next))
    }

    fn events_framed(&mut self, id: u64, after: Option<u64>, verb: &str) -> Result<(Vec<String>, Option<u64>)> {
        self.send_line(&Self::events_request(id, after, verb))?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let (count, next) = Self::events_header(&map)?;
        let bytes: usize = map.get("bytes").context("missing bytes")?.parse()?;
        let mut payload = vec![0u8; bytes + 8];
        self.reader.read_exact(&mut payload).context("read binary event payload")?;
        Ok((protocol::decode_events_binary(&payload, count)?, next))
    }

    fn events_text(&mut self, id: u64, after: Option<u64>) -> Result<(Vec<String>, Option<u64>)> {
        self.send_line(&Self::events_request(id, after, "EVENTS"))?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let (count, next) = Self::events_header(&map)?;
        let mut lines = Vec::with_capacity(count);
        for _ in 0..count {
            let line = self.read_line()?;
            lines.push(
                line.strip_prefix("EVENT ").context("expected EVENT line")?.trim_end().to_string(),
            );
        }
        let end = self.read_line()?;
        ensure!(end.trim() == "END", "expected END terminator, got '{}'", end.trim());
        Ok((lines, next))
    }

    /// Fetch the server's Prometheus-style metrics exposition
    /// (`METRICS`): the body text, one sample or declaration per line.
    pub fn metrics(&mut self) -> Result<String> {
        self.send_line("METRICS")?;
        let header = self.read_line()?;
        let map = Self::header_map(&header)?;
        let lines: usize = map.get("lines").context("missing lines")?.parse()?;
        let mut body = String::new();
        for _ in 0..lines {
            body.push_str(&self.read_line()?);
        }
        let end = self.read_line()?;
        ensure!(end.trim() == "END", "expected END terminator, got '{}'", end.trim());
        Ok(body)
    }
}
