//! Always-on TCP front end for the service: thread-per-connection over
//! `std::net`, one request–response exchange per protocol line.
//!
//! The accept loop runs on a dedicated thread; each connection gets its
//! own handler thread (the same structure as the pjrt-gated
//! `runtime/server.rs`, but serving the public line protocol instead of
//! PJRT executions, and compiled unconditionally). `SHUTDOWN` stops the
//! accept loop; in-flight jobs are drained by
//! [`ServiceManager::shutdown`], which the binary calls after `join`.
//!
//! The accept/read/dispatch machinery is generic over a request
//! handler ([`spawn_accept_loop`]): a worker node and the shard router
//! ([`super::shard::ShardServer`]) speak the same line protocol through
//! the same loop and differ only in which verbs they answer.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::Router;
use crate::partition::BlockJob;
use crate::pipeline::{AtomKind, Lamc};

use super::manager::{JobState, ServiceManager};
use super::protocol::{self, Request, PROTO_VERSION};

/// A running TCP server bound to a local address.
pub struct ServiceServer {
    addr: SocketAddr,
    manager: ServiceManager,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServiceServer {
    /// Bind and start serving in the background. Pass port 0 for an
    /// ephemeral port; the bound address is available via
    /// [`ServiceServer::addr`].
    pub fn spawn(addr: impl ToSocketAddrs, manager: ServiceManager) -> Result<Self> {
        let handler_manager = manager.clone();
        let handler: RequestHandler =
            Arc::new(move |req, payload, conn| respond(&handler_manager, req, payload, conn));
        let AcceptLoop { addr, stop, thread } = spawn_accept_loop(addr, handler)?;
        crate::log_info!("service listening on {addr}");
        Ok(Self { addr, manager, stop, accept_thread: Some(thread) })
    }

    /// The bound socket address (useful with an ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The manager this server fronts.
    pub fn manager(&self) -> &ServiceManager {
        &self.manager
    }

    /// Block until the accept loop exits (i.e. until a `SHUTDOWN`
    /// request arrives or [`ServiceServer::shutdown`] is called from
    /// another thread).
    pub fn join(mut self) -> ServiceManager {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        self.manager.clone()
    }

    /// Stop accepting connections (does not touch in-flight jobs).
    pub fn shutdown(&self) {
        request_stop(&self.stop, self.addr);
    }
}

impl Drop for ServiceServer {
    fn drop(&mut self) {
        request_stop(&self.stop, self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Flag the accept loop to stop and poke it awake with a throwaway
/// connection (accept() has no timeout in std).
pub(crate) fn request_stop(stop: &AtomicBool, addr: SocketAddr) {
    if stop.swap(true, Ordering::SeqCst) {
        return; // already stopping
    }
    let _ = TcpStream::connect(addr);
}

/// Longest accepted request line. Requests are a verb plus a handful of
/// short fields; the cap exists so a peer streaming bytes without a
/// newline cannot grow the buffer without bound.
pub(crate) const MAX_REQUEST_LINE_BYTES: u64 = 64 * 1024;

/// Per-connection negotiated state, owned by the connection loop and
/// threaded through every dispatch on that connection. `binary` flips
/// when a `HELLO … framing=binary` handshake succeeds and stays set for
/// the connection's lifetime: from then on `RESULT`, `EVENTS`, `SPANS`
/// and `SUBSCRIBE` replies ship their bodies as one length-prefixed,
/// checksummed payload with no per-verb negotiation.
#[derive(Debug, Default)]
pub(crate) struct ConnState {
    pub(crate) binary: bool,
}

/// Answers one parsed request (plus its binary request payload, when
/// the verb carries one) with a full response frame. The [`ConnState`]
/// is the connection's negotiated framing, mutable so a `HELLO`
/// handshake can upgrade it mid-connection.
pub(crate) type RequestHandler =
    Arc<dyn Fn(Request, Option<Vec<u8>>, &mut ConnState) -> Reply + Send + Sync>;

/// A bound, running accept loop dispatching to a [`RequestHandler`].
pub(crate) struct AcceptLoop {
    pub(crate) addr: SocketAddr,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) thread: JoinHandle<()>,
}

/// Bind `addr` and serve connections on background threads, parsing the
/// line protocol and reading declared binary request payloads before
/// handing each request to `handler`. `SHUTDOWN` is answered by the
/// handler like any verb, then stops the loop.
pub(crate) fn spawn_accept_loop(addr: impl ToSocketAddrs, handler: RequestHandler) -> Result<AcceptLoop> {
    let listener = TcpListener::bind(addr).context("bind service listener")?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let accept_stop = Arc::clone(&stop);
    let thread = std::thread::Builder::new()
        .name("lamc-accept".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let stop = Arc::clone(&accept_stop);
                let handler = Arc::clone(&handler);
                // Handler threads are detached: they end when the
                // client hangs up, and hold only Arc'd state.
                let _ = std::thread::Builder::new()
                    .name("lamc-conn".into())
                    .spawn(move || handle_connection(stream, stop, addr, handler));
            }
        })
        .context("spawn accept thread")?;
    Ok(AcceptLoop { addr, stop, thread })
}

fn handle_connection(stream: TcpStream, stop: Arc<AtomicBool>, addr: SocketAddr, handler: RequestHandler) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    let mut conn = ConnState::default();
    loop {
        line.clear();
        match (&mut reader).take(MAX_REQUEST_LINE_BYTES).read_line(&mut line) {
            Ok(0) | Err(_) => return, // client hung up (or sent bad UTF-8)
            Ok(n) => {
                if n as u64 == MAX_REQUEST_LINE_BYTES && !line.ends_with('\n') {
                    // Overlong request: reject and drop the connection
                    // rather than resynchronizing mid-stream.
                    let reply = format!("{}\n", protocol::err_line("request line too long"));
                    let _ = writer.write_all(reply.as_bytes());
                    return;
                }
            }
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_request(&line) {
            Ok(req) => {
                let payload = match req.binary_payload_len() {
                    Ok(None) => None,
                    Ok(Some(len)) => {
                        let mut buf = vec![0u8; len];
                        if reader.read_exact(&mut buf).is_err() {
                            return;
                        }
                        Some(buf)
                    }
                    Err(e) => {
                        // The declared payload length is unusable, so
                        // the stream cannot be resynchronized: answer
                        // with the error and drop the connection.
                        let _ = Reply::err(&e).write_to(&mut writer);
                        let _ = writer.flush();
                        return;
                    }
                };
                let is_shutdown = matches!(req, Request::Shutdown);
                let reply = handler(req, payload, &mut conn);
                if is_shutdown {
                    let _ = reply.write_to(&mut writer);
                    let _ = writer.flush();
                    crate::log_info!("shutdown requested by {peer}");
                    request_stop(&stop, addr);
                    return;
                }
                reply
            }
            Err(e) => Reply::err(&e),
        };
        if reply.write_to(&mut writer).and_then(|_| writer.flush()).is_err() {
            return;
        }
    }
}

/// A response frame: text lines, optionally followed by a binary block
/// (the `RESULTB`/`GATHERB`/`EXECB` payload — its length prefix lives
/// in the header line).
pub(crate) enum Reply {
    Text(String),
    Binary { header: String, payload: Vec<u8> },
}

impl Reply {
    pub(crate) fn err(e: &anyhow::Error) -> Reply {
        Reply::Text(format!("{}\n", protocol::err_line(&format!("{e:#}"))))
    }

    fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        match self {
            Reply::Text(s) => w.write_all(s.as_bytes()),
            Reply::Binary { header, payload } => {
                w.write_all(header.as_bytes())?;
                w.write_all(payload)
            }
        }
    }
}

/// Execute one request against the manager; returns the full response.
fn respond(
    manager: &ServiceManager,
    req: Request,
    payload: Option<Vec<u8>>,
    conn: &mut ConnState,
) -> Reply {
    match handle(manager, req, payload, conn) {
        Ok(reply) => reply,
        Err(e) => Reply::err(&e),
    }
}

/// Typed error message for an unknown job id: the wire line is
/// `ERR no-such-job id=N`, stable enough for clients to match on
/// without parsing free text. Shared with the shard router.
pub(crate) fn no_such_job(id: u64) -> String {
    format!("no-such-job id={id}")
}

/// Fetch a finished job's record or explain why it has no result yet.
fn finished_job(manager: &ServiceManager, id: u64) -> Result<super::manager::JobRecord> {
    let record = manager.job(id).with_context(|| no_such_job(id))?;
    match record.state {
        JobState::Done => Ok(record),
        JobState::Failed => anyhow::bail!(
            "job {id} failed: {}",
            record.error.as_deref().unwrap_or("unknown error")
        ),
        other => anyhow::bail!("job {id} is still {}", other.as_str()),
    }
}

/// The `RESULTB`-shaped binary result frame — also what a plain
/// `RESULT` returns once the connection negotiated unified framing.
fn result_binary_reply(manager: &ServiceManager, id: u64) -> Result<Reply> {
    let record = finished_job(manager, id)?;
    let out = record.result.context("done job missing result")?;
    let payload = protocol::encode_labels_binary(&out.row_labels, &out.col_labels)?;
    Ok(Reply::Binary {
        header: format!(
            "OK id={id} k={} rows={} cols={} cached={}\n",
            out.k,
            out.row_labels.len(),
            out.col_labels.len(),
            record.cached,
        ),
        payload,
    })
}

/// The `EVENTSB`-shaped binary events frame — also what a plain
/// `EVENTS` returns once the connection negotiated unified framing.
fn events_binary_reply(manager: &ServiceManager, id: u64, after: Option<u64>) -> Result<Reply> {
    let records = manager
        .job_events(id, after, EVENTS_PAGE_MAX)
        .with_context(|| no_such_job(id))?;
    let payload = protocol::encode_events_binary(&records);
    let mut header = events_header(id, &records);
    header.insert_str(header.len() - 1, &format!(" bytes={}", payload.len() - 8));
    Ok(Reply::Binary { header, payload })
}

fn handle(
    manager: &ServiceManager,
    req: Request,
    payload: Option<Vec<u8>>,
    conn: &mut ConnState,
) -> Result<Reply> {
    match req {
        Request::Submit(spec) => {
            let id = manager.submit(spec)?;
            Ok(Reply::Text(format!("OK id={id}\n")))
        }
        Request::Status { id } => {
            let record = manager.job(id).with_context(|| no_such_job(id))?;
            let mut line = format!("OK id={id} state={} cached={}", record.state.as_str(), record.cached);
            if let Some(e) = &record.error {
                line.push_str(&format!(" error={}", e.replace([' ', '\n'], "_")));
            }
            line.push('\n');
            Ok(Reply::Text(line))
        }
        Request::Result { id } => {
            if conn.binary {
                return result_binary_reply(manager, id);
            }
            let record = finished_job(manager, id)?;
            let out = record.result.context("done job missing result")?;
            Ok(Reply::Text(format!(
                "OK id={id} k={} rows={} cols={} cached={}\nROWS {}\nCOLS {}\nEND\n",
                out.k,
                out.row_labels.len(),
                out.col_labels.len(),
                record.cached,
                protocol::encode_labels(&out.row_labels),
                protocol::encode_labels(&out.col_labels),
            )))
        }
        // Compat shim (one release behind the unified framing): old
        // clients still negotiate binary per verb.
        Request::ResultBinary { id } => result_binary_reply(manager, id),
        Request::Stats => {
            let (queued, running, done, failed) = manager.job_counts();
            let snap = manager.stats().snapshot();
            let cache = manager.cache();
            Ok(Reply::Text(format!(
                "OK jobs_queued={queued} jobs_running={running} jobs_done={done} jobs_failed={failed} \
                 cache_hits={} cache_misses={} cache_entries={} cache_bytes={} cache_capacity_bytes={} \
                 cache_disk_hits={} blocks_total={} blocks_native={} blocks_pjrt={} matrices={} \
                 store_chunks_read={} store_bytes_read={} store_bytes_decoded={} store_cache_hits={} \
                 prefetch_issued={} prefetch_hits={} prefetch_wasted_bytes={} \
                 gather_s={:.6} exec_s={:.6} merge_s={:.6} \
                 hist_gather={} hist_exec={} hist_merge={} hist_queue_wait={}\n",
                snap.cache_hits,
                snap.cache_misses,
                cache.len(),
                cache.bytes(),
                cache.capacity_bytes(),
                cache.disk_hits(),
                snap.blocks_total,
                snap.blocks_native,
                snap.blocks_pjrt,
                manager.matrix_names().len(),
                snap.store_chunks_read,
                snap.store_bytes_read,
                snap.store_bytes_decoded,
                snap.store_cache_hits,
                snap.prefetch_issued,
                snap.prefetch_hits,
                snap.prefetch_wasted_bytes,
                snap.gather_s,
                snap.exec_s,
                snap.merge_s,
                snap.hist_gather.to_wire(),
                snap.hist_exec.to_wire(),
                snap.hist_merge.to_wire(),
                snap.hist_queue_wait.to_wire(),
            )))
        }
        Request::Load { name, dataset, path, store, rows, seed } => {
            let (r, c) = match (dataset, path, store) {
                (Some(ds), None, None) => manager.load_dataset(&name, &ds, rows, seed)?,
                (None, Some(p), None) => manager.load_file(&name, &PathBuf::from(p))?,
                (None, None, Some(s)) => manager.register_store(&name, &PathBuf::from(s))?,
                _ => unreachable!("parser enforces exactly one source"),
            };
            Ok(Reply::Text(format!("OK name={name} rows={r} cols={c}\n")))
        }
        Request::Hello { proto, version: _, framing } => {
            anyhow::ensure!(
                proto == PROTO_VERSION,
                "protocol version mismatch: peer speaks proto {proto}, this node speaks proto {PROTO_VERSION}"
            );
            conn.binary = framing.as_deref() == Some("binary");
            let ack = match &framing {
                Some(f) => format!(" framing={f}"),
                None => String::new(),
            };
            Ok(Reply::Text(format!(
                "OK proto={PROTO_VERSION} version={}{ack}\n",
                env!("CARGO_PKG_VERSION")
            )))
        }
        Request::Shards => {
            let sets = manager.shard_sets();
            let mut out = format!("OK sets={}\n", sets.len());
            for (name, set) in sets {
                let info = protocol::ShardSetInfo {
                    name,
                    rows: set.rows,
                    cols: set.cols,
                    nnz: set.nnz,
                    sparse: set.sparse,
                    fingerprint: set.fingerprint,
                    bands: set.band_spans(),
                };
                out.push_str(&protocol::encode_shard_set(&info)?);
                out.push('\n');
            }
            out.push_str("END\n");
            Ok(Reply::Text(out))
        }
        Request::Route => {
            anyhow::bail!("ROUTE is answered by a shard router; this is a worker node")
        }
        Request::GatherBinary { name, rows, cols, trace_id, parent_span } => {
            let payload = payload.context("GATHERB payload missing")?;
            let traced = trace_id.is_some() && parent_span.is_some();
            let req_start = Instant::now();
            let set = manager
                .shard_set(&name)
                .with_context(|| format!("no shard set named '{name}'"))?;
            let (row_ids, col_ids) = protocol::decode_labels_binary(&payload, rows, cols)?;
            let gather_start_us = req_start.elapsed().as_micros() as u64;
            let t0 = Instant::now();
            let block = set.gather(&row_ids, &col_ids)?;
            let gather_ns = t0.elapsed().as_nanos() as u64;
            let stats = manager.stats();
            stats.add_gather(gather_ns);
            stats.hist_gather.observe_ns(gather_ns);
            stats.add_io(&set.take_io_delta());
            let mut body = protocol::encode_block(block.data());
            let mut header = format!("OK rows={rows} cols={cols} bytes={}", body.len());
            if traced {
                // Local ids from 1, parent 0 = "attach at the exchange
                // boundary", times relative to request receipt — the
                // router re-ids and re-anchors (`trace::span::anchor_spans`).
                let sheet = vec![crate::trace::SpanRecord {
                    id: 1,
                    parent: crate::trace::ROOT_SPAN,
                    name: "gather".into(),
                    worker: 0,
                    start_us: gather_start_us,
                    dur_us: gather_ns / 1_000,
                }];
                let block = protocol::encode_spans_binary(&sheet);
                header.push_str(&format!(" span_bytes={}", block.len() - 8));
                body.extend_from_slice(&block);
            }
            header.push('\n');
            Ok(Reply::Binary { header, payload: body })
        }
        Request::ExecBinary { name, method, k, seed, rows, cols, inline, trace_id, parent_span } => {
            let payload = payload.context("EXECB payload missing")?;
            let traced = trace_id.is_some() && parent_span.is_some();
            let req_start = Instant::now();
            let set = manager
                .shard_set(&name)
                .with_context(|| format!("no shard set named '{name}'"))?;
            let (row_ids, col_ids, inline_rows) =
                protocol::decode_exec_payload(&payload, rows, cols, inline)?;
            let atom: AtomKind = method.parse()?;
            let stats = manager.stats();
            let gather_start_us = req_start.elapsed().as_micros() as u64;
            let t0 = Instant::now();
            let block = set.assemble_block(&row_ids, &col_ids, &inline_rows)?;
            let gather_ns = t0.elapsed().as_nanos() as u64;
            stats.add_gather(gather_ns);
            stats.hist_gather.observe_ns(gather_ns);
            let exec_start_us = req_start.elapsed().as_micros() as u64;
            let t1 = Instant::now();
            let result = Router::native_only(atom.build()).execute(&block, k, seed, stats)?;
            let exec_ns = t1.elapsed().as_nanos() as u64;
            stats.add_exec(exec_ns);
            stats.hist_exec.observe_ns(exec_ns);
            // `Router::execute` counts the native route; the per-job
            // total is the scheduler's job in-process and ours here.
            stats.blocks_total.fetch_add(1, Ordering::Relaxed);
            stats.add_io(&set.take_io_delta());
            let job = BlockJob { round: 0, grid: (0, 0), rows: row_ids, cols: col_ids };
            let atoms = Lamc::block_to_atoms(&job, &result);
            let mut body = protocol::encode_atoms(&atoms);
            let mut header = format!("OK clusters={} bytes={}", atoms.len(), body.len());
            if traced {
                // Worker-local sheet, anchored at the exchange boundary
                // (parent 0, ids from 1, request-relative times). An
                // untraced request leaves the reply byte-identical.
                let sheet = vec![
                    crate::trace::SpanRecord {
                        id: 1,
                        parent: crate::trace::ROOT_SPAN,
                        name: "gather".into(),
                        worker: 0,
                        start_us: gather_start_us,
                        dur_us: gather_ns / 1_000,
                    },
                    crate::trace::SpanRecord {
                        id: 2,
                        parent: crate::trace::ROOT_SPAN,
                        name: "exec".into(),
                        worker: 0,
                        start_us: exec_start_us,
                        dur_us: exec_ns / 1_000,
                    },
                ];
                let block = protocol::encode_spans_binary(&sheet);
                header.push_str(&format!(" span_bytes={}", block.len() - 8));
                body.extend_from_slice(&block);
            }
            header.push('\n');
            Ok(Reply::Binary { header, payload: body })
        }
        Request::Events { id, after } => {
            if conn.binary {
                return events_binary_reply(manager, id, after);
            }
            let records = manager
                .job_events(id, after, EVENTS_PAGE_MAX)
                .with_context(|| no_such_job(id))?;
            let mut out = events_header(id, &records);
            for rec in &records {
                out.push_str("EVENT ");
                out.push_str(&rec.to_wire());
                out.push('\n');
            }
            out.push_str("END\n");
            Ok(Reply::Text(out))
        }
        // Compat shim (one release behind the unified framing).
        Request::EventsBinary { id, after } => events_binary_reply(manager, id, after),
        Request::Metrics => {
            let (body, lines) = worker_metrics(manager).finish();
            Ok(Reply::Text(format!("OK lines={lines}\n{body}END\n")))
        }
        Request::Spans { id } => {
            let spans = manager.job_spans(id).with_context(|| no_such_job(id))?;
            if conn.binary {
                let payload = protocol::encode_spans_binary(&spans);
                let header =
                    format!("OK id={id} count={} bytes={}\n", spans.len(), payload.len() - 8);
                return Ok(Reply::Binary { header, payload });
            }
            let mut out = format!("OK id={id} count={}\n", spans.len());
            for s in &spans {
                out.push_str("SPAN ");
                out.push_str(&s.to_wire());
                out.push('\n');
            }
            out.push_str("END\n");
            Ok(Reply::Text(out))
        }
        Request::Append { name, rows, cols } => {
            let payload = payload.context("APPEND payload missing")?;
            let values = protocol::decode_append_rows(&payload, rows, cols)?;
            let outcome = manager.append_rows(&name, rows, cols, &values)?;
            let job = match outcome.job {
                Some(id) => id.to_string(),
                None => "none".to_string(),
            };
            Ok(Reply::Text(format!(
                "OK name={name} rows={} generation={} job={job}\n",
                outcome.total_rows, outcome.generation,
            )))
        }
        Request::Subscribe { name, after } => {
            anyhow::ensure!(
                conn.binary,
                "SUBSCRIBE ships only on the unified framing: greet with HELLO framing=binary first"
            );
            let records = manager
                .feed_events(&name, after, EVENTS_PAGE_MAX)
                .with_context(|| format!("no matrix named '{name}'"))?;
            let payload = protocol::encode_events_binary(&records);
            let mut header = match records.last() {
                Some(last) => format!("OK name={name} count={} next={}\n", records.len(), last.seq),
                None => format!("OK name={name} count=0\n"),
            };
            header.insert_str(header.len() - 1, &format!(" bytes={}", payload.len() - 8));
            Ok(Reply::Binary { header, payload })
        }
        Request::Shutdown => Ok(Reply::Text("OK shutting-down\n".to_string())),
    }
}

/// Most event records one `EVENTS` page returns; the client keeps
/// polling with the advanced cursor until it drains the journal.
pub(crate) const EVENTS_PAGE_MAX: usize = 512;

/// The shared `EVENTS`/`EVENTSB` header line. `next=` (the cursor for
/// the following poll) is present only when the page is non-empty;
/// an empty page means "keep your cursor and poll again".
pub(crate) fn events_header(id: u64, records: &[crate::trace::EventRecord]) -> String {
    match records.last() {
        Some(last) => format!("OK id={id} count={} next={}\n", records.len(), last.seq),
        None => format!("OK id={id} count=0\n"),
    }
}

/// Render this worker's counters — the same numbers `STATS` reports —
/// as Prometheus-style text exposition.
fn worker_metrics(manager: &ServiceManager) -> protocol::MetricsText {
    let (queued, running, done, failed) = manager.job_counts();
    let snap = manager.stats().snapshot();
    let cache = manager.cache();
    let mut m = protocol::MetricsText::new();
    m.declare("lamc_jobs", "gauge", "Jobs on this node, by lifecycle state.")
        .sample("lamc_jobs{state=\"queued\"}", queued)
        .sample("lamc_jobs{state=\"running\"}", running)
        .sample("lamc_jobs{state=\"done\"}", done)
        .sample("lamc_jobs{state=\"failed\"}", failed)
        .counter("lamc_cache_hits_total", snap.cache_hits, "Result-cache hits (jobs answered without running).")
        .counter("lamc_cache_misses_total", snap.cache_misses, "Result-cache misses (jobs that ran the pipeline).")
        .counter("lamc_cache_disk_hits_total", cache.disk_hits(), "Result-cache hits served from the disk tier.")
        .gauge("lamc_cache_entries", cache.len(), "Result-cache entries resident in memory.")
        .gauge("lamc_cache_bytes", cache.bytes(), "Result-cache bytes resident in memory.")
        .gauge("lamc_cache_capacity_bytes", cache.capacity_bytes(), "Result-cache memory capacity.")
        .gauge("lamc_matrices", manager.matrix_names().len(), "Matrices registered on this node.")
        .counter("lamc_blocks_total", snap.blocks_total, "Block jobs executed.")
        .counter("lamc_blocks_native_total", snap.blocks_native, "Block jobs executed on the native route.")
        .counter("lamc_blocks_pjrt_total", snap.blocks_pjrt, "Block jobs executed on the PJRT route.")
        .counter("lamc_pjrt_fallbacks_total", snap.pjrt_fallbacks, "PJRT failures that fell back to the native route.")
        .counter("lamc_store_chunks_read_total", snap.store_chunks_read, "Store chunks decoded off disk.")
        .counter("lamc_store_bytes_read_total", snap.store_bytes_read, "Store payload bytes read off disk (stored, post-codec).")
        .counter("lamc_store_bytes_decoded_total", snap.store_bytes_decoded, "Uncompressed payload bytes produced by chunk decodes.")
        .counter("lamc_store_cache_hits_total", snap.store_cache_hits, "Decoded-chunk cache hits.")
        .counter("lamc_prefetch_issued_total", snap.prefetch_issued, "Chunks pulled ahead of the compute wave.")
        .counter("lamc_prefetch_hits_total", snap.prefetch_hits, "Chunk reads answered by a prefetched chunk.")
        .counter("lamc_prefetch_wasted_bytes_total", snap.prefetch_wasted_bytes, "Prefetched bytes evicted unconsumed.")
        .counter("lamc_gather_seconds_total", format!("{:.6}", snap.gather_s), "Cumulative gather-phase seconds.")
        .counter("lamc_exec_seconds_total", format!("{:.6}", snap.exec_s), "Cumulative execute-phase seconds.")
        .counter("lamc_merge_seconds_total", format!("{:.6}", snap.merge_s), "Cumulative merge-phase seconds.")
        .declare(
            "lamc_round_seconds",
            "histogram",
            "Phase latency distribution (per round single-node, per block on a worker), by phase.",
        )
        .histogram_series("lamc_round_seconds", "phase=\"gather\"", &snap.hist_gather)
        .histogram_series("lamc_round_seconds", "phase=\"exec\"", &snap.hist_exec)
        .histogram_series("lamc_round_seconds", "phase=\"merge\"", &snap.hist_merge)
        .declare(
            "lamc_queue_wait_seconds",
            "histogram",
            "Seconds jobs waited in the queue before a runner picked them up.",
        )
        .histogram_series("lamc_queue_wait_seconds", "", &snap.hist_queue_wait);
    m
}
