//! Always-on TCP front end for the service: thread-per-connection over
//! `std::net`, one request–response exchange per protocol line.
//!
//! The accept loop runs on a dedicated thread; each connection gets its
//! own handler thread (the same structure as the pjrt-gated
//! `runtime/server.rs`, but serving the public line protocol instead of
//! PJRT executions, and compiled unconditionally). `SHUTDOWN` stops the
//! accept loop; in-flight jobs are drained by
//! [`ServiceManager::shutdown`], which the binary calls after `join`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::manager::{JobState, ServiceManager};
use super::protocol::{self, Request};

/// A running TCP server bound to a local address.
pub struct ServiceServer {
    addr: SocketAddr,
    manager: ServiceManager,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ServiceServer {
    /// Bind and start serving in the background. Pass port 0 for an
    /// ephemeral port; the bound address is available via
    /// [`ServiceServer::addr`].
    pub fn spawn(addr: impl ToSocketAddrs, manager: ServiceManager) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("bind service listener")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept_manager = manager.clone();
        let accept_thread = std::thread::Builder::new()
            .name("lamc-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let manager = accept_manager.clone();
                    let stop = Arc::clone(&accept_stop);
                    // Handler threads are detached: they end when the
                    // client hangs up, and hold only Arc'd state.
                    let _ = std::thread::Builder::new()
                        .name("lamc-conn".into())
                        .spawn(move || handle_connection(stream, manager, stop, addr));
                }
            })
            .context("spawn accept thread")?;
        crate::log_info!("service listening on {addr}");
        Ok(Self { addr, manager, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound socket address (useful with an ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The manager this server fronts.
    pub fn manager(&self) -> &ServiceManager {
        &self.manager
    }

    /// Block until the accept loop exits (i.e. until a `SHUTDOWN`
    /// request arrives or [`ServiceServer::shutdown`] is called from
    /// another thread).
    pub fn join(mut self) -> ServiceManager {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        self.manager.clone()
    }

    /// Stop accepting connections (does not touch in-flight jobs).
    pub fn shutdown(&self) {
        request_stop(&self.stop, self.addr);
    }
}

impl Drop for ServiceServer {
    fn drop(&mut self) {
        request_stop(&self.stop, self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Flag the accept loop to stop and poke it awake with a throwaway
/// connection (accept() has no timeout in std).
fn request_stop(stop: &AtomicBool, addr: SocketAddr) {
    if stop.swap(true, Ordering::SeqCst) {
        return; // already stopping
    }
    let _ = TcpStream::connect(addr);
}

/// Longest accepted request line. Requests are a verb plus a handful of
/// short fields; the cap exists so a peer streaming bytes without a
/// newline cannot grow the buffer without bound.
const MAX_REQUEST_LINE_BYTES: u64 = 64 * 1024;

fn handle_connection(stream: TcpStream, manager: ServiceManager, stop: Arc<AtomicBool>, addr: SocketAddr) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match (&mut reader).take(MAX_REQUEST_LINE_BYTES).read_line(&mut line) {
            Ok(0) | Err(_) => return, // client hung up (or sent bad UTF-8)
            Ok(n) => {
                if n as u64 == MAX_REQUEST_LINE_BYTES && !line.ends_with('\n') {
                    // Overlong request: reject and drop the connection
                    // rather than resynchronizing mid-stream.
                    let reply = format!("{}\n", protocol::err_line("request line too long"));
                    let _ = writer.write_all(reply.as_bytes());
                    return;
                }
            }
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_request(&line) {
            Ok(req) => {
                let is_shutdown = matches!(req, Request::Shutdown);
                let reply = respond(&manager, req);
                if is_shutdown {
                    let _ = reply.write_to(&mut writer);
                    let _ = writer.flush();
                    crate::log_info!("shutdown requested by {peer}");
                    request_stop(&stop, addr);
                    return;
                }
                reply
            }
            Err(e) => Reply::err(&e),
        };
        if reply.write_to(&mut writer).and_then(|_| writer.flush()).is_err() {
            return;
        }
    }
}

/// A response frame: text lines, optionally followed by a binary block
/// (the `RESULTB` payload — its length prefix lives in the header line).
enum Reply {
    Text(String),
    Binary { header: String, payload: Vec<u8> },
}

impl Reply {
    fn err(e: &anyhow::Error) -> Reply {
        Reply::Text(format!("{}\n", protocol::err_line(&format!("{e:#}"))))
    }

    fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        match self {
            Reply::Text(s) => w.write_all(s.as_bytes()),
            Reply::Binary { header, payload } => {
                w.write_all(header.as_bytes())?;
                w.write_all(payload)
            }
        }
    }
}

/// Execute one request against the manager; returns the full response.
fn respond(manager: &ServiceManager, req: Request) -> Reply {
    match handle(manager, req) {
        Ok(reply) => reply,
        Err(e) => Reply::err(&e),
    }
}

/// Fetch a finished job's record or explain why it has no result yet.
fn finished_job(manager: &ServiceManager, id: u64) -> Result<super::manager::JobRecord> {
    let record = manager.job(id).with_context(|| format!("no job with id {id}"))?;
    match record.state {
        JobState::Done => Ok(record),
        JobState::Failed => anyhow::bail!(
            "job {id} failed: {}",
            record.error.as_deref().unwrap_or("unknown error")
        ),
        other => anyhow::bail!("job {id} is still {}", other.as_str()),
    }
}

fn handle(manager: &ServiceManager, req: Request) -> Result<Reply> {
    match req {
        Request::Submit(spec) => {
            let id = manager.submit(spec)?;
            Ok(Reply::Text(format!("OK id={id}\n")))
        }
        Request::Status { id } => {
            let record = manager.job(id).with_context(|| format!("no job with id {id}"))?;
            let mut line = format!("OK id={id} state={} cached={}", record.state.as_str(), record.cached);
            if let Some(e) = &record.error {
                line.push_str(&format!(" error={}", e.replace([' ', '\n'], "_")));
            }
            line.push('\n');
            Ok(Reply::Text(line))
        }
        Request::Result { id } => {
            let record = finished_job(manager, id)?;
            let out = record.result.context("done job missing result")?;
            Ok(Reply::Text(format!(
                "OK id={id} k={} rows={} cols={} cached={}\nROWS {}\nCOLS {}\nEND\n",
                out.k,
                out.row_labels.len(),
                out.col_labels.len(),
                record.cached,
                protocol::encode_labels(&out.row_labels),
                protocol::encode_labels(&out.col_labels),
            )))
        }
        Request::ResultBinary { id } => {
            let record = finished_job(manager, id)?;
            let out = record.result.context("done job missing result")?;
            let payload = protocol::encode_labels_binary(&out.row_labels, &out.col_labels)?;
            Ok(Reply::Binary {
                header: format!(
                    "OK id={id} k={} rows={} cols={} cached={}\n",
                    out.k,
                    out.row_labels.len(),
                    out.col_labels.len(),
                    record.cached,
                ),
                payload,
            })
        }
        Request::Stats => {
            let (queued, running, done, failed) = manager.job_counts();
            let snap = manager.stats().snapshot();
            let cache = manager.cache();
            Ok(Reply::Text(format!(
                "OK jobs_queued={queued} jobs_running={running} jobs_done={done} jobs_failed={failed} \
                 cache_hits={} cache_misses={} cache_entries={} cache_bytes={} cache_capacity_bytes={} \
                 cache_disk_hits={} blocks_total={} blocks_native={} blocks_pjrt={} matrices={} \
                 store_chunks_read={} store_bytes_read={} store_cache_hits={} \
                 prefetch_issued={} prefetch_hits={} prefetch_wasted_bytes={}\n",
                snap.cache_hits,
                snap.cache_misses,
                cache.len(),
                cache.bytes(),
                cache.capacity_bytes(),
                cache.disk_hits(),
                snap.blocks_total,
                snap.blocks_native,
                snap.blocks_pjrt,
                manager.matrix_names().len(),
                snap.store_chunks_read,
                snap.store_bytes_read,
                snap.store_cache_hits,
                snap.prefetch_issued,
                snap.prefetch_hits,
                snap.prefetch_wasted_bytes,
            )))
        }
        Request::Load { name, dataset, path, store, rows, seed } => {
            let (r, c) = match (dataset, path, store) {
                (Some(ds), None, None) => manager.load_dataset(&name, &ds, rows, seed)?,
                (None, Some(p), None) => manager.load_file(&name, &PathBuf::from(p))?,
                (None, None, Some(s)) => manager.register_store(&name, &PathBuf::from(s))?,
                _ => unreachable!("parser enforces exactly one source"),
            };
            Ok(Reply::Text(format!("OK name={name} rows={r} cols={c}\n")))
        }
        Request::Shutdown => Ok(Reply::Text("OK shutting-down\n".to_string())),
    }
}
