//! The long-lived co-clustering service core.
//!
//! A [`ServiceManager`] owns everything the batch pipeline used to
//! re-create per call: a registry of loaded matrices (with memoized
//! content fingerprints), a bounded job queue for backpressure, a small
//! crew of runner threads that drive jobs through `pipeline::Lamc` (whose
//! block jobs execute on the shared persistent
//! [`WorkerPool`](super::WorkerPool)), and a byte-bounded LRU
//! [`ResultCache`](super::ResultCache) so an identical re-submission is
//! answered without touching the pipeline at all.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::Stats;
use crate::matrix::{DenseMatrix, Matrix};
use crate::pipeline::{AtomKind, Lamc, LamcConfig, RunBasis};
use crate::rng::{mix64 as mix, mix64_str as mix_str};
use crate::store::{ChunkWriter, IoCounters, Layout, MatrixRef, ShardManifest, StoreReader};
use crate::trace::{Event, EventRecord, Journal, Trace, DEFAULT_RING_CAPACITY};

use super::cache::{CacheKey, JobOutput, ResultCache};

/// One co-clustering request: which matrix, which method, which knobs.
///
/// This is the wire-visible, cache-canonical subset of
/// [`LamcConfig`]: every field either changes the result (and therefore
/// the cache key) or is the `workers` concurrency cap, which is included
/// conservatively because the partition planner's cost model reads it.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Name of a registered matrix (see [`ServiceManager::register`]).
    pub matrix: String,
    /// `lamc-scc` | `lamc-pnmtf` (partitioned) or `scc` | `pnmtf`
    /// (whole-matrix baseline).
    pub method: String,
    /// Target co-cluster count.
    pub k: usize,
    pub seed: u64,
    /// Partition planner detection-probability threshold.
    pub p_thresh: f64,
    /// Merge similarity threshold τ.
    pub tau: f64,
    /// Concurrency cap for the block scheduler (0 = auto).
    pub workers: usize,
}

impl Default for JobSpec {
    fn default() -> Self {
        Self {
            matrix: String::new(),
            method: "lamc-scc".to_string(),
            k: 4,
            seed: 42,
            p_thresh: 0.95,
            tau: 0.35,
            workers: 0,
        }
    }
}

impl JobSpec {
    /// Is this a partitioned (LAMC) run, as opposed to a whole-matrix
    /// baseline? Errors on unknown methods.
    pub fn partitioned(&self) -> Result<bool> {
        match self.method.as_str() {
            "lamc-scc" | "lamc-pnmtf" => Ok(true),
            "scc" | "pnmtf" => Ok(false),
            other => bail!("unknown method '{other}' (want lamc-scc|lamc-pnmtf|scc|pnmtf)"),
        }
    }

    fn atom(&self) -> Result<AtomKind> {
        self.method.trim_start_matches("lamc-").parse()
    }

    /// The full pipeline configuration this spec denotes. Exposed so
    /// callers (and tests) can reproduce a service run exactly.
    pub fn lamc_config(&self) -> Result<LamcConfig> {
        let mut cfg = LamcConfig {
            k: self.k,
            atom: self.atom()?,
            seed: self.seed,
            workers: self.workers,
            ..Default::default()
        };
        cfg.planner.p_thresh = self.p_thresh;
        cfg.merge.tau = self.tau;
        Ok(cfg)
    }

    /// Canonical config hash: the second half of the result-cache key.
    /// Two specs hash equal iff every result-relevant field matches
    /// (`matrix` is deliberately excluded — the matrix side of the key
    /// is the content fingerprint, so a renamed or reloaded-but-equal
    /// matrix still hits).
    pub fn config_hash(&self) -> u64 {
        let mut h = mix(0x4C41_4D43_5350_4543, self.k as u64);
        h = mix_str(h, &self.method);
        h = mix(h, self.seed);
        h = mix(h, self.p_thresh.to_bits());
        h = mix(h, self.tau.to_bits());
        h = mix(h, self.workers as u64);
        h
    }
}

/// Lifecycle of a submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

impl std::str::FromStr for JobState {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "done" => Ok(JobState::Done),
            "failed" => Ok(JobState::Failed),
            other => bail!("unknown job state '{other}'"),
        }
    }
}

/// A job's full record (cheap to clone: the result is shared).
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    /// Was the result served from the cache instead of a pipeline run?
    pub cached: bool,
    pub error: Option<String>,
    pub result: Option<Arc<JobOutput>>,
    /// When the job reached `Done`/`Failed` — the TTL sweep's clock.
    pub finished_at: Option<Instant>,
    /// Per-job lifecycle event journal (`EVENTS` verb, `lamc watch`).
    /// Shared with the pipeline's [`Trace`] while the job runs.
    pub journal: Arc<Journal>,
}

/// Bounded MPMC queue (Mutex + Condvar): the service's backpressure
/// point. `try_push` rejects when full; `push` blocks; `pop` blocks
/// until an item or close (then drains remaining items before `None`).
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why a non-blocking enqueue was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueRejection {
    Full,
    Closed,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be positive");
        Self {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking enqueue; the item is returned on rejection.
    pub fn try_push(&self, item: T) -> std::result::Result<(), (T, QueueRejection)> {
        let mut q = self.inner.lock().unwrap();
        if q.closed {
            return Err((item, QueueRejection::Closed));
        }
        if q.items.len() >= self.capacity {
            return Err((item, QueueRejection::Full));
        }
        q.items.push_back(item);
        drop(q);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking enqueue: waits for space. Returns the item back if the
    /// queue closes while waiting.
    pub fn push(&self, item: T) -> std::result::Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if q.closed {
                return Err(item);
            }
            if q.items.len() < self.capacity {
                q.items.push_back(item);
                drop(q);
                self.not_empty.notify_one();
                return Ok(());
            }
            q = self.not_full.wait(q).unwrap();
        }
    }

    /// Blocking dequeue; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                drop(q);
                self.not_full.notify_one();
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
    }

    /// Close the queue: pending `pop`s drain then return `None`; pushes
    /// are rejected from now on.
    pub fn close(&self) {
        let mut q = self.inner.lock().unwrap();
        q.closed = true;
        drop(q);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Service sizing knobs.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Job-runner threads draining the queue. 0 is allowed (nothing
    /// drains — useful for tests and manual stepping).
    pub runners: usize,
    /// Bounded queue capacity: submissions beyond this are rejected.
    pub queue_capacity: usize,
    /// Result-cache byte budget (memory tier).
    pub cache_capacity_bytes: usize,
    /// Durable state directory. When set, finished results spill to
    /// `<root>/results` and survive a manager restart (`ResultCache`'s
    /// disk tier). `lamc serve --store-root` sets this.
    pub store_root: Option<PathBuf>,
    /// Byte budget for the spill directory (disk tier): oldest spills
    /// are pruned past it, so a config-sweep workload cannot fill the
    /// disk. 0 = unbounded. Ignored without `store_root`.
    pub cache_disk_capacity_bytes: usize,
    /// Retention for finished (`Done`/`Failed`) job records. The sweep
    /// runs on every submission, so a long-lived server's job map stays
    /// bounded by its recent traffic instead of growing forever.
    /// `None` keeps records until shutdown.
    pub job_ttl: Option<Duration>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            runners: 2,
            queue_capacity: 64,
            cache_capacity_bytes: 64 << 20,
            store_root: None,
            cache_disk_capacity_bytes: 512 << 20,
            job_ttl: Some(Duration::from_secs(3600)),
        }
    }
}

struct MatrixEntry {
    matrix: MatrixRef,
    /// Content hash, computed once at registration (O(1) for
    /// store-backed matrices: it comes from the store header).
    fingerprint: u64,
    /// Backing store file for store-backed registrations — the target
    /// [`ServiceManager::append_rows`] grows. `None` for in-memory
    /// matrices, which cannot be appended to through the service.
    store_path: Option<PathBuf>,
    /// The matrix's feed journal (`SUBSCRIBE`): `MatrixAppended` and
    /// `LabelsUpdated` events. Preserved across appends and even
    /// re-registration so subscriber cursors stay valid while the
    /// matrix grows.
    feed: Arc<Journal>,
    /// The most recent partitioned run retained as a [`RunBasis`], so
    /// the incremental job an append triggers re-runs only the
    /// sampling rounds whose row bands changed. Shared via `Arc` so
    /// runners read/update it without holding the registry lock.
    basis: Arc<Mutex<Option<RetainedBasis>>>,
}

/// A completed partitioned run retained for incremental reuse: the
/// spec that produced it (resubmitted verbatim when an append needs
/// fresh labels) plus its per-job atom sets.
struct RetainedBasis {
    spec: JobSpec,
    basis: Arc<RunBasis>,
}

/// Outcome of [`ServiceManager::append_rows`].
#[derive(Clone, Copy, Debug)]
pub struct AppendOutcome {
    /// Row count of the grown matrix.
    pub total_rows: usize,
    /// Store generation after the append (monotonic per store).
    pub generation: u64,
    /// Incremental re-clustering job queued for the grown matrix, when
    /// an earlier partitioned run left a basis to extend. `None` until
    /// a first job has seeded one.
    pub job: Option<u64>,
}

/// One row band this worker owns, with its open store reader.
pub struct ShardBand {
    pub row_lo: usize,
    pub row_hi: usize,
    pub reader: Arc<StoreReader>,
}

/// The bands of one sharded matrix registered on this worker (`lamc
/// serve --shards`). A worker may own any subset of a matrix's bands;
/// the same band on several workers is replication, which is what lets
/// the router's retry-once policy succeed after a node loss.
pub struct ShardSet {
    /// Parent matrix shape — not the sum of owned bands.
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub sparse: bool,
    /// Parent store content fingerprint; the router refuses topologies
    /// whose workers disagree on it.
    pub fingerprint: u64,
    /// Owned bands, sorted by `row_lo`, pairwise disjoint.
    pub bands: Vec<ShardBand>,
}

impl ShardSet {
    /// `(row_lo, row_hi)` per owned band, ascending.
    pub fn band_spans(&self) -> Vec<(usize, usize)> {
        self.bands.iter().map(|b| (b.row_lo, b.row_hi)).collect()
    }

    /// Index of the owned band containing `row`, if any.
    pub fn owning_band(&self, row: usize) -> Option<usize> {
        let i = self.bands.partition_point(|b| b.row_hi <= row);
        (i < self.bands.len() && self.bands[i].row_lo <= row && row < self.bands[i].row_hi)
            .then_some(i)
    }

    /// Gather a dense block of owned rows (`GATHERB`): every requested
    /// row must live in one of this worker's bands.
    pub fn gather(&self, rows: &[usize], cols: &[usize]) -> Result<DenseMatrix> {
        self.assemble_block(rows, cols, &[])
    }

    /// Assemble an execution block (`EXECB`): owned rows are gathered
    /// from the local shard stores, non-owned rows must arrive inline as
    /// `(position-in-rows, values)`. Rows stay in the job's sampled
    /// order — the exact block the single-node gather would produce.
    pub fn assemble_block(
        &self,
        rows: &[usize],
        cols: &[usize],
        inline: &[(u32, Vec<f32>)],
    ) -> Result<DenseMatrix> {
        let (nr, nc) = (rows.len(), cols.len());
        anyhow::ensure!(nr > 0 && nc > 0, "empty block");
        if let Some(&c) = cols.iter().find(|&&c| c >= self.cols) {
            bail!("column {c} out of range (matrix has {} columns)", self.cols);
        }
        let mut data = vec![0.0f32; nr * nc];
        let mut covered = vec![false; nr];
        for (pos, values) in inline {
            let p = *pos as usize;
            anyhow::ensure!(p < nr, "inline position {p} out of range");
            anyhow::ensure!(!covered[p], "duplicate inline position {p}");
            anyhow::ensure!(
                values.len() == nc,
                "inline row has {} values, block has {nc} columns",
                values.len()
            );
            data[p * nc..(p + 1) * nc].copy_from_slice(values);
            covered[p] = true;
        }
        // Group the remaining positions per owned band so each band
        // answers with one `tile` call (chunk decode amortized across
        // every row the job takes from that band).
        let mut per_band: Vec<Vec<usize>> = vec![Vec::new(); self.bands.len()];
        for (p, &row) in rows.iter().enumerate() {
            if covered[p] {
                continue;
            }
            let b = self.owning_band(row).with_context(|| {
                format!("row {row} is not owned by this worker and was not shipped inline")
            })?;
            per_band[b].push(p);
        }
        for (b, positions) in per_band.iter().enumerate() {
            if positions.is_empty() {
                continue;
            }
            let band = &self.bands[b];
            let local: Vec<usize> = positions.iter().map(|&p| rows[p] - band.row_lo).collect();
            let tile = band.reader.tile(&local, cols)?;
            for (i, &p) in positions.iter().enumerate() {
                data[p * nc..(p + 1) * nc].copy_from_slice(&tile.data()[i * nc..(i + 1) * nc]);
            }
        }
        Ok(DenseMatrix::from_vec(nr, nc, data))
    }

    /// Claim the I/O delta across every owned band's reader (for the
    /// per-node stats fold — see `StatsSnapshot::merged`).
    pub fn take_io_delta(&self) -> IoCounters {
        let mut total = IoCounters::default();
        for band in &self.bands {
            let d = band.reader.take_io_delta();
            total.chunks_read += d.chunks_read;
            total.bytes_read += d.bytes_read;
            total.bytes_decoded += d.bytes_decoded;
            total.cache_hits += d.cache_hits;
            total.prefetch_issued += d.prefetch_issued;
            total.prefetch_hits += d.prefetch_hits;
            total.prefetch_wasted_bytes += d.prefetch_wasted_bytes;
        }
        total
    }
}

struct Inner {
    matrices: RwLock<HashMap<String, MatrixEntry>>,
    /// Sharded matrices this worker holds bands of (`serve --shards`).
    shard_sets: RwLock<HashMap<String, Arc<ShardSet>>>,
    jobs: RwLock<HashMap<u64, JobRecord>>,
    queue: BoundedQueue<u64>,
    cache: ResultCache,
    /// Service-wide telemetry: cache hit/miss counters plus aggregated
    /// per-run block/time counters from every pipeline execution.
    stats: Stats,
    next_id: AtomicU64,
    job_ttl: Option<Duration>,
    /// Where per-job event journals spill as JSONL (`<store_root>/events`).
    /// `None` keeps journals memory-only (bounded ring, no post-mortems).
    events_root: Option<PathBuf>,
}

/// Handle to the service core. Cloning shares the same service; the
/// runner threads live until [`ServiceManager::shutdown`].
#[derive(Clone)]
pub struct ServiceManager {
    inner: Arc<Inner>,
    runners: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServiceManager {
    pub fn new(config: ServiceConfig) -> Self {
        let cache = match &config.store_root {
            Some(root) => ResultCache::with_persistence(
                config.cache_capacity_bytes,
                root.join("results"),
                config.cache_disk_capacity_bytes,
            ),
            None => ResultCache::new(config.cache_capacity_bytes),
        };
        let inner = Arc::new(Inner {
            matrices: RwLock::new(HashMap::new()),
            shard_sets: RwLock::new(HashMap::new()),
            jobs: RwLock::new(HashMap::new()),
            queue: BoundedQueue::new(config.queue_capacity),
            cache,
            stats: Stats::default(),
            next_id: AtomicU64::new(1),
            job_ttl: config.job_ttl,
            events_root: config.store_root.as_ref().map(|r| r.join("events")),
        });
        let mut handles = Vec::with_capacity(config.runners);
        for i in 0..config.runners {
            let inner = Arc::clone(&inner);
            let handle = std::thread::Builder::new()
                .name(format!("lamc-runner-{i}"))
                .spawn(move || {
                    while let Some(id) = inner.queue.pop() {
                        run_job(&inner, id);
                    }
                })
                .expect("spawn job runner");
            handles.push(handle);
        }
        Self { inner, runners: Arc::new(Mutex::new(handles)) }
    }

    /// Register an in-memory matrix under a name (replacing any previous
    /// binding). Computes and memoizes the content fingerprint.
    pub fn register(&self, name: &str, matrix: Matrix) -> u64 {
        self.register_ref(name, MatrixRef::in_mem(matrix))
    }

    /// Register a matrix handle — in-memory or store-backed — under a
    /// name. Store-backed registration is O(1): the fingerprint comes
    /// from the store header, never a payload scan.
    pub fn register_ref(&self, name: &str, matrix: MatrixRef) -> u64 {
        self.register_entry(name, matrix, None)
    }

    fn register_entry(&self, name: &str, matrix: MatrixRef, store_path: Option<PathBuf>) -> u64 {
        let fingerprint = matrix.fingerprint();
        let mut matrices = self.inner.matrices.write().unwrap();
        // Re-registering keeps the feed journal (subscriber cursors
        // survive a reload) but drops any retained basis: the new
        // content has no relation to the old run's partial sets.
        let feed = match matrices.remove(name) {
            Some(old) => old.feed,
            None => Arc::new(Journal::new(DEFAULT_RING_CAPACITY)),
        };
        let entry = MatrixEntry {
            matrix,
            fingerprint,
            store_path,
            feed,
            basis: Arc::new(Mutex::new(None)),
        };
        matrices.insert(name.to_string(), entry);
        fingerprint
    }

    /// Register a LAMC2/LAMC3 store file as a disk-resident matrix: the
    /// pipeline will stream chunk-backed tiles from it instead of
    /// holding the matrix in RAM. Returns (rows, cols).
    pub fn register_store(&self, name: &str, path: &Path) -> Result<(usize, usize)> {
        let matrix = MatrixRef::open_store(path)?;
        let shape = (matrix.rows(), matrix.cols());
        self.register_entry(name, matrix, Some(path.to_path_buf()));
        crate::log_info!("registered store {path:?} as '{name}' ({} x {})", shape.0, shape.1);
        Ok(shape)
    }

    /// Register a named dataset spec (`amazon1000`, `classic4`,
    /// `rcv1_large`) built by the synthetic generators.
    pub fn load_dataset(&self, name: &str, dataset: &str, rows: Option<usize>, seed: u64) -> Result<(usize, usize)> {
        let ds = crate::data::datasets::build(dataset, rows, seed)
            .with_context(|| format!("unknown dataset '{dataset}'"))?;
        let shape = (ds.matrix.rows(), ds.matrix.cols());
        self.register(name, ds.matrix);
        Ok(shape)
    }

    /// Register a matrix loaded from disk: a LAMC2/LAMC3 store (kept
    /// disk-resident), MatrixMarket when the path ends in `.mtx`, or the
    /// LAMC binary format otherwise (both materialized into RAM).
    pub fn load_file(&self, name: &str, path: &Path) -> Result<(usize, usize)> {
        match path.extension().and_then(|e| e.to_str()) {
            Some("lamc2") | Some("lamc3") => self.register_store(name, path),
            Some("mtx") => {
                let matrix = Matrix::Sparse(crate::matrix::io::read_matrix_market(path)?);
                let shape = (matrix.rows(), matrix.cols());
                self.register(name, matrix);
                Ok(shape)
            }
            _ => {
                let matrix = crate::matrix::io::load(path)?;
                let shape = (matrix.rows(), matrix.cols());
                self.register(name, matrix);
                Ok(shape)
            }
        }
    }

    /// Append `rows` dense rows (row-major, `rows * cols` values) to a
    /// store-backed matrix's backing file, sealing them as new row
    /// bands with a bumped footer generation, and swap the grown
    /// reader in under the same name. The content fingerprint changes
    /// with the append, so result-cache entries for the old content
    /// simply stop matching — stale labels are never served.
    ///
    /// Emits [`Event::MatrixAppended`] to the matrix's feed journal
    /// (`SUBSCRIBE`), and — when an earlier partitioned job left a
    /// [`RunBasis`] — resubmits that job's spec so an incremental
    /// re-clustering republishes labels for the grown matrix.
    pub fn append_rows(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        values: &[f32],
    ) -> Result<AppendOutcome> {
        anyhow::ensure!(rows >= 1, "append of zero rows");
        let want = rows.checked_mul(cols).context("append shape overflows")?;
        anyhow::ensure!(
            values.len() == want,
            "append payload has {} values, want {rows} x {cols}",
            values.len()
        );
        let (path, feed) = {
            let matrices = self.inner.matrices.read().unwrap();
            let e = matrices
                .get(name)
                .with_context(|| format!("no matrix named '{name}' is loaded"))?;
            let path = e.store_path.clone().with_context(|| {
                format!(
                    "matrix '{name}' is in-memory; APPEND needs a store-backed matrix \
                     (pack it and re-register via LOAD name={name} store=...)"
                )
            })?;
            (path, Arc::clone(&e.feed))
        };
        let mut writer = ChunkWriter::append_to(&path)?;
        anyhow::ensure!(
            writer.cols() == cols,
            "append rows have {cols} columns, store '{name}' has {}",
            writer.cols()
        );
        for r in 0..rows {
            let row = &values[r * cols..(r + 1) * cols];
            match writer.layout() {
                Layout::Dense => writer.append_dense_row(row)?,
                Layout::Csr => {
                    let entries: Vec<(u32, f32)> = row
                        .iter()
                        .enumerate()
                        .filter(|&(_, &v)| v != 0.0)
                        .map(|(j, &v)| (j as u32, v))
                        .collect();
                    writer.append_sparse_row(&entries)?;
                }
            }
        }
        writer.finish()?;
        let matrix = MatrixRef::open_store(&path)?;
        let generation = matrix.generation();
        let total_rows = matrix.rows();
        let fingerprint = matrix.fingerprint();
        let retained = {
            let mut matrices = self.inner.matrices.write().unwrap();
            let e = matrices
                .get_mut(name)
                .with_context(|| format!("matrix '{name}' disappeared during the append"))?;
            e.matrix = matrix;
            e.fingerprint = fingerprint;
            e.basis.lock().unwrap().as_ref().map(|r| r.spec.clone())
        };
        feed.emit(Event::MatrixAppended { rows: rows as u64, generation });
        crate::log_info!(
            "appended {rows} row(s) to '{name}' (now {total_rows} rows, generation {generation})"
        );
        // Re-cluster incrementally: resubmit the retained spec; the
        // runner finds the basis and re-runs only the sampling rounds
        // whose row bands grew. A full queue degrades to no job — the
        // append itself is already durable.
        let job = match retained {
            Some(spec) => match self.submit(spec) {
                Ok(id) => Some(id),
                Err(e) => {
                    crate::log_warn!("append to '{name}': incremental resubmit rejected ({e:#})");
                    None
                }
            },
            None => None,
        };
        Ok(AppendOutcome { total_rows, generation, job })
    }

    /// Page through a matrix's feed journal (`SUBSCRIBE`): append and
    /// label-update events with `seq > after` (all retained records
    /// when `after` is `None`), at most `max`. `None` for an unknown
    /// matrix name.
    pub fn feed_events(&self, name: &str, after: Option<u64>, max: usize) -> Option<Vec<EventRecord>> {
        let feed = {
            let matrices = self.inner.matrices.read().unwrap();
            Arc::clone(&matrices.get(name)?.feed)
        };
        Some(feed.events_after(after, max))
    }

    /// Register this worker's bands of a sharded matrix from its
    /// manifest. `indices` picks which bands (default: all of them —
    /// full replication). Duplicate indices are a typed error: silently
    /// opening the same band twice would double its I/O accounting and
    /// mask a mis-written `--shards` flag.
    pub fn register_shards(
        &self,
        name: &str,
        manifest_path: &Path,
        indices: Option<&[usize]>,
    ) -> Result<Arc<ShardSet>> {
        let manifest = ShardManifest::load(manifest_path)?;
        let selected: Vec<usize> = match indices {
            Some(list) => list.to_vec(),
            None => (0..manifest.entries.len()).collect(),
        };
        anyhow::ensure!(!selected.is_empty(), "no shard indices selected for '{name}'");
        let mut seen = std::collections::HashSet::new();
        let mut bands = Vec::with_capacity(selected.len());
        for &i in &selected {
            anyhow::ensure!(
                seen.insert(i),
                "duplicate band ownership: shard index {i} of '{name}' registered twice"
            );
            let entry = manifest.entries.get(i).with_context(|| {
                format!("shard index {i} out of range ('{name}' has {} shards)", manifest.entries.len())
            })?;
            let path = manifest.shard_path(entry);
            let reader = StoreReader::open(&path)
                .with_context(|| format!("open shard {i} of '{name}'"))?;
            anyhow::ensure!(
                reader.rows() == entry.row_hi - entry.row_lo && reader.cols() == manifest.cols,
                "shard {i} of '{name}' is {}x{}, manifest says {}x{}",
                reader.rows(),
                reader.cols(),
                entry.row_hi - entry.row_lo,
                manifest.cols
            );
            bands.push(ShardBand {
                row_lo: entry.row_lo,
                row_hi: entry.row_hi,
                reader: Arc::new(reader),
            });
        }
        bands.sort_by_key(|b| b.row_lo);
        let set = Arc::new(ShardSet {
            rows: manifest.rows,
            cols: manifest.cols,
            nnz: manifest.nnz,
            sparse: manifest.sparse,
            fingerprint: manifest.fingerprint,
            bands,
        });
        crate::log_info!(
            "registered shard set '{name}': {} x {}, {} band(s) of {}",
            set.rows,
            set.cols,
            set.bands.len(),
            manifest.entries.len()
        );
        self.inner.shard_sets.write().unwrap().insert(name.to_string(), Arc::clone(&set));
        Ok(set)
    }

    /// The shard set registered under `name`, if any.
    pub fn shard_set(&self, name: &str) -> Option<Arc<ShardSet>> {
        self.inner.shard_sets.read().unwrap().get(name).cloned()
    }

    /// Every registered shard set, sorted by name.
    pub fn shard_sets(&self) -> Vec<(String, Arc<ShardSet>)> {
        let mut sets: Vec<(String, Arc<ShardSet>)> = self
            .inner
            .shard_sets
            .read()
            .unwrap()
            .iter()
            .map(|(n, s)| (n.clone(), Arc::clone(s)))
            .collect();
        sets.sort_by(|a, b| a.0.cmp(&b.0));
        sets
    }

    /// Names of registered matrices (sorted).
    pub fn matrix_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.inner.matrices.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    fn lookup_matrix(&self, name: &str) -> Result<(MatrixRef, u64)> {
        if let Some(e) = self.inner.matrices.read().unwrap().get(name) {
            return Ok((e.matrix.clone(), e.fingerprint));
        }
        // Lazy auto-load: a matrix named after a built-in dataset spec is
        // generated on first reference (default seed 42, full size).
        if crate::data::datasets::spec(name).is_some() {
            crate::log_info!("auto-loading dataset '{name}' (seed 42)");
            self.load_dataset(name, name, None, 42)?;
            if let Some(e) = self.inner.matrices.read().unwrap().get(name) {
                return Ok((e.matrix.clone(), e.fingerprint));
            }
        }
        bail!("no matrix named '{name}' is loaded")
    }

    /// Submit a job. Validates the spec and matrix, then enqueues with
    /// backpressure: a full queue rejects immediately (the client should
    /// retry later) rather than buffering unboundedly.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        // Keep the job map bounded before growing it: every submission
        // sweeps finished records past their TTL.
        self.sweep_jobs();
        spec.partitioned()?; // validate method early
        spec.lamc_config()?;
        anyhow::ensure!(spec.k >= 1, "k must be ≥ 1");
        self.lookup_matrix(&spec.matrix)?; // validate (and auto-load) matrix
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let journal = Arc::new(match &self.inner.events_root {
            // Spill failures degrade to a memory-only journal: events are
            // advisory, so a read-only events dir must not fail the job.
            Some(root) => {
                Journal::with_spill(DEFAULT_RING_CAPACITY, &root.join(format!("job-{id}.jsonl")))
                    .unwrap_or_else(|e| {
                        crate::log_warn!("job {id}: event spill unavailable ({e:#})");
                        Journal::new(DEFAULT_RING_CAPACITY)
                    })
            }
            None => Journal::new(DEFAULT_RING_CAPACITY),
        });
        let record = JobRecord {
            id,
            spec,
            state: JobState::Queued,
            cached: false,
            error: None,
            result: None,
            finished_at: None,
            journal: Arc::clone(&journal),
        };
        self.inner.jobs.write().unwrap().insert(id, record);
        // Before the queue push: a runner can pop the id the instant it
        // lands, and JobStarted must not beat JobQueued into the journal.
        // A rejected push discards the whole journal with the record.
        journal.emit(Event::JobQueued);
        if let Err((_, why)) = self.inner.queue.try_push(id) {
            self.inner.jobs.write().unwrap().remove(&id);
            match why {
                QueueRejection::Full => bail!(
                    "job queue full ({} pending); retry later",
                    self.inner.queue.capacity()
                ),
                QueueRejection::Closed => bail!("service is shutting down"),
            }
        }
        Ok(id)
    }

    /// Snapshot one job's record.
    pub fn job(&self, id: u64) -> Option<JobRecord> {
        self.inner.jobs.read().unwrap().get(&id).cloned()
    }

    /// Page through a job's lifecycle events: records with `seq > after`
    /// (all retained records when `after` is `None`), at most `max`.
    /// `None` for an unknown job id.
    pub fn job_events(&self, id: u64, after: Option<u64>, max: usize) -> Option<Vec<EventRecord>> {
        let journal = {
            let jobs = self.inner.jobs.read().unwrap();
            Arc::clone(&jobs.get(&id)?.journal)
        };
        Some(journal.events_after(after, max))
    }

    /// A job's recorded span tree, sorted by `(start_us, id)`. `None`
    /// for an unknown job id; empty until the job starts running.
    pub fn job_spans(&self, id: u64) -> Option<Vec<crate::trace::SpanRecord>> {
        let journal = {
            let jobs = self.inner.jobs.read().unwrap();
            Arc::clone(&jobs.get(&id)?.journal)
        };
        Some(journal.spans())
    }

    /// Counts of jobs per state: (queued, running, done, failed).
    pub fn job_counts(&self) -> (usize, usize, usize, usize) {
        let jobs = self.inner.jobs.read().unwrap();
        let mut c = (0, 0, 0, 0);
        for j in jobs.values() {
            match j.state {
                JobState::Queued => c.0 += 1,
                JobState::Running => c.1 += 1,
                JobState::Done => c.2 += 1,
                JobState::Failed => c.3 += 1,
            }
        }
        c
    }

    /// Drop finished (`Done`/`Failed`) job records older than the
    /// configured TTL; queued and running jobs are never touched.
    /// Returns how many records were removed. Called automatically on
    /// every submission; exposed for explicit maintenance and tests.
    pub fn sweep_jobs(&self) -> usize {
        let Some(ttl) = self.inner.job_ttl else {
            return 0;
        };
        let mut jobs = self.inner.jobs.write().unwrap();
        let before = jobs.len();
        jobs.retain(|_, r| match r.finished_at {
            Some(at) => at.elapsed() <= ttl,
            None => true,
        });
        before - jobs.len()
    }

    /// Service-wide telemetry (cache counters + aggregated block stats).
    pub fn stats(&self) -> &Stats {
        &self.inner.stats
    }

    pub fn cache(&self) -> &ResultCache {
        &self.inner.cache
    }

    /// Block until a job leaves the queue/running states, polling every
    /// few milliseconds; `None` on timeout or unknown id.
    pub fn wait(&self, id: u64, timeout: std::time::Duration) -> Option<JobRecord> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let record = self.job(id)?;
            match record.state {
                JobState::Done | JobState::Failed => return Some(record),
                _ if std::time::Instant::now() >= deadline => return None,
                _ => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        }
    }

    /// Stop accepting work, drain queued jobs, and join the runners.
    /// Idempotent; also called on drop of the last handle.
    pub fn shutdown(&self) {
        self.inner.queue.close();
        let handles = std::mem::take(&mut *self.runners.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ServiceManager {
    fn drop(&mut self) {
        // Only the last handle tears the service down. `Arc::into_inner`
        // yields `Some` for exactly one of any set of racing droppers,
        // unlike a strong_count check (which two simultaneous drops could
        // both read as 2, leaking the runner threads).
        let runners = std::mem::replace(&mut self.runners, Arc::new(Mutex::new(Vec::new())));
        if let Some(mutex) = Arc::into_inner(runners) {
            self.inner.queue.close();
            for h in mutex.into_inner().unwrap() {
                let _ = h.join();
            }
        }
    }
}

fn set_state(inner: &Inner, id: u64, f: impl FnOnce(&mut JobRecord)) {
    if let Some(r) = inner.jobs.write().unwrap().get_mut(&id) {
        f(r);
    }
}

/// Execute one job end to end: cache probe → (maybe) pipeline → record.
fn run_job(inner: &Inner, id: u64) {
    let Some(record) = inner.jobs.read().unwrap().get(&id).cloned() else {
        return;
    };
    // Tag every log line from this runner thread (and the emitted
    // events' journal) with the job id until the job finishes.
    let _scope = crate::logging::job_scope(id);
    set_state(inner, id, |r| r.state = JobState::Running);
    record.journal.emit(Event::JobStarted);

    let trace = Trace::to_journal(Arc::clone(&record.journal));
    // Root of the job's span tree. The journal epoch is submit time, so
    // "now" is exactly how long the job sat queued — recorded both as a
    // `queue` span and into the queue-wait histogram.
    let queue_us = trace.now_us();
    let job_span = trace.reserve_span();
    trace.record_span(trace.reserve_span(), job_span, "queue", 0, 0, queue_us);
    inner.stats.hist_queue_wait.observe_ns(queue_us.saturating_mul(1_000));

    let outcome = execute_spec(inner, id, &record.spec, trace.child_of(job_span));
    // The job span covers submit → terminal state (queue wait included),
    // so every child — queue, rounds, merge — nests inside it.
    trace.record_span(job_span, crate::trace::ROOT_SPAN, "job", 0, 0, trace.now_us());
    match outcome {
        // The terminal event lands before the state flips: a client
        // whose `wait` just returned must find it in the journal.
        Ok((output, cached)) => {
            record.journal.emit(Event::JobDone);
            set_state(inner, id, |r| {
                r.state = JobState::Done;
                r.cached = cached;
                r.result = Some(output);
                r.finished_at = Some(Instant::now());
            });
        }
        Err(e) => {
            let error = format!("{e:#}");
            record.journal.emit(Event::JobFailed { error: error.clone() });
            set_state(inner, id, |r| {
                r.state = JobState::Failed;
                r.error = Some(error);
                r.finished_at = Some(Instant::now());
            });
        }
    }
}

/// Returns the job output and whether it came from the cache.
fn execute_spec(inner: &Inner, job_id: u64, spec: &JobSpec, trace: Trace) -> Result<(Arc<JobOutput>, bool)> {
    let (matrix, fingerprint, feed, basis_slot) = {
        let matrices = inner.matrices.read().unwrap();
        let e = matrices
            .get(&spec.matrix)
            .with_context(|| format!("matrix '{}' disappeared before the job ran", spec.matrix))?;
        (e.matrix.clone(), e.fingerprint, Arc::clone(&e.feed), Arc::clone(&e.basis))
    };
    let key = CacheKey { matrix: fingerprint, config: spec.config_hash() };
    if let Some(hit) = inner.cache.get(&key) {
        inner.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        return Ok((hit, true));
    }
    inner.stats.cache_misses.fetch_add(1, Ordering::Relaxed);

    let mut cfg = spec.lamc_config()?;
    cfg.trace = trace;
    let lamc = Lamc::new(cfg);
    let result = if spec.partitioned()? {
        // Partitioned runs are tracked: the per-job atom sets are
        // retained as a `RunBasis`, and a later run of the same spec
        // against the grown matrix goes through `run_incremental`,
        // which re-runs only the sampling rounds whose row bands
        // changed (labels stay byte-identical to a from-scratch run).
        let opts = lamc.options();
        let prior = {
            let slot = basis_slot.lock().unwrap();
            match &*slot {
                Some(r) if r.spec.config_hash() == spec.config_hash() => Some(Arc::clone(&r.basis)),
                _ => None,
            }
        };
        let (result, next) = match prior {
            Some(basis) => lamc.run_incremental(&matrix, &opts, &basis)?,
            None => lamc.run_tracked(&matrix, &opts)?,
        };
        *basis_slot.lock().unwrap() =
            Some(RetainedBasis { spec: spec.clone(), basis: Arc::new(next) });
        result
    } else {
        lamc.run_baseline(&matrix)?
    };

    // Fold the run's telemetry into the service-wide counters.
    let s = &result.stats;
    inner.stats.blocks_total.fetch_add(s.blocks_total, Ordering::Relaxed);
    inner.stats.blocks_native.fetch_add(s.blocks_native, Ordering::Relaxed);
    inner.stats.blocks_pjrt.fetch_add(s.blocks_pjrt, Ordering::Relaxed);
    inner.stats.pjrt_fallbacks.fetch_add(s.pjrt_fallbacks, Ordering::Relaxed);
    inner.stats.add_gather((s.gather_s * 1e9) as u64);
    inner.stats.add_exec((s.exec_s * 1e9) as u64);
    inner.stats.merge_ns.fetch_add((s.merge_s * 1e9) as u64, Ordering::Relaxed);
    inner.stats.hist_gather.fold(&s.hist_gather);
    inner.stats.hist_exec.fold(&s.hist_exec);
    inner.stats.hist_merge.fold(&s.hist_merge);
    // Store I/O + prefetch telemetry (zero for in-memory matrices):
    // without this fold the reader counters were invisible through the
    // service — STATS reported cache hit/miss but no real disk I/O.
    inner.stats.add_io(&crate::store::IoCounters {
        chunks_read: s.store_chunks_read,
        bytes_read: s.store_bytes_read,
        bytes_decoded: s.store_bytes_decoded,
        cache_hits: s.store_cache_hits,
        prefetch_issued: s.prefetch_issued,
        prefetch_hits: s.prefetch_hits,
        prefetch_wasted_bytes: s.prefetch_wasted_bytes,
    });

    let output = Arc::new(JobOutput {
        row_labels: result.row_labels,
        col_labels: result.col_labels,
        k: result.k,
        elapsed_s: result.elapsed_s,
    });
    inner.cache.put(key, Arc::clone(&output));
    // Fresh labels landed (this was a cache miss): tell the matrix's
    // subscribers, tagged with the store generation they describe.
    feed.emit(Event::LabelsUpdated {
        job: job_id,
        k: output.k as u64,
        generation: matrix.generation(),
    });
    Ok((output, false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{planted_dense, PlantedConfig};
    use std::time::Duration;

    fn small_matrix(seed: u64) -> Matrix {
        planted_dense(&PlantedConfig {
            rows: 60,
            cols: 50,
            row_clusters: 3,
            col_clusters: 3,
            noise: 0.1,
            signal: 1.5,
            seed,
            ..Default::default()
        })
        .matrix
    }

    #[test]
    fn queue_rejects_when_full_and_recovers() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        let (item, why) = q.try_push(3).unwrap_err();
        assert_eq!(item, 3);
        assert_eq!(why, QueueRejection::Full);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok(), "space freed by pop");
    }

    #[test]
    fn queue_blocking_push_waits_for_space() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(10u64).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(11).is_ok());
        // Give the pusher time to block on the full queue.
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.len(), 1, "pusher is blocked, not buffered");
        assert_eq!(q.pop(), Some(10));
        assert!(pusher.join().unwrap(), "push completed after pop");
        assert_eq!(q.pop(), Some(11));
    }

    #[test]
    fn queue_close_drains_then_ends() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(q.try_push(2).unwrap_err().1, QueueRejection::Closed);
        assert_eq!(q.pop(), Some(1), "closed queue still drains");
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn submit_backpressure_without_runners() {
        // runners: 0 ⇒ nothing drains; the bounded queue is the limit.
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 0,
            queue_capacity: 2,
            cache_capacity_bytes: 1 << 20,
            ..Default::default()
        });
        mgr.register("m", small_matrix(1));
        let spec = |seed| JobSpec { matrix: "m".into(), seed, ..Default::default() };
        mgr.submit(spec(1)).unwrap();
        mgr.submit(spec(2)).unwrap();
        let err = mgr.submit(spec(3)).unwrap_err().to_string();
        assert!(err.contains("queue full"), "{err}");
        // The rejected job left no orphan record behind.
        let (queued, running, done, failed) = mgr.job_counts();
        assert_eq!((queued, running, done, failed), (2, 0, 0, 0));
        mgr.shutdown();
    }

    #[test]
    fn jobs_run_to_done_and_cache_hits_second_submission() {
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 1,
            queue_capacity: 8,
            cache_capacity_bytes: 8 << 20,
            ..Default::default()
        });
        mgr.register("m", small_matrix(2));
        let spec = JobSpec { matrix: "m".into(), k: 3, seed: 9, ..Default::default() };
        let a = mgr.submit(spec.clone()).unwrap();
        let ra = mgr.wait(a, Duration::from_secs(120)).expect("job a finished");
        assert_eq!(ra.state, JobState::Done);
        assert!(!ra.cached);
        let b = mgr.submit(spec).unwrap();
        let rb = mgr.wait(b, Duration::from_secs(120)).expect("job b finished");
        assert_eq!(rb.state, JobState::Done);
        assert!(rb.cached, "identical spec must be a cache hit");
        let out_a = ra.result.unwrap();
        let out_b = rb.result.unwrap();
        assert_eq!(out_a.row_labels, out_b.row_labels);
        assert_eq!(out_a.col_labels, out_b.col_labels);
        let snap = mgr.stats().snapshot();
        assert_eq!(snap.cache_hits, 1);
        assert_eq!(snap.cache_misses, 1);
        mgr.shutdown();
    }

    #[test]
    fn job_lifecycle_events_arrive_in_order() {
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 1,
            queue_capacity: 8,
            cache_capacity_bytes: 8 << 20,
            ..Default::default()
        });
        mgr.register("m", small_matrix(11));
        let spec = JobSpec { matrix: "m".into(), k: 3, seed: 4, ..Default::default() };
        let id = mgr.submit(spec.clone()).unwrap();
        assert_eq!(mgr.wait(id, Duration::from_secs(120)).unwrap().state, JobState::Done);
        let events = mgr.job_events(id, None, 4096).expect("job exists");
        let kinds: Vec<&str> = events.iter().map(|r| r.event.kind()).collect();
        // Lifecycle markers in order, with the pipeline's events between.
        let pos = |k: &str| {
            kinds.iter().position(|&x| x == k).unwrap_or_else(|| panic!("no {k} in {kinds:?}"))
        };
        assert_eq!(pos("JobQueued"), 0);
        assert!(pos("JobStarted") < pos("RoundStarted"));
        assert!(pos("RoundCompleted") < pos("MergeStarted"));
        assert!(pos("MergeCompleted") < pos("JobDone"));
        assert_eq!(kinds.last(), Some(&"JobDone"));
        assert!(events.windows(2).all(|w| w[1].seq > w[0].seq), "seqs monotonic");
        // A cache-hit resubmission still gets the full queued→done arc
        // (its journal just has no pipeline rounds).
        let hit = mgr.submit(spec).unwrap();
        mgr.wait(hit, Duration::from_secs(120)).unwrap();
        let kinds: Vec<String> = mgr
            .job_events(hit, None, 64)
            .unwrap()
            .iter()
            .map(|r| r.event.kind().to_string())
            .collect();
        assert_eq!(kinds, ["JobQueued", "JobStarted", "JobDone"]);
        // The cursor pages past what the first call already saw.
        let tail = mgr.job_events(id, Some(0), 4096).unwrap();
        assert_eq!(tail.first().map(|r| r.seq), Some(1));
        assert!(matches!(events.last().unwrap().event, Event::JobDone));
        mgr.shutdown();
    }

    #[test]
    fn failed_jobs_report_errors() {
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 1,
            queue_capacity: 4,
            cache_capacity_bytes: 1 << 20,
            ..Default::default()
        });
        // Unknown matrix fails at submit time.
        let err = mgr.submit(JobSpec { matrix: "ghost".into(), ..Default::default() }).unwrap_err();
        assert!(err.to_string().contains("no matrix named"), "{err}");
        // Unknown method fails at submit time too.
        mgr.register("m", small_matrix(3));
        let err = mgr
            .submit(JobSpec { matrix: "m".into(), method: "magic".into(), ..Default::default() })
            .unwrap_err();
        assert!(err.to_string().contains("unknown method"), "{err}");
        mgr.shutdown();
    }

    #[test]
    fn config_hash_separates_specs() {
        let base = JobSpec { matrix: "m".into(), ..Default::default() };
        let same = JobSpec { matrix: "renamed".into(), ..base.clone() };
        assert_eq!(base.config_hash(), same.config_hash(), "matrix name not in config hash");
        for changed in [
            JobSpec { k: 5, ..base.clone() },
            JobSpec { seed: 43, ..base.clone() },
            JobSpec { method: "pnmtf".into(), ..base.clone() },
            JobSpec { p_thresh: 0.9, ..base.clone() },
            JobSpec { tau: 0.5, ..base.clone() },
            JobSpec { workers: 2, ..base.clone() },
        ] {
            assert_ne!(base.config_hash(), changed.config_hash(), "{changed:?}");
        }
    }

    #[test]
    fn ttl_sweep_drops_finished_records_only() {
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 1,
            queue_capacity: 8,
            cache_capacity_bytes: 1 << 20,
            job_ttl: Some(Duration::ZERO), // everything finished is stale
            ..Default::default()
        });
        mgr.register("m", small_matrix(5));
        let done = mgr.submit(JobSpec { matrix: "m".into(), k: 3, ..Default::default() }).unwrap();
        assert_eq!(mgr.wait(done, Duration::from_secs(120)).unwrap().state, JobState::Done);
        // The finished record is swept; nothing queued/running is.
        assert_eq!(mgr.sweep_jobs(), 1);
        assert!(mgr.job(done).is_none(), "finished record dropped after TTL");
        assert_eq!(mgr.job_counts(), (0, 0, 0, 0));
        // Submission triggers the sweep implicitly too.
        let a = mgr.submit(JobSpec { matrix: "m".into(), k: 3, seed: 1, ..Default::default() }).unwrap();
        mgr.wait(a, Duration::from_secs(120)).unwrap();
        let b = mgr.submit(JobSpec { matrix: "m".into(), k: 3, seed: 2, ..Default::default() }).unwrap();
        assert!(mgr.job(a).is_none(), "a was finished and stale at b's submission");
        mgr.wait(b, Duration::from_secs(120)).unwrap();
        mgr.shutdown();
    }

    #[test]
    fn no_ttl_keeps_finished_records() {
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 1,
            queue_capacity: 4,
            cache_capacity_bytes: 1 << 20,
            job_ttl: None,
            ..Default::default()
        });
        mgr.register("m", small_matrix(6));
        let id = mgr.submit(JobSpec { matrix: "m".into(), k: 3, ..Default::default() }).unwrap();
        mgr.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(mgr.sweep_jobs(), 0);
        assert!(mgr.job(id).is_some());
        mgr.shutdown();
    }

    fn sharded_fixture(name: &str, rows: usize, cols: usize, n: usize) -> (PathBuf, Matrix) {
        let dir = std::env::temp_dir().join(format!("lamc_mgr_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let matrix = small_matrix(77);
        let matrix = match (rows, cols) {
            (60, 50) => matrix,
            _ => {
                let mut rng = crate::rng::Xoshiro256::seed_from(rows as u64 ^ cols as u64);
                let data: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32()).collect();
                Matrix::Dense(DenseMatrix::from_vec(rows, cols, data))
            }
        };
        let store = dir.join("m.lamc3");
        crate::store::chunk::pack_matrix_tiled(&matrix, &store, 16, 16).unwrap();
        let reader = StoreReader::open(&store).unwrap();
        let (manifest_path, _) =
            crate::store::shard_store(&reader, &dir.join("shards"), "m", n).unwrap();
        (manifest_path, matrix)
    }

    #[test]
    fn register_shards_rejects_duplicate_band_ownership() {
        let (manifest_path, _) = sharded_fixture("dup", 60, 50, 2);
        let mgr = ServiceManager::new(ServiceConfig { runners: 0, ..Default::default() });
        let err = mgr.register_shards("m", &manifest_path, Some(&[0, 0])).unwrap_err();
        assert!(err.to_string().contains("duplicate band ownership"), "{err}");
        let err = mgr.register_shards("m", &manifest_path, Some(&[7])).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert!(mgr.shard_set("m").is_none(), "failed registration left no set behind");
        mgr.shutdown();
    }

    #[test]
    fn shard_set_assembles_blocks_with_inline_rows() {
        let (manifest_path, matrix) = sharded_fixture("assemble", 60, 50, 3);
        let mgr = ServiceManager::new(ServiceConfig { runners: 0, ..Default::default() });
        // Own only the middle band; other rows must arrive inline.
        let set = mgr.register_shards("m", &manifest_path, Some(&[1])).unwrap();
        assert_eq!(set.rows, 60);
        assert_eq!(set.cols, 50);
        let (lo, hi) = set.band_spans()[0];

        let dense = match &matrix {
            Matrix::Dense(d) => d,
            _ => unreachable!(),
        };
        let cols: Vec<usize> = vec![3, 7, 11, 40];
        let rows: Vec<usize> = vec![lo + 1, 2, lo, 59];
        let inline: Vec<(u32, Vec<f32>)> = rows
            .iter()
            .enumerate()
            .filter(|(_, &r)| r < lo || r >= hi)
            .map(|(p, &r)| (p as u32, cols.iter().map(|&c| dense.get(r, c)).collect()))
            .collect();
        let block = set.assemble_block(&rows, &cols, &inline).unwrap();
        for (p, &r) in rows.iter().enumerate() {
            for (q, &c) in cols.iter().enumerate() {
                assert_eq!(block.get(p, q), dense.get(r, c), "({r},{c})");
            }
        }
        // I/O from the owned-band tile read is observable and consumed.
        let io = set.take_io_delta();
        assert!(io.chunks_read > 0 || io.cache_hits > 0, "owned rows came off the store");

        // A non-owned row that is not shipped inline is a typed error.
        let err = set.assemble_block(&rows, &cols, &[]).unwrap_err();
        assert!(err.to_string().contains("not owned by this worker"), "{err}");
        mgr.shutdown();
    }

    #[test]
    fn baseline_methods_run_through_the_service() {
        let mgr = ServiceManager::new(ServiceConfig {
            runners: 1,
            queue_capacity: 4,
            cache_capacity_bytes: 1 << 20,
            ..Default::default()
        });
        mgr.register("m", small_matrix(4));
        let id = mgr
            .submit(JobSpec { matrix: "m".into(), method: "scc".into(), k: 3, ..Default::default() })
            .unwrap();
        let r = mgr.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(r.state, JobState::Done, "error: {:?}", r.error);
        let out = r.result.unwrap();
        assert_eq!(out.row_labels.len(), 60);
        assert_eq!(out.col_labels.len(), 50);
        mgr.shutdown();
    }
}
