//! Byte-bounded LRU cache for finished co-clustering results, with
//! optional spill-to-disk persistence.
//!
//! Repeated-analysis workloads re-cluster the same matrix under the same
//! configuration many times (parameter sweeps, dashboards, retries); the
//! service answers those from memory. Keys combine a content hash of the
//! input matrix (`Matrix::fingerprint` or the store header fingerprint,
//! SplitMix64-mixed) with a canonical hash of the job configuration, so
//! any change to either the data or the requested clustering
//! invalidates the entry.
//!
//! With a persistence directory configured (the service's
//! `--store-root`), every insert is also written to
//! `<dir>/<matrix>-<config>.lamcres` and a memory miss falls through to
//! disk — so cached results survive a `ServiceManager` restart. The
//! memory tier stays byte-bounded; the disk tier is the durable record
//! (eviction from memory never deletes a spilled file). Disk entries
//! are checksummed; a damaged file is treated as a miss, never an error.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::cache::ByteLru;
use crate::store::checksum_bytes;

/// Cache key: (matrix content hash, canonical config hash).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub matrix: u64,
    pub config: u64,
}

/// A finished job's labelling, shared between the job table and cache.
#[derive(Clone, Debug, PartialEq)]
pub struct JobOutput {
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    /// Number of final co-clusters.
    pub k: usize,
    /// Wall-clock seconds of the run that produced this result.
    pub elapsed_s: f64,
}

impl JobOutput {
    /// Approximate resident bytes (used for the cache's byte budget).
    pub fn approx_bytes(&self) -> usize {
        (self.row_labels.len() + self.col_labels.len()) * std::mem::size_of::<usize>() + 64
    }
}

/// Magic of a spilled result file.
const RESULT_MAGIC: &[u8; 8] = b"LAMCRES1";

/// Thread-safe LRU result cache bounded by total payload bytes, with an
/// optional disk tier. The memory tier is a shared [`ByteLru`] — the
/// same eviction policy the store reader's chunk cache and the
/// disk-spill pruner use.
///
/// Hit/miss accounting deliberately lives with the caller (the service
/// manager counts into `coordinator::Stats`, the type that already
/// carries run telemetry) — the cache itself only tracks what nobody
/// else can observe: evictions, resident bytes, disk loads/spill
/// failures.
pub struct ResultCache {
    inner: Mutex<ByteLru<CacheKey, Arc<JobOutput>>>,
    persist_dir: Option<PathBuf>,
    /// Disk-tier byte budget; 0 = unbounded (no pruning).
    disk_capacity_bytes: usize,
    /// Entries answered from the disk tier after a memory miss.
    disk_hits: AtomicU64,
    /// Spilled files pruned to keep the disk tier inside its budget.
    disk_evictions: AtomicU64,
    /// Spill/load failures (I/O or checksum); never fatal.
    persist_errors: AtomicU64,
    tmp_counter: AtomicU64,
    /// Bytes spilled since the last directory prune; pruning re-scans
    /// the directory only once this passes a fraction of the budget
    /// (seeded to `u64::MAX` so the first spill always prunes — the
    /// directory may already be over budget from a previous life).
    spilled_since_prune: AtomicU64,
}

impl ResultCache {
    pub fn new(capacity_bytes: usize) -> Self {
        Self {
            inner: Mutex::new(ByteLru::new(capacity_bytes)),
            persist_dir: None,
            disk_capacity_bytes: 0,
            disk_hits: AtomicU64::new(0),
            disk_evictions: AtomicU64::new(0),
            persist_errors: AtomicU64::new(0),
            tmp_counter: AtomicU64::new(0),
            spilled_since_prune: AtomicU64::new(u64::MAX),
        }
    }

    /// A cache whose entries also spill to `dir` and are read back after
    /// a restart. If `dir` cannot be created, persistence is disabled
    /// (with a warning) rather than failing service startup.
    ///
    /// `disk_capacity_bytes` bounds the spill directory: after each
    /// spill, the oldest `.lamcres` files are pruned until the directory
    /// fits the budget again, so a long-lived config-sweep workload
    /// cannot fill the disk. 0 = unbounded (caller opts out explicitly).
    pub fn with_persistence(capacity_bytes: usize, dir: PathBuf, disk_capacity_bytes: usize) -> Self {
        let mut cache = Self::new(capacity_bytes);
        match std::fs::create_dir_all(&dir) {
            Ok(()) => {
                // Sweep tmp files orphaned by a crash mid-spill in a
                // previous life — they are invisible to the `.lamcres`
                // pruner and would otherwise accumulate forever.
                if let Ok(entries) = std::fs::read_dir(&dir) {
                    for entry in entries.flatten() {
                        let name = entry.file_name();
                        if name.to_string_lossy().starts_with(".tmp-") {
                            let _ = std::fs::remove_file(entry.path());
                        }
                    }
                }
                cache.persist_dir = Some(dir);
                cache.disk_capacity_bytes = disk_capacity_bytes;
            }
            Err(e) => {
                crate::log_warn!("result-cache persistence disabled: cannot create {dir:?}: {e}");
            }
        }
        cache
    }

    /// Where entries spill, when persistence is on.
    pub fn persist_dir(&self) -> Option<&Path> {
        self.persist_dir.as_deref()
    }

    /// Look up a result, refreshing its recency. A memory miss falls
    /// through to the disk tier (when configured), promoting any spilled
    /// entry back into memory.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<JobOutput>> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(value) = inner.get(key) {
                return Some(Arc::clone(value));
            }
        }
        let dir = self.persist_dir.as_ref()?;
        let path = entry_path(dir, key);
        if !path.exists() {
            return None;
        }
        match read_output(&path) {
            Ok(output) => {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                let output = Arc::new(output);
                self.insert_memory(*key, Arc::clone(&output));
                Some(output)
            }
            Err(e) => {
                self.persist_errors.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!("ignoring damaged cache spill {path:?}: {e:#}");
                None
            }
        }
    }

    /// Insert a result, evicting least-recently-used memory entries
    /// until the byte budget holds, and spilling to disk when
    /// persistence is on. Values larger than the whole memory budget
    /// skip the memory tier but still spill.
    pub fn put(&self, key: CacheKey, value: Arc<JobOutput>) {
        self.insert_memory(key, Arc::clone(&value));
        if let Some(dir) = &self.persist_dir {
            if let Err(e) = self.spill(dir, &key, &value) {
                self.persist_errors.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!("result-cache spill failed for {key:?}: {e:#}");
            }
            self.prune_disk(dir);
        }
    }

    /// Keep the spill directory inside its byte budget by deleting the
    /// oldest `.lamcres` files first (mtime order — spill recency, which
    /// rename refreshes on re-computation). Best-effort: I/O errors are
    /// skipped, never raised. The directory re-scan is amortized: it
    /// only runs once enough new bytes have spilled to matter (1/16 of
    /// the budget), not on every insert.
    ///
    /// The eviction decision is the shared [`ByteLru`]'s: files replay
    /// into a budget-bounded LRU in mtime order (oldest first), so
    /// whatever the LRU displaces — including any single file larger
    /// than the whole budget — is exactly the set to delete.
    fn prune_disk(&self, dir: &Path) {
        if self.disk_capacity_bytes == 0 {
            return;
        }
        let threshold = (self.disk_capacity_bytes as u64 / 16).max(1);
        if self.spilled_since_prune.load(Ordering::Relaxed) < threshold {
            return;
        }
        self.spilled_since_prune.store(0, Ordering::Relaxed);
        let Ok(entries) = std::fs::read_dir(dir) else {
            return;
        };
        let mut files: Vec<(std::time::SystemTime, u64, PathBuf)> = Vec::new();
        let mut total = 0u64;
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("lamcres") {
                continue;
            }
            let Ok(meta) = entry.metadata() else { continue };
            let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            total += meta.len();
            files.push((mtime, meta.len(), path));
        }
        if total <= self.disk_capacity_bytes as u64 {
            return;
        }
        files.sort_by(|a, b| a.0.cmp(&b.0));
        let mut lru: ByteLru<usize, PathBuf> = ByteLru::new(self.disk_capacity_bytes);
        let mut doomed: Vec<PathBuf> = Vec::new();
        for (i, (_, len, path)) in files.into_iter().enumerate() {
            let ins = lru.insert(i, path, len as usize);
            doomed.extend(ins.evicted.into_iter().map(|(_, p)| p));
            doomed.extend(ins.rejected);
        }
        for path in doomed {
            if std::fs::remove_file(&path).is_ok() {
                self.disk_evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn insert_memory(&self, key: CacheKey, value: Arc<JobOutput>) {
        let bytes = value.approx_bytes();
        // The shared LRU rejects values over the whole budget and evicts
        // stale entries past it; the displaced `Arc`s drop here.
        let _ = self.inner.lock().unwrap().insert(key, value, bytes);
    }

    /// Write-then-rename so a crash mid-write can never leave a
    /// half-written file under the final name.
    fn spill(&self, dir: &Path, key: &CacheKey, value: &JobOutput) -> Result<()> {
        let tmp = dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.tmp_counter.fetch_add(1, Ordering::Relaxed)
        ));
        let result = write_output(&tmp, value)
            .and_then(|()| {
                let path = entry_path(dir, key);
                std::fs::rename(&tmp, &path).with_context(|| format!("rename into {path:?}"))
            });
        if result.is_err() {
            // Never leave a half-written tmp behind: it is invisible to
            // the `.lamcres` pruner and would accumulate forever.
            let _ = std::fs::remove_file(&tmp);
        } else {
            // Track new bytes so prune_disk knows when a re-scan is due.
            // Saturating (not wrapping) add: the counter is seeded to
            // u64::MAX so the first spill of a process always prunes.
            let bytes = (4 + value.row_labels.len() + value.col_labels.len()) as u64 * 8 + 16;
            let prev = self.spilled_since_prune.load(Ordering::Relaxed);
            self.spilled_since_prune.store(prev.saturating_add(bytes), Ordering::Relaxed);
        }
        result
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions()
    }

    /// Entries served from the disk tier (restart survivors).
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Spilled files pruned to keep the disk tier inside its budget.
    pub fn disk_evictions(&self) -> u64 {
        self.disk_evictions.load(Ordering::Relaxed)
    }

    /// Spill/load failures so far (damaged files, full disk, …).
    pub fn persist_errors(&self) -> u64 {
        self.persist_errors.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current payload bytes held in memory.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes()
    }

    pub fn capacity_bytes(&self) -> usize {
        self.inner.lock().unwrap().capacity()
    }
}

fn entry_path(dir: &Path, key: &CacheKey) -> PathBuf {
    dir.join(format!("{:016x}-{:016x}.lamcres", key.matrix, key.config))
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize: magic, then a checksummed body of
/// `k, elapsed_bits, n_rows, n_cols, rows…, cols…` as LE `u64`s.
fn write_output(path: &Path, out: &JobOutput) -> Result<()> {
    let mut body =
        Vec::with_capacity((4 + out.row_labels.len() + out.col_labels.len()) * 8);
    push_u64(&mut body, out.k as u64);
    push_u64(&mut body, out.elapsed_s.to_bits());
    push_u64(&mut body, out.row_labels.len() as u64);
    push_u64(&mut body, out.col_labels.len() as u64);
    for &l in out.row_labels.iter().chain(&out.col_labels) {
        push_u64(&mut body, l as u64);
    }
    let mut f = File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(RESULT_MAGIC)?;
    f.write_all(&checksum_bytes(&body).to_le_bytes())?;
    f.write_all(&body)?;
    f.sync_data()?;
    Ok(())
}

fn read_output(path: &Path) -> Result<JobOutput> {
    let mut f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != RESULT_MAGIC {
        bail!("bad result magic");
    }
    let mut ck = [0u8; 8];
    f.read_exact(&mut ck)?;
    let want = u64::from_le_bytes(ck);
    let mut body = Vec::new();
    f.read_to_end(&mut body)?;
    if checksum_bytes(&body) != want {
        bail!("result checksum mismatch");
    }
    if body.len() < 32 || body.len() % 8 != 0 {
        bail!("result body has {} bytes", body.len());
    }
    let word = |i: usize| {
        let b = &body[i * 8..i * 8 + 8];
        u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    };
    let k = word(0) as usize;
    let elapsed_s = f64::from_bits(word(1));
    let n_rows = word(2) as usize;
    let n_cols = word(3) as usize;
    if body.len() != (4 + n_rows + n_cols) * 8 {
        bail!("result body length does not match label counts");
    }
    let row_labels = (0..n_rows).map(|i| word(4 + i) as usize).collect();
    let col_labels = (0..n_cols).map(|i| word(4 + n_rows + i) as usize).collect();
    Ok(JobOutput { row_labels, col_labels, k, elapsed_s })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn output(n: usize) -> Arc<JobOutput> {
        Arc::new(JobOutput { row_labels: vec![0; n], col_labels: vec![1; n], k: 2, elapsed_s: 0.1 })
    }

    fn key(m: u64, c: u64) -> CacheKey {
        CacheKey { matrix: m, config: c }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lamc_cache_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn get_after_put_round_trips() {
        let cache = ResultCache::new(1 << 20);
        assert!(cache.get(&key(1, 1)).is_none());
        cache.put(key(1, 1), output(10));
        let got = cache.get(&key(1, 1)).unwrap();
        assert_eq!(got.k, 2);
        assert_eq!(got.row_labels.len(), 10);
    }

    #[test]
    fn either_key_half_invalidates() {
        let cache = ResultCache::new(1 << 20);
        cache.put(key(1, 1), output(4));
        assert!(cache.get(&key(2, 1)).is_none(), "different matrix");
        assert!(cache.get(&key(1, 2)).is_none(), "different config");
        assert!(cache.get(&key(1, 1)).is_some());
    }

    #[test]
    fn lru_eviction_respects_recency() {
        let one = output(100).approx_bytes();
        let cache = ResultCache::new(one * 2 + 1);
        cache.put(key(1, 0), output(100));
        cache.put(key(2, 0), output(100));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(cache.get(&key(1, 0)).is_some());
        cache.put(key(3, 0), output(100));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key(1, 0)).is_some());
        assert!(cache.get(&key(2, 0)).is_none(), "LRU entry evicted");
        assert!(cache.get(&key(3, 0)).is_some());
        assert_eq!(cache.evictions(), 1);
        assert!(cache.bytes() <= cache.capacity_bytes());
    }

    #[test]
    fn oversized_values_are_not_cached() {
        let cache = ResultCache::new(64);
        cache.put(key(1, 0), output(10_000));
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0);
    }

    #[test]
    fn reinsert_replaces_bytes() {
        let cache = ResultCache::new(1 << 20);
        cache.put(key(1, 0), output(100));
        let b1 = cache.bytes();
        cache.put(key(1, 0), output(50));
        assert!(cache.bytes() < b1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn spilled_entries_survive_a_new_cache() {
        let dir = tmp_dir("survive");
        let value = Arc::new(JobOutput {
            row_labels: vec![0, 2, 1],
            col_labels: vec![1, 0],
            k: 3,
            elapsed_s: 1.25,
        });
        {
            let cache = ResultCache::with_persistence(1 << 20, dir.clone(), 0);
            cache.put(key(7, 9), Arc::clone(&value));
        } // old cache dropped — simulated restart
        let cache = ResultCache::with_persistence(1 << 20, dir, 0);
        assert!(cache.is_empty(), "memory tier starts cold");
        let got = cache.get(&key(7, 9)).expect("disk tier answers");
        assert_eq!(&*got, &*value);
        assert_eq!(cache.disk_hits(), 1);
        // Promoted into memory: the next get is a memory hit.
        cache.get(&key(7, 9)).unwrap();
        assert_eq!(cache.disk_hits(), 1, "second get served from memory");
        assert!(cache.get(&key(7, 8)).is_none(), "other keys still miss");
    }

    #[test]
    fn damaged_spill_is_a_miss_not_an_error() {
        let dir = tmp_dir("damaged");
        let cache = ResultCache::with_persistence(1 << 20, dir.clone(), 0);
        cache.put(key(1, 1), output(5));
        // Corrupt the spilled file.
        let path = super::entry_path(&dir, &key(1, 1));
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let fresh = ResultCache::with_persistence(1 << 20, dir, 0);
        assert!(fresh.get(&key(1, 1)).is_none());
        assert_eq!(fresh.persist_errors(), 1);
    }

    #[test]
    fn memory_eviction_keeps_disk_tier() {
        let dir = tmp_dir("evict_keep");
        let one = output(100).approx_bytes();
        let cache = ResultCache::with_persistence(one + 1, dir, 0);
        cache.put(key(1, 0), output(100));
        cache.put(key(2, 0), output(100)); // evicts key 1 from memory
        assert_eq!(cache.len(), 1);
        // …but key 1 comes back from disk.
        assert!(cache.get(&key(1, 0)).is_some());
        assert_eq!(cache.disk_hits(), 1);
    }

    #[test]
    fn disk_tier_is_pruned_to_its_budget() {
        let dir = tmp_dir("prune");
        // Budget fits roughly two spilled files of this size.
        let spilled = (4 + 100 + 100) * 8 + 16;
        let cache = ResultCache::with_persistence(1 << 20, dir.clone(), spilled * 2 + 8);
        for i in 0..6u64 {
            cache.put(key(i, 0), output(100));
            // Keep mtimes distinguishable on coarse-granularity filesystems.
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        assert!(cache.disk_evictions() > 0, "old spills pruned");
        let total: u64 = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.path().extension().and_then(|x| x.to_str()) == Some("lamcres"))
            .map(|e| e.metadata().unwrap().len())
            .sum();
        assert!(total <= (spilled * 2 + 8) as u64, "disk tier within budget ({total} B)");
        // The newest spill survives pruning.
        assert!(cache.get(&key(5, 0)).is_some());
    }

    #[test]
    fn unbounded_disk_tier_keeps_everything() {
        let dir = tmp_dir("no_prune");
        let cache = ResultCache::with_persistence(1 << 20, dir.clone(), 0);
        for i in 0..4u64 {
            cache.put(key(i, 0), output(50));
        }
        assert_eq!(cache.disk_evictions(), 0);
        let n = std::fs::read_dir(&dir).unwrap().flatten().count();
        assert_eq!(n, 4);
    }

    #[test]
    fn output_codec_round_trips_empty_labels() {
        let dir = tmp_dir("empty");
        let path = dir.join("x.lamcres");
        let out = JobOutput { row_labels: vec![], col_labels: vec![], k: 0, elapsed_s: 0.0 };
        write_output(&path, &out).unwrap();
        assert_eq!(read_output(&path).unwrap(), out);
    }
}
