//! Byte-bounded LRU cache for finished co-clustering results.
//!
//! Repeated-analysis workloads re-cluster the same matrix under the same
//! configuration many times (parameter sweeps, dashboards, retries); the
//! service answers those from memory. Keys combine a content hash of the
//! input matrix (`Matrix::fingerprint`, SplitMix64-mixed) with a
//! canonical hash of the job configuration, so any change to either the
//! data or the requested clustering invalidates the entry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: (matrix content hash, canonical config hash).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub matrix: u64,
    pub config: u64,
}

/// A finished job's labelling, shared between the job table and cache.
#[derive(Clone, Debug, PartialEq)]
pub struct JobOutput {
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    /// Number of final co-clusters.
    pub k: usize,
    /// Wall-clock seconds of the run that produced this result.
    pub elapsed_s: f64,
}

impl JobOutput {
    /// Approximate resident bytes (used for the cache's byte budget).
    pub fn approx_bytes(&self) -> usize {
        (self.row_labels.len() + self.col_labels.len()) * std::mem::size_of::<usize>() + 64
    }
}

struct Entry {
    value: Arc<JobOutput>,
    bytes: usize,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<CacheKey, Entry>,
    bytes: usize,
    tick: u64,
}

/// Thread-safe LRU result cache bounded by total payload bytes.
///
/// Hit/miss accounting deliberately lives with the caller (the service
/// manager counts into `coordinator::Stats`, the type that already
/// carries run telemetry) — the cache itself only tracks what nobody
/// else can observe: evictions and resident bytes.
pub struct ResultCache {
    inner: Mutex<CacheInner>,
    capacity_bytes: usize,
    evictions: AtomicU64,
}

impl ResultCache {
    pub fn new(capacity_bytes: usize) -> Self {
        Self {
            inner: Mutex::new(CacheInner { map: HashMap::new(), bytes: 0, tick: 0 }),
            capacity_bytes,
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up a result, refreshing its recency.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<JobOutput>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                Some(Arc::clone(&e.value))
            }
            None => None,
        }
    }

    /// Insert a result, evicting least-recently-used entries until the
    /// byte budget holds. Values larger than the whole budget are not
    /// cached at all.
    pub fn put(&self, key: CacheKey, value: Arc<JobOutput>) {
        let bytes = value.approx_bytes();
        if bytes > self.capacity_bytes {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.map.insert(key, Entry { value, bytes, last_used: tick }) {
            inner.bytes -= old.bytes;
        }
        inner.bytes += bytes;
        while inner.bytes > self.capacity_bytes {
            // O(n) LRU scan: entry counts stay small because the budget
            // is on bytes and each entry is a whole labelling.
            let Some((&victim, _)) = inner
                .map
                .iter()
                .filter(|(k2, _)| **k2 != key)
                .min_by_key(|(_, e)| e.last_used)
            else {
                break;
            };
            let e = inner.map.remove(&victim).unwrap();
            inner.bytes -= e.bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current payload bytes held.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn output(n: usize) -> Arc<JobOutput> {
        Arc::new(JobOutput { row_labels: vec![0; n], col_labels: vec![1; n], k: 2, elapsed_s: 0.1 })
    }

    fn key(m: u64, c: u64) -> CacheKey {
        CacheKey { matrix: m, config: c }
    }

    #[test]
    fn get_after_put_round_trips() {
        let cache = ResultCache::new(1 << 20);
        assert!(cache.get(&key(1, 1)).is_none());
        cache.put(key(1, 1), output(10));
        let got = cache.get(&key(1, 1)).unwrap();
        assert_eq!(got.k, 2);
        assert_eq!(got.row_labels.len(), 10);
    }

    #[test]
    fn either_key_half_invalidates() {
        let cache = ResultCache::new(1 << 20);
        cache.put(key(1, 1), output(4));
        assert!(cache.get(&key(2, 1)).is_none(), "different matrix");
        assert!(cache.get(&key(1, 2)).is_none(), "different config");
        assert!(cache.get(&key(1, 1)).is_some());
    }

    #[test]
    fn lru_eviction_respects_recency() {
        let one = output(100).approx_bytes();
        let cache = ResultCache::new(one * 2 + 1);
        cache.put(key(1, 0), output(100));
        cache.put(key(2, 0), output(100));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(cache.get(&key(1, 0)).is_some());
        cache.put(key(3, 0), output(100));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key(1, 0)).is_some());
        assert!(cache.get(&key(2, 0)).is_none(), "LRU entry evicted");
        assert!(cache.get(&key(3, 0)).is_some());
        assert_eq!(cache.evictions(), 1);
        assert!(cache.bytes() <= cache.capacity_bytes());
    }

    #[test]
    fn oversized_values_are_not_cached() {
        let cache = ResultCache::new(64);
        cache.put(key(1, 0), output(10_000));
        assert!(cache.is_empty());
        assert_eq!(cache.bytes(), 0);
    }

    #[test]
    fn reinsert_replaces_bytes() {
        let cache = ResultCache::new(1 << 20);
        cache.put(key(1, 0), output(100));
        let b1 = cache.bytes();
        cache.put(key(1, 0), output(50));
        assert!(cache.bytes() < b1);
        assert_eq!(cache.len(), 1);
    }
}
