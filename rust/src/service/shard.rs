//! Shard router: one logical co-clustering service over multiple
//! `lamc serve` worker nodes (distributed leader of the paper's
//! leader/worker design, §IV-C).
//!
//! A matrix is split into contiguous row bands (`store::shard_store`);
//! each worker registers the bands it owns and advertises them over
//! `SHARDS`. The router replicates the single-node pipeline exactly —
//! partition planning and sampling are dims-only, so they run locally
//! from the manifest dimensions — then scatters each block job to a
//! worker owning the job's *primary* band (`plan_jobs_by_band`), ships
//! the remaining rows inline (`GATHERB` → `EXECB`), gathers the per-job
//! atom co-clusters, and runs one global `merge::consensus` reduce.
//!
//! **Determinism guarantee**: for the same matrix content, seed and
//! config, a routed run yields labels *byte-identical* to
//! `pipeline::Lamc::run` on one node — same leader RNG, same per-job
//! seeds (`job_seed`), same flat job order into the same single merge.
//! `tests/property_store_layouts.rs` proves this over seeded random
//! configs; `tests/integration_shard.rs` adds fault injection.
//!
//! **Failure semantics**: every wire operation carries an I/O timeout
//! and every job a wall-clock budget. A connection that breaks or times
//! out marks its worker dead ([`ShardError::WorkerLost`]); lost jobs
//! are retried (default: once) against surviving owners. When no live
//! worker owns a needed band the job fails typed
//! ([`ShardError::BandLost`]) — never a hang, never a partial label
//! set.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::scheduler::{job_seed, leader_rng};
use crate::coordinator::{plan_jobs_by_band, BandSpan, JobBandPlan, SchedulerConfig, StatsSnapshot};
use crate::merge::{extract_labels, reduce_partial_sets, Cocluster};
use crate::partition::{plan, sample_partition, BlockJob};
use crate::pipeline::{AtomKind, LamcConfig};
use crate::trace::{Event, Journal, SpanRecord, Trace, DEFAULT_RING_CAPACITY};

use super::client::ServiceClient;
use super::manager::{JobSpec, JobState};
use super::protocol::{self, Request, ShardSetInfo, PROTO_VERSION};
use super::server::{
    events_header, no_such_job, request_stop, spawn_accept_loop, AcceptLoop, ConnState, Reply,
    RequestHandler, EVENTS_PAGE_MAX,
};

/// Typed routing failures — the error contract of the fault-injection
/// harness. Stringified via `Display`, each carries a stable
/// `shard …` tag so callers (and the CLI smoke test) can classify
/// failures without downcasting.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardError {
    /// A worker connection broke or timed out mid-exchange. Retryable:
    /// surviving owners of the same bands can re-run the job.
    WorkerLost { addr: String, detail: String },
    /// No live worker owns a band a job needs. Terminal.
    BandLost { name: String, row_lo: usize, row_hi: usize },
    /// A job exceeded its wall-clock budget. Terminal.
    JobTimeout { budget_s: u64 },
    /// A worker speaks a different protocol or binary version.
    VersionMismatch { addr: String, got: String, want: String },
}

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardError::WorkerLost { addr, detail } => {
                write!(f, "shard worker lost: {addr}: {detail}")
            }
            ShardError::BandLost { name, row_lo, row_hi } => {
                write!(f, "shard band lost: no live worker owns rows {row_lo}..{row_hi} of '{name}'")
            }
            ShardError::JobTimeout { budget_s } => {
                write!(f, "shard job timeout: job not finished within {budget_s}s")
            }
            ShardError::VersionMismatch { addr, got, want } => {
                write!(f, "shard worker version mismatch: {addr} runs {got}, router wants {want}")
            }
        }
    }
}

impl std::error::Error for ShardError {}

/// Router knobs: bounded retries and the two timeout layers.
#[derive(Clone, Copy, Debug)]
pub struct ShardRouterConfig {
    /// How many times a job lost to a dead worker is re-run (against
    /// surviving owners) before its error propagates. The issue's
    /// retry-once-then-fail policy is the default.
    pub retries: usize,
    /// Per-exchange socket timeout: a worker that neither answers nor
    /// hangs up within this window is declared lost.
    pub io_timeout: Duration,
    /// Wall-clock budget for one block job across all its exchanges
    /// and retries.
    pub job_timeout: Duration,
}

impl Default for ShardRouterConfig {
    fn default() -> Self {
        Self {
            retries: 1,
            io_timeout: Duration::from_secs(30),
            job_timeout: Duration::from_secs(600),
        }
    }
}

/// Per-round scatter bookkeeping for event emission. `RoundStarted`
/// fires when the round's first job is claimed; `RoundCompleted` when
/// its last job *succeeds* (a retried job counts on the retry that
/// lands, and a round whose job fails terminally never completes).
/// Store I/O happens on the workers, so router-side `RoundCompleted`
/// events carry zero I/O fields — worker `METRICS` has the real totals.
struct RoundProgress {
    jobs: u64,
    started: AtomicBool,
    remaining: AtomicU64,
    gather_ns: AtomicU64,
    exec_ns: AtomicU64,
    /// The round's span id, reserved up front on the leader thread so
    /// every scatter span (and retry) can parent under it race-free;
    /// `0` when tracing is off. Recorded when the round completes.
    span: u64,
    start_us: AtomicU64,
}

impl RoundProgress {
    fn new(jobs: u64, span: u64) -> RoundProgress {
        RoundProgress {
            jobs,
            started: AtomicBool::new(false),
            remaining: AtomicU64::new(jobs),
            gather_ns: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            span,
            start_us: AtomicU64::new(0),
        }
    }

    /// Emit `RoundStarted` exactly once, on the first claimed job.
    fn mark_started(&self, trace: &Trace, round: usize) {
        if !self.started.swap(true, Ordering::SeqCst) {
            self.start_us.store(trace.now_us(), Ordering::SeqCst);
            trace.emit(Event::RoundStarted { round: round as u64, jobs: self.jobs });
        }
    }

    /// Count one job success; the last one emits `RoundCompleted` and
    /// records the round's span (a round whose job fails terminally
    /// never completes, so its span is never recorded).
    fn mark_done(&self, trace: &Trace, round: usize) {
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            let start = self.start_us.load(Ordering::SeqCst);
            trace.record_span(
                self.span,
                trace.parent(),
                &format!("round-{round}"),
                0,
                start,
                trace.now_us().saturating_sub(start),
            );
            trace.emit(Event::RoundCompleted {
                round: round as u64,
                jobs: self.jobs,
                gather_s: self.gather_ns.load(Ordering::Relaxed) as f64 / 1e9,
                exec_s: self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
                io_chunks: 0,
                io_bytes: 0,
                io_cache_hits: 0,
                prefetch_issued: 0,
                prefetch_hits: 0,
                prefetch_wasted_bytes: 0,
            });
        }
    }
}

/// One worker connection plus its liveness flag. The connection is
/// request–response serialized under the mutex; a transport error
/// poisons the stream framing, so the link is dropped and the worker
/// marked dead rather than resynchronized.
struct WorkerLink {
    addr: String,
    alive: AtomicBool,
    conn: Mutex<Option<ServiceClient>>,
}

impl WorkerLink {
    fn alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }
}

/// Band layout and ownership of one sharded matrix across the fleet.
#[derive(Clone, Debug)]
pub struct MatrixTopology {
    pub rows: usize,
    pub cols: usize,
    pub nnz: u64,
    pub sparse: bool,
    pub fingerprint: u64,
    /// Contiguous bands covering `0..rows`, sorted by `row_lo`.
    pub bands: Vec<BandSpan>,
    /// Per band: worker indices owning it, ascending. Identical spans
    /// on several workers are replicas.
    pub owners: Vec<Vec<usize>>,
}

/// A completed routed run — the distributed analogue of `LamcResult`.
#[derive(Clone, Debug)]
pub struct RoutedRun {
    pub row_labels: Vec<usize>,
    pub col_labels: Vec<usize>,
    pub k: usize,
    pub coclusters: Vec<Cocluster>,
}

/// The shard router: owns one connection per worker and the merged
/// band topology, and runs routed co-clustering jobs against them.
pub struct ShardRouter {
    workers: Vec<Arc<WorkerLink>>,
    topo: HashMap<String, MatrixTopology>,
    cfg: ShardRouterConfig,
}

impl ShardRouter {
    /// Connect to every worker, handshake versions, and merge their
    /// advertised shard sets into one validated topology.
    pub fn connect(addrs: &[String], cfg: ShardRouterConfig) -> Result<Self> {
        ensure!(!addrs.is_empty(), "shard router needs at least one worker address");
        let want = format!("proto {PROTO_VERSION} version {}", env!("CARGO_PKG_VERSION"));
        let mut workers = Vec::with_capacity(addrs.len());
        let mut advertised = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let mut client = ServiceClient::connect(addr.as_str())
                .with_context(|| format!("connect to shard worker {addr}"))?;
            client.set_io_timeout(Some(cfg.io_timeout))?;
            let (proto, version) =
                client.hello().with_context(|| format!("handshake with shard worker {addr}"))?;
            if proto != PROTO_VERSION || version != env!("CARGO_PKG_VERSION") {
                return Err(anyhow::Error::new(ShardError::VersionMismatch {
                    addr: addr.clone(),
                    got: format!("proto {proto} version {version}"),
                    want,
                }));
            }
            let sets = client
                .shard_sets()
                .with_context(|| format!("discover shard sets on {addr}"))?;
            advertised.push(sets);
            workers.push(Arc::new(WorkerLink {
                addr: addr.clone(),
                alive: AtomicBool::new(true),
                conn: Mutex::new(Some(client)),
            }));
        }
        let topo = build_topology(&advertised)?;
        ensure!(!topo.is_empty(), "no shard sets advertised by any worker");
        crate::log_info!(
            "shard router: {} worker(s), {} matrix topolog{}",
            workers.len(),
            topo.len(),
            if topo.len() == 1 { "y" } else { "ies" }
        );
        Ok(Self { workers, topo, cfg })
    }

    /// The merged topology (matrix name → bands and owners).
    pub fn topology(&self) -> &HashMap<String, MatrixTopology> {
        &self.topo
    }

    /// Worker addresses and their current liveness.
    pub fn worker_health(&self) -> Vec<(String, bool)> {
        self.workers.iter().map(|w| (w.addr.clone(), w.alive())).collect()
    }

    /// Route one service job spec. Baseline (whole-matrix) methods need
    /// the full matrix on one node and are rejected typed.
    pub fn run_spec(&self, spec: &JobSpec) -> Result<RoutedRun> {
        self.run_spec_traced(spec, &Trace::disabled())
    }

    /// [`ShardRouter::run_spec`] with lifecycle events emitted into
    /// `trace` (advisory: labels are identical with tracing off).
    pub fn run_spec_traced(&self, spec: &JobSpec, trace: &Trace) -> Result<RoutedRun> {
        ensure!(
            spec.partitioned()?,
            "whole-matrix baseline method '{}' cannot be routed across shards",
            spec.method
        );
        self.run_config_traced(&spec.matrix, &spec.lamc_config()?, trace)
    }

    /// Run the partitioned pipeline on sharded matrix `name`,
    /// byte-identical to `Lamc::run` with the same config on one node.
    pub fn run_config(&self, name: &str, cfg: &LamcConfig) -> Result<RoutedRun> {
        self.run_config_traced(name, cfg, &Trace::disabled())
    }

    /// [`ShardRouter::run_config`] with lifecycle events emitted into
    /// `trace`.
    pub fn run_config_traced(&self, name: &str, cfg: &LamcConfig, trace: &Trace) -> Result<RoutedRun> {
        let topo = self
            .topo
            .get(name)
            .with_context(|| format!("no shard topology for matrix '{name}'"))?;
        let (rows, cols) = (topo.rows, topo.cols);
        ensure!(rows > 0 && cols > 0, "empty matrix");

        // 1+2. Plan and sample locally — both are dims-only, so this is
        // the exact leader sequence of `Lamc::run` without any data.
        let mut planner = cfg.planner.clone();
        if planner.workers == 0 {
            planner.workers =
                SchedulerConfig { workers: cfg.workers, ..Default::default() }.effective_workers();
        }
        let partition_plan = plan(rows, cols, &planner);
        let mut rng = leader_rng(cfg.seed);
        let rounds = sample_partition(rows, cols, &partition_plan, &mut rng);
        let jobs: Vec<&BlockJob> = rounds.iter().flat_map(|r| r.jobs.iter()).collect();
        let band_plans = plan_jobs_by_band(&jobs, &topo.bands)?;
        crate::log_info!(
            "routing {} block jobs over {} worker(s) ({} bands)",
            jobs.len(),
            self.workers.len(),
            topo.bands.len()
        );
        let method = match cfg.atom {
            AtomKind::Scc => "scc",
            AtomKind::Pnmtf => "pnmtf",
        };

        // Per-round event bookkeeping (flat job index → round).
        let round_of: Vec<usize> = rounds
            .iter()
            .enumerate()
            .flat_map(|(r, round)| std::iter::repeat_n(r, round.jobs.len()))
            .collect();
        let progress: Vec<RoundProgress> = rounds
            .iter()
            .map(|round| RoundProgress::new(round.jobs.len() as u64, trace.reserve_span()))
            .collect();

        // 3. Scatter: claim-loop threads pull the next unclaimed job.
        // Per-job deadlines start at scatter time, so a stalled worker
        // bounds the whole round.
        let deadline = Instant::now() + self.cfg.job_timeout;
        let slots: Vec<Mutex<Option<Result<Vec<Cocluster>>>>> =
            (0..band_plans.len()).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let n_threads = self.workers.len().min(band_plans.len()).max(1);
        std::thread::scope(|scope| {
            for _ in 0..n_threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= band_plans.len() {
                        break;
                    }
                    let r = round_of[band_plans[i].job];
                    progress[r].mark_started(trace, r);
                    let res = self
                        .run_block(name, topo, method, cfg, &band_plans[i], &jobs, deadline, trace, &progress[r]);
                    if res.is_ok() {
                        progress[r].mark_done(trace, r);
                    }
                    *slots[i].lock().unwrap() = Some(res);
                });
            }
        });

        // 3b. Bounded retry pass: only worker-lost jobs re-run, against
        // whatever owners survive.
        let mut partials = Vec::with_capacity(band_plans.len());
        for (i, slot) in slots.into_iter().enumerate() {
            let mut res = slot.into_inner().unwrap().expect("scatter visited every job");
            let mut attempts = 0;
            while attempts < self.cfg.retries
                && matches!(
                    res.as_ref().err().and_then(|e| e.downcast_ref::<ShardError>()),
                    Some(ShardError::WorkerLost { .. })
                )
            {
                attempts += 1;
                trace.emit(Event::WorkerRetry {
                    job: band_plans[i].job as u64,
                    attempt: attempts as u64,
                });
                crate::log_info!("retrying routed job {i} (attempt {attempts})");
                let r = round_of[band_plans[i].job];
                res = self
                    .run_block(name, topo, method, cfg, &band_plans[i], &jobs, deadline, trace, &progress[r]);
                if res.is_ok() {
                    progress[r].mark_done(trace, r);
                }
            }
            partials.push(res.with_context(|| format!("routed block job {i} failed"))?);
        }

        // 4. Cross-node reduce: concatenate partial atom sets in flat
        // job order — the order `Lamc::run` merges in — then one global
        // consensus merge.
        trace.emit(Event::MergeStarted {
            blocks: partials.iter().map(|p| p.len() as u64).sum(),
        });
        let merge_start_us = trace.now_us();
        let t_merge = Instant::now();
        let merged = reduce_partial_sets(partials, &cfg.merge);
        let (row_labels, col_labels, k) = extract_labels(&merged, rows, cols);
        trace.add_span(
            "merge",
            0,
            merge_start_us,
            trace.now_us().saturating_sub(merge_start_us),
        );
        trace.emit(Event::MergeCompleted {
            k: k as u64,
            merge_s: t_merge.elapsed().as_secs_f64(),
        });
        Ok(RoutedRun { row_labels, col_labels, k, coclusters: merged })
    }

    /// Execute one block job: pick an owner of the job's primary band,
    /// ship the rows it does not own inline, run the atom remotely.
    fn run_block(
        &self,
        name: &str,
        topo: &MatrixTopology,
        method: &str,
        cfg: &LamcConfig,
        plan: &JobBandPlan,
        jobs: &[&BlockJob],
        deadline: Instant,
        trace: &Trace,
        progress: &RoundProgress,
    ) -> Result<Vec<Cocluster>> {
        let job = jobs[plan.job];
        let executor = self.live_owner(&topo.owners[plan.primary]).or_else(|| {
            // Any live worker can execute with every row shipped inline.
            (0..self.workers.len()).find(|&w| self.workers[w].alive())
        });
        let Some(executor) = executor else {
            let band = topo.bands[plan.primary];
            return Err(anyhow::Error::new(ShardError::BandLost {
                name: name.to_string(),
                row_lo: band.row_lo,
                row_hi: band.row_hi,
            }));
        };
        trace.emit(Event::BlockScattered {
            job: plan.job as u64,
            worker: executor as u64,
            band: plan.primary as u64,
        });

        // One scatter span per dispatch (a retry gets a fresh one under
        // the same round span). Worker sheets returned by traced
        // exchanges are stitched under it, anchored at each exchange's
        // router-side window so worker clock skew cannot escape it.
        let scatter_span = trace.reserve_span();
        let scatter_start_us = trace.now_us();
        let (trace_id, parent_span) = if scatter_span == 0 {
            (None, None)
        } else {
            (Some(plan.job as u64), Some(scatter_span))
        };

        let t_gather = Instant::now();
        let mut inline: Vec<(u32, Vec<f32>)> = Vec::new();
        for (band, positions) in &plan.per_band {
            if topo.owners[*band].contains(&executor) {
                continue;
            }
            let Some(owner) = self.live_owner(&topo.owners[*band]) else {
                let span = topo.bands[*band];
                return Err(anyhow::Error::new(ShardError::BandLost {
                    name: name.to_string(),
                    row_lo: span.row_lo,
                    row_hi: span.row_hi,
                }));
            };
            let needed: Vec<usize> = positions.iter().map(|&p| job.rows[p]).collect();
            let exchange_start_us = trace.now_us();
            let (values, sheet) = self.with_conn(owner, deadline, trace, |c| {
                c.gather_block_traced(name, &needed, &job.cols, trace_id, parent_span)
            })?;
            stitch_worker_spans(trace, scatter_span, exchange_start_us, owner, &sheet);
            for (slot, &p) in positions.iter().enumerate() {
                inline.push((
                    p as u32,
                    values[slot * job.cols.len()..(slot + 1) * job.cols.len()].to_vec(),
                ));
            }
        }
        progress.gather_ns.fetch_add(t_gather.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let seed = job_seed(cfg.seed, job);
        let t_exec = Instant::now();
        let exchange_start_us = trace.now_us();
        let res = self.with_conn(executor, deadline, trace, |c| {
            c.exec_block_traced(name, method, cfg.k, seed, &job.rows, &job.cols, &inline, trace_id, parent_span)
        });
        progress.exec_ns.fetch_add(t_exec.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let res = res.map(|(atoms, sheet)| {
            stitch_worker_spans(trace, scatter_span, exchange_start_us, executor, &sheet);
            atoms
        });
        trace.record_span(
            scatter_span,
            progress.span,
            &format!("scatter-{}", plan.job),
            executor as u64,
            scatter_start_us,
            trace.now_us().saturating_sub(scatter_start_us),
        );
        res
    }

    fn live_owner(&self, owners: &[usize]) -> Option<usize> {
        owners.iter().copied().find(|&w| self.workers[w].alive())
    }

    /// One serialized exchange on worker `w`'s connection, under both
    /// timeout layers. Transport errors drop the connection (the
    /// request–response framing is desynchronized) and mark the worker
    /// dead; application errors (`server error: …` replies) leave it
    /// alive and are not retryable.
    fn with_conn<T>(
        &self,
        w: usize,
        deadline: Instant,
        trace: &Trace,
        f: impl FnOnce(&mut ServiceClient) -> Result<T>,
    ) -> Result<T> {
        let link = &self.workers[w];
        let timeout_err =
            || anyhow::Error::new(ShardError::JobTimeout { budget_s: self.cfg.job_timeout.as_secs() });
        if Instant::now() >= deadline {
            return Err(timeout_err());
        }
        let mut guard = link.conn.lock().unwrap();
        // Re-check after the lock wait: exchanges are serialized per
        // worker, so another job may have consumed the budget while
        // holding this connection.
        let now = Instant::now();
        if now >= deadline {
            return Err(timeout_err());
        }
        // Cap the socket timeout by the job budget; sub-millisecond
        // values could round to zero, which std treats as "no timeout".
        let io = self.cfg.io_timeout.min(deadline - now).max(Duration::from_millis(1));
        let Some(conn) = guard.as_mut() else {
            return Err(anyhow::Error::new(ShardError::WorkerLost {
                addr: link.addr.clone(),
                detail: "connection already closed".to_string(),
            }));
        };
        conn.set_io_timeout(Some(io))?;
        match f(conn) {
            Ok(v) => Ok(v),
            Err(e) => {
                let detail = format!("{e:#}");
                if detail.contains("server error:") {
                    // The worker answered; the stream is still in sync.
                    return Err(e);
                }
                *guard = None;
                link.alive.store(false, Ordering::SeqCst);
                trace.emit(Event::WorkerLost { worker: w as u64 });
                if Instant::now() >= deadline {
                    Err(timeout_err())
                } else {
                    Err(anyhow::Error::new(ShardError::WorkerLost { addr: link.addr.clone(), detail }))
                }
            }
        }
    }

    /// Aggregate `STATS` across the router and every live worker:
    /// coordinator counters sum via [`StatsSnapshot::merged`]-style
    /// field addition (each worker holds only its own I/O and block
    /// counters — see PR 5's single-process assumption), cache and
    /// registry gauges sum numerically.
    fn aggregate_stats(&self) -> (usize, usize, StatsSnapshot, HashMap<String, f64>) {
        let far = Instant::now() + self.cfg.io_timeout;
        let no_trace = Trace::disabled();
        let mut agg = StatsSnapshot::default();
        let mut gauges: HashMap<String, f64> = HashMap::new();
        let mut live = 0usize;
        for w in 0..self.workers.len() {
            if !self.workers[w].alive() {
                continue;
            }
            let Ok(map) = self.with_conn(w, far, &no_trace, |c| c.stats()) else { continue };
            live += 1;
            agg = agg.merged(&parse_stats_snapshot(&map));
            for key in ["cache_entries", "cache_bytes", "cache_capacity_bytes", "cache_disk_hits", "matrices"] {
                if let Some(v) = map.get(key).and_then(|v| v.parse::<f64>().ok()) {
                    *gauges.entry(key.to_string()).or_insert(0.0) += v;
                }
            }
        }
        (self.workers.len(), live, agg, gauges)
    }
}

/// Stitch a worker's span sheet into the router journal: re-id the
/// sheet with fresh router ids, hang its roots under `scatter_span`,
/// and re-base its request-relative times onto the router-side exchange
/// window `[exchange_start_us, now]` — the clock-skew anchoring rule
/// (worker clocks never reorder the stitched tree). No-op with tracing
/// off (`scatter_span == 0`) or against span-less workers.
fn stitch_worker_spans(
    trace: &Trace,
    scatter_span: u64,
    exchange_start_us: u64,
    worker: usize,
    sheet: &[SpanRecord],
) {
    if scatter_span == 0 || sheet.is_empty() {
        return;
    }
    let anchor = SpanRecord {
        id: scatter_span,
        parent: crate::trace::ROOT_SPAN,
        name: "exchange".to_string(),
        worker: worker as u64,
        start_us: exchange_start_us,
        dur_us: trace.now_us().saturating_sub(exchange_start_us),
    };
    let anchored =
        crate::trace::span::anchor_spans(sheet, &anchor, worker as u64, || trace.reserve_span());
    for s in anchored {
        trace.record_span(s.id, s.parent, &s.name, s.worker, s.start_us, s.dur_us);
    }
}

/// Rebuild the coordinator-counter part of a worker's `STATS` reply.
/// Keys a worker does not report stay zero.
fn parse_stats_snapshot(map: &std::collections::BTreeMap<String, String>) -> StatsSnapshot {
    let u = |k: &str| map.get(k).and_then(|v| v.parse::<u64>().ok()).unwrap_or(0);
    let f = |k: &str| map.get(k).and_then(|v| v.parse::<f64>().ok()).unwrap_or(0.0);
    let h = |k: &str| {
        map.get(k)
            .and_then(|v| crate::coordinator::stats::HistogramSnapshot::from_wire(v).ok())
            .unwrap_or_default()
    };
    StatsSnapshot {
        blocks_total: u("blocks_total"),
        blocks_native: u("blocks_native"),
        blocks_pjrt: u("blocks_pjrt"),
        pjrt_fallbacks: u("pjrt_fallbacks"),
        gather_s: f("gather_s"),
        exec_s: f("exec_s"),
        merge_s: f("merge_s"),
        cache_hits: u("cache_hits"),
        cache_misses: u("cache_misses"),
        store_chunks_read: u("store_chunks_read"),
        store_bytes_read: u("store_bytes_read"),
        store_bytes_decoded: u("store_bytes_decoded"),
        store_cache_hits: u("store_cache_hits"),
        prefetch_issued: u("prefetch_issued"),
        prefetch_hits: u("prefetch_hits"),
        prefetch_wasted_bytes: u("prefetch_wasted_bytes"),
        hist_gather: h("hist_gather"),
        hist_exec: h("hist_exec"),
        hist_merge: h("hist_merge"),
        hist_queue_wait: h("hist_queue_wait"),
    }
}

/// Merge every worker's advertised shard sets into per-matrix
/// topologies, rejecting disagreeing identities, overlapping bands and
/// gaps. Identical spans from several workers are replicas.
fn build_topology(advertised: &[Vec<ShardSetInfo>]) -> Result<HashMap<String, MatrixTopology>> {
    // name → (identity, span → owner list)
    let mut acc: HashMap<String, (ShardSetInfo, HashMap<(usize, usize), Vec<usize>>)> =
        HashMap::new();
    for (w, sets) in advertised.iter().enumerate() {
        for info in sets {
            let entry = acc
                .entry(info.name.clone())
                .or_insert_with(|| (info.clone(), HashMap::new()));
            let first = &entry.0;
            ensure!(
                first.rows == info.rows
                    && first.cols == info.cols
                    && first.fingerprint == info.fingerprint,
                "workers disagree on matrix '{}': {}x{} fp {:016x} vs {}x{} fp {:016x}",
                info.name,
                first.rows,
                first.cols,
                first.fingerprint,
                info.rows,
                info.cols,
                info.fingerprint
            );
            for &span in &info.bands {
                entry.1.entry(span).or_default().push(w);
            }
        }
    }
    let mut topo = HashMap::new();
    for (name, (id, span_owners)) in acc {
        let mut spans: Vec<(usize, usize)> = span_owners.keys().copied().collect();
        spans.sort_unstable();
        for pair in spans.windows(2) {
            ensure!(
                pair[0].1 <= pair[1].0,
                "overlapping shard bands {}-{} and {}-{} for matrix '{name}'",
                pair[0].0,
                pair[0].1,
                pair[1].0,
                pair[1].1
            );
        }
        let covered = spans.first().map(|s| s.0) == Some(0)
            && spans.last().map(|s| s.1) == Some(id.rows)
            && spans.windows(2).all(|p| p[0].1 == p[1].0);
        ensure!(
            covered,
            "shard bands of matrix '{name}' do not cover rows 0..{} contiguously",
            id.rows
        );
        let mut owners = Vec::with_capacity(spans.len());
        let mut bands = Vec::with_capacity(spans.len());
        for &(lo, hi) in &spans {
            let mut list = span_owners[&(lo, hi)].clone();
            list.sort_unstable();
            list.dedup();
            owners.push(list);
            bands.push(BandSpan { row_lo: lo, row_hi: hi });
        }
        topo.insert(
            name,
            MatrixTopology {
                rows: id.rows,
                cols: id.cols,
                nnz: id.nnz,
                sparse: id.sparse,
                fingerprint: id.fingerprint,
                bands,
                owners,
            },
        );
    }
    Ok(topo)
}

/// One routed job's lifecycle on the router front end.
struct RouteJob {
    state: JobState,
    result: Option<Arc<RoutedRun>>,
    error: Option<String>,
    /// Lifecycle event journal (`EVENTS` verb). Memory-only: the
    /// router has no `--store-root`, so nothing spills to disk.
    journal: Arc<Journal>,
}

struct RouterState {
    router: ShardRouter,
    jobs: Mutex<HashMap<u64, RouteJob>>,
    next_id: AtomicU64,
}

/// TCP front end for a [`ShardRouter`]: speaks the same line protocol
/// as a worker (`SUBMIT`/`STATUS`/`RESULT`/`RESULTB`/`STATS`/
/// `SHUTDOWN`), answers `ROUTE` with the topology summary, and rejects
/// worker-only verbs typed. Existing clients need no changes to talk
/// to a router instead of a single node.
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl ShardServer {
    pub fn spawn(addr: impl std::net::ToSocketAddrs, router: ShardRouter) -> Result<Self> {
        let state = Arc::new(RouterState {
            router,
            jobs: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
        });
        let handler: RequestHandler =
            Arc::new(move |req, _payload, conn| route_respond(&state, req, conn));
        let AcceptLoop { addr, stop, thread } = spawn_accept_loop(addr, handler)?;
        crate::log_info!("shard router listening on {addr}");
        Ok(Self { addr, stop, accept_thread: Some(thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (`SHUTDOWN` or
    /// [`ShardServer::shutdown`]).
    pub fn join(mut self) {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }

    pub fn shutdown(&self) {
        request_stop(&self.stop, self.addr);
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        request_stop(&self.stop, self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn route_respond(state: &Arc<RouterState>, req: Request, conn: &mut ConnState) -> Reply {
    match route_handle(state, req, conn) {
        Ok(reply) => reply,
        Err(e) => Reply::err(&e),
    }
}

fn finished_route_job(state: &RouterState, id: u64) -> Result<Arc<RoutedRun>> {
    let jobs = state.jobs.lock().unwrap();
    let job = jobs.get(&id).with_context(|| no_such_job(id))?;
    match job.state {
        JobState::Done => job.result.clone().context("done job missing result"),
        JobState::Failed => {
            bail!("job {id} failed: {}", job.error.as_deref().unwrap_or("unknown error"))
        }
        other => bail!("job {id} is still {}", other.as_str()),
    }
}

/// The binary result frame — answers `RESULTB` and, once the
/// connection negotiated the unified framing, plain `RESULT` too.
fn route_result_binary(state: &RouterState, id: u64) -> Result<Reply> {
    let run = finished_route_job(state, id)?;
    let payload = protocol::encode_labels_binary(&run.row_labels, &run.col_labels)?;
    Ok(Reply::Binary {
        header: format!(
            "OK id={id} k={} rows={} cols={} cached=false\n",
            run.k,
            run.row_labels.len(),
            run.col_labels.len(),
        ),
        payload,
    })
}

/// The binary events frame — answers `EVENTSB` and, once the
/// connection negotiated the unified framing, plain `EVENTS` too.
fn route_events_binary(state: &RouterState, id: u64, after: Option<u64>) -> Result<Reply> {
    let records = route_job_events(state, id, after)?;
    let payload = protocol::encode_events_binary(&records);
    let mut header = events_header(id, &records);
    header.insert_str(header.len() - 1, &format!(" bytes={}", payload.len() - 8));
    Ok(Reply::Binary { header, payload })
}

fn route_handle(state: &Arc<RouterState>, req: Request, conn: &mut ConnState) -> Result<Reply> {
    match req {
        Request::Submit(spec) => {
            // Fail fast on specs the router can never run, so the error
            // reaches the submitter instead of a job record.
            ensure!(
                spec.partitioned()?,
                "whole-matrix baseline method '{}' cannot be routed across shards",
                spec.method
            );
            ensure!(
                state.router.topo.contains_key(&spec.matrix),
                "no shard topology for matrix '{}'",
                spec.matrix
            );
            let id = state.next_id.fetch_add(1, Ordering::Relaxed);
            let journal = Arc::new(Journal::new(DEFAULT_RING_CAPACITY));
            state.jobs.lock().unwrap().insert(
                id,
                RouteJob {
                    state: JobState::Running,
                    result: None,
                    error: None,
                    journal: Arc::clone(&journal),
                },
            );
            journal.emit(Event::JobQueued);
            let worker_state = Arc::clone(state);
            std::thread::Builder::new()
                .name("lamc-route-job".into())
                .spawn(move || {
                    let _scope = crate::logging::job_scope(id);
                    journal.emit(Event::JobStarted);
                    let trace = Trace::to_journal(Arc::clone(&journal));
                    // Root of the routed job's span tree: the journal
                    // epoch is submit time, so "now" is the queue wait.
                    let queue_us = trace.now_us();
                    let job_span = trace.reserve_span();
                    trace.record_span(trace.reserve_span(), job_span, "queue", 0, 0, queue_us);
                    let outcome =
                        worker_state.router.run_spec_traced(&spec, &trace.child_of(job_span));
                    // The job span covers submit → terminal state so
                    // every child (queue, rounds, scatters) nests in it.
                    trace.record_span(job_span, crate::trace::ROOT_SPAN, "job", 0, 0, trace.now_us());
                    let mut jobs = worker_state.jobs.lock().unwrap();
                    let Some(job) = jobs.get_mut(&id) else { return };
                    match outcome {
                        Ok(run) => {
                            job.state = JobState::Done;
                            job.result = Some(Arc::new(run));
                            journal.emit(Event::JobDone);
                        }
                        Err(e) => {
                            let error = format!("{e:#}");
                            job.state = JobState::Failed;
                            job.error = Some(error.clone());
                            journal.emit(Event::JobFailed { error });
                        }
                    }
                })
                .context("spawn route job thread")?;
            Ok(Reply::Text(format!("OK id={id}\n")))
        }
        Request::Status { id } => {
            let jobs = state.jobs.lock().unwrap();
            let job = jobs.get(&id).with_context(|| no_such_job(id))?;
            let mut line = format!("OK id={id} state={} cached=false", job.state.as_str());
            if let Some(e) = &job.error {
                line.push_str(&format!(" error={}", e.replace([' ', '\n'], "_")));
            }
            line.push('\n');
            Ok(Reply::Text(line))
        }
        Request::Result { id } => {
            if conn.binary {
                return route_result_binary(state, id);
            }
            let run = finished_route_job(state, id)?;
            Ok(Reply::Text(format!(
                "OK id={id} k={} rows={} cols={} cached=false\nROWS {}\nCOLS {}\nEND\n",
                run.k,
                run.row_labels.len(),
                run.col_labels.len(),
                protocol::encode_labels(&run.row_labels),
                protocol::encode_labels(&run.col_labels),
            )))
        }
        // Compat shim (one release behind the unified framing).
        Request::ResultBinary { id } => route_result_binary(state, id),
        Request::Stats => {
            let (queued, running, done, failed) = {
                let jobs = state.jobs.lock().unwrap();
                let count = |s: JobState| jobs.values().filter(|j| j.state == s).count();
                (count(JobState::Queued), count(JobState::Running), count(JobState::Done), count(JobState::Failed))
            };
            let (total, live, snap, gauges) = state.router.aggregate_stats();
            let gauge = |k: &str| gauges.get(k).copied().unwrap_or(0.0) as u64;
            Ok(Reply::Text(format!(
                "OK jobs_queued={queued} jobs_running={running} jobs_done={done} jobs_failed={failed} \
                 cache_hits={} cache_misses={} cache_entries={} cache_bytes={} cache_capacity_bytes={} \
                 cache_disk_hits={} blocks_total={} blocks_native={} blocks_pjrt={} matrices={} \
                 store_chunks_read={} store_bytes_read={} store_bytes_decoded={} store_cache_hits={} \
                 prefetch_issued={} prefetch_hits={} prefetch_wasted_bytes={} \
                 gather_s={:.6} exec_s={:.6} merge_s={:.6} \
                 hist_gather={} hist_exec={} hist_merge={} hist_queue_wait={} \
                 workers={total} workers_live={live}\n",
                snap.cache_hits,
                snap.cache_misses,
                gauge("cache_entries"),
                gauge("cache_bytes"),
                gauge("cache_capacity_bytes"),
                gauge("cache_disk_hits"),
                snap.blocks_total,
                snap.blocks_native,
                snap.blocks_pjrt,
                state.router.topo.len(),
                snap.store_chunks_read,
                snap.store_bytes_read,
                snap.store_bytes_decoded,
                snap.store_cache_hits,
                snap.prefetch_issued,
                snap.prefetch_hits,
                snap.prefetch_wasted_bytes,
                snap.gather_s,
                snap.exec_s,
                snap.merge_s,
                snap.hist_gather.to_wire(),
                snap.hist_exec.to_wire(),
                snap.hist_merge.to_wire(),
                snap.hist_queue_wait.to_wire(),
            )))
        }
        Request::Route => {
            let bands: usize = state.router.topo.values().map(|t| t.bands.len()).sum();
            let live = state.router.worker_health().iter().filter(|(_, a)| *a).count();
            Ok(Reply::Text(format!(
                "OK workers={} live={live} matrices={} bands={bands}\n",
                state.router.workers.len(),
                state.router.topo.len(),
            )))
        }
        Request::Hello { proto, version: _, framing } => {
            ensure!(
                proto == PROTO_VERSION,
                "protocol version mismatch: peer speaks proto {proto}, this node speaks proto {PROTO_VERSION}"
            );
            conn.binary = framing.as_deref() == Some("binary");
            let ack = match &framing {
                Some(f) => format!(" framing={f}"),
                None => String::new(),
            };
            Ok(Reply::Text(format!(
                "OK proto={PROTO_VERSION} version={}{ack}\n",
                env!("CARGO_PKG_VERSION")
            )))
        }
        Request::Shards => {
            // The router's aggregate view: every band, owner-agnostic.
            let mut names: Vec<&String> = state.router.topo.keys().collect();
            names.sort();
            let mut out = format!("OK sets={}\n", names.len());
            for name in names {
                let t = &state.router.topo[name];
                let info = ShardSetInfo {
                    name: name.clone(),
                    rows: t.rows,
                    cols: t.cols,
                    nnz: t.nnz,
                    sparse: t.sparse,
                    fingerprint: t.fingerprint,
                    bands: t.bands.iter().map(|b| (b.row_lo, b.row_hi)).collect(),
                };
                out.push_str(&protocol::encode_shard_set(&info)?);
                out.push('\n');
            }
            out.push_str("END\n");
            Ok(Reply::Text(out))
        }
        Request::Load { .. } => {
            bail!("LOAD is answered by a worker node; register shards with `lamc serve --shards`")
        }
        Request::GatherBinary { .. } | Request::ExecBinary { .. } => {
            bail!("GATHERB/EXECB are answered by a worker node; this is a shard router")
        }
        Request::Events { id, after } => {
            if conn.binary {
                return route_events_binary(state, id, after);
            }
            let records = route_job_events(state, id, after)?;
            let mut out = events_header(id, &records);
            for rec in &records {
                out.push_str("EVENT ");
                out.push_str(&rec.to_wire());
                out.push('\n');
            }
            out.push_str("END\n");
            Ok(Reply::Text(out))
        }
        // Compat shim (one release behind the unified framing).
        Request::EventsBinary { id, after } => route_events_binary(state, id, after),
        Request::Spans { id } => {
            // The stitched tree: router-side job/round/scatter spans
            // plus every worker sheet anchored at its exchange.
            let journal = {
                let jobs = state.jobs.lock().unwrap();
                Arc::clone(&jobs.get(&id).with_context(|| no_such_job(id))?.journal)
            };
            let spans = journal.spans();
            if conn.binary {
                let payload = protocol::encode_spans_binary(&spans);
                let header =
                    format!("OK id={id} count={} bytes={}\n", spans.len(), payload.len() - 8);
                return Ok(Reply::Binary { header, payload });
            }
            let mut out = format!("OK id={id} count={}\n", spans.len());
            for s in &spans {
                out.push_str("SPAN ");
                out.push_str(&s.to_wire());
                out.push('\n');
            }
            out.push_str("END\n");
            Ok(Reply::Text(out))
        }
        Request::Metrics => {
            let (body, lines) = router_metrics(state).finish();
            Ok(Reply::Text(format!("OK lines={lines}\n{body}END\n")))
        }
        Request::Append { .. } | Request::Subscribe { .. } => {
            bail!("APPEND/SUBSCRIBE are answered by a worker node hosting the store; this is a shard router")
        }
        Request::Shutdown => Ok(Reply::Text("OK shutting-down\n".to_string())),
    }
}

fn route_job_events(
    state: &RouterState,
    id: u64,
    after: Option<u64>,
) -> Result<Vec<crate::trace::EventRecord>> {
    let journal = {
        let jobs = state.jobs.lock().unwrap();
        Arc::clone(&jobs.get(&id).with_context(|| no_such_job(id))?.journal)
    };
    Ok(journal.events_after(after, EVENTS_PAGE_MAX))
}

/// Render the router's fleet-wide counters — the same aggregation the
/// `STATS` verb reports — as Prometheus-style text exposition.
fn router_metrics(state: &RouterState) -> protocol::MetricsText {
    let (queued, running, done, failed) = {
        let jobs = state.jobs.lock().unwrap();
        let count = |s: JobState| jobs.values().filter(|j| j.state == s).count();
        (count(JobState::Queued), count(JobState::Running), count(JobState::Done), count(JobState::Failed))
    };
    let (total, live, snap, gauges) = state.router.aggregate_stats();
    let gauge = |k: &str| gauges.get(k).copied().unwrap_or(0.0) as u64;
    let mut m = protocol::MetricsText::new();
    m.declare("lamc_jobs", "gauge", "Routed jobs on this router, by lifecycle state.")
        .sample("lamc_jobs{state=\"queued\"}", queued)
        .sample("lamc_jobs{state=\"running\"}", running)
        .sample("lamc_jobs{state=\"done\"}", done)
        .sample("lamc_jobs{state=\"failed\"}", failed)
        .gauge("lamc_workers", total, "Worker nodes this router connected to.")
        .gauge("lamc_workers_live", live, "Worker nodes currently believed alive.")
        .gauge("lamc_matrices", state.router.topo.len(), "Sharded matrices in the merged topology.")
        .counter("lamc_cache_hits_total", snap.cache_hits, "Result-cache hits across the fleet.")
        .counter("lamc_cache_misses_total", snap.cache_misses, "Result-cache misses across the fleet.")
        .counter(
            "lamc_cache_disk_hits_total",
            gauge("cache_disk_hits"),
            "Result-cache hits served from the disk tier across the fleet.",
        )
        .gauge("lamc_cache_entries", gauge("cache_entries"), "Resident result-cache entries across the fleet.")
        .gauge("lamc_cache_bytes", gauge("cache_bytes"), "Resident result-cache bytes across the fleet.")
        .counter("lamc_blocks_total", snap.blocks_total, "Block jobs executed across the fleet.")
        .counter("lamc_blocks_native_total", snap.blocks_native, "Block jobs run on the native backend.")
        .counter("lamc_blocks_pjrt_total", snap.blocks_pjrt, "Block jobs run on the PJRT backend.")
        .counter("lamc_store_chunks_read_total", snap.store_chunks_read, "Store chunks read across the fleet.")
        .counter("lamc_store_bytes_read_total", snap.store_bytes_read, "Store bytes read across the fleet.")
        .counter(
            "lamc_store_bytes_decoded_total",
            snap.store_bytes_decoded,
            "Uncompressed bytes decoded from store chunks across the fleet.",
        )
        .counter(
            "lamc_store_cache_hits_total",
            snap.store_cache_hits,
            "Chunk reads served by worker chunk caches.",
        )
        .counter("lamc_prefetch_issued_total", snap.prefetch_issued, "Chunk prefetches issued across the fleet.")
        .counter(
            "lamc_prefetch_hits_total",
            snap.prefetch_hits,
            "Chunk reads answered by a prefetched chunk across the fleet.",
        )
        .counter(
            "lamc_prefetch_wasted_bytes_total",
            snap.prefetch_wasted_bytes,
            "Prefetched bytes evicted unread across the fleet.",
        )
        .counter("lamc_gather_seconds_total", format!("{:.6}", snap.gather_s), "Seconds spent gathering blocks.")
        .counter("lamc_exec_seconds_total", format!("{:.6}", snap.exec_s), "Seconds spent co-clustering blocks.")
        .counter("lamc_merge_seconds_total", format!("{:.6}", snap.merge_s), "Seconds spent merging atom sets.")
        // Bucket-wise aggregation across workers: each worker ships its
        // raw bucket counts over `STATS` and the router sums them
        // (`HistogramSnapshot::merged`), so fleet `_bucket` counts are
        // exact, not re-binned.
        .declare(
            "lamc_round_seconds",
            "histogram",
            "Phase latency distribution aggregated across workers, by phase.",
        )
        .histogram_series("lamc_round_seconds", "phase=\"gather\"", &snap.hist_gather)
        .histogram_series("lamc_round_seconds", "phase=\"exec\"", &snap.hist_exec)
        .histogram_series("lamc_round_seconds", "phase=\"merge\"", &snap.hist_merge)
        .declare(
            "lamc_queue_wait_seconds",
            "histogram",
            "Seconds jobs waited in worker queues before a runner picked them up.",
        )
        .histogram_series("lamc_queue_wait_seconds", "", &snap.hist_queue_wait);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;

    fn set(name: &str, rows: usize, bands: &[(usize, usize)]) -> ShardSetInfo {
        ShardSetInfo {
            name: name.to_string(),
            rows,
            cols: 10,
            nnz: 0,
            sparse: false,
            fingerprint: 0xabc,
            bands: bands.to_vec(),
        }
    }

    #[test]
    fn topology_merges_replicas_and_rejects_bad_layouts() {
        // Two workers: disjoint bands plus one replicated band.
        let topo = build_topology(&[
            vec![set("m", 30, &[(0, 10), (10, 20)])],
            vec![set("m", 30, &[(10, 20), (20, 30)])],
        ])
        .unwrap();
        let t = &topo["m"];
        assert_eq!(t.bands.len(), 3);
        assert_eq!(t.owners[0], vec![0]);
        assert_eq!(t.owners[1], vec![0, 1], "replicated band has both owners");
        assert_eq!(t.owners[2], vec![1]);

        // Overlapping-but-different spans are rejected.
        let err = build_topology(&[
            vec![set("m", 30, &[(0, 15)])],
            vec![set("m", 30, &[(10, 30)])],
        ])
        .unwrap_err();
        assert!(err.to_string().contains("overlapping shard bands"), "{err}");

        // A gap is rejected.
        let err = build_topology(&[
            vec![set("m", 30, &[(0, 10)])],
            vec![set("m", 30, &[(20, 30)])],
        ])
        .unwrap_err();
        assert!(err.to_string().contains("do not cover rows"), "{err}");

        // Fingerprint disagreement is rejected.
        let mut other = set("m", 30, &[(10, 30)]);
        other.fingerprint = 0xdef;
        let err = build_topology(&[vec![set("m", 30, &[(0, 10)])], vec![other]]).unwrap_err();
        assert!(err.to_string().contains("disagree on matrix"), "{err}");
    }

    #[test]
    fn connect_rejects_version_mismatch() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let fake = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("HELLO"), "router leads with HELLO, got '{line}'");
            let mut w = stream;
            w.write_all(b"OK proto=1 version=0.0.0-fake\n").unwrap();
            w.flush().unwrap();
        });
        let err = ShardRouter::connect(&[addr.to_string()], ShardRouterConfig::default())
            .unwrap_err();
        let err = format!("{err:#}");
        assert!(err.contains("shard worker version mismatch"), "{err}");
        assert!(err.contains("0.0.0-fake"), "{err}");
        fake.join().unwrap();
    }

    #[test]
    fn shard_error_display_is_tagged() {
        let cases: Vec<(ShardError, &str)> = vec![
            (
                ShardError::WorkerLost { addr: "h:1".into(), detail: "broken pipe".into() },
                "shard worker lost",
            ),
            (
                ShardError::BandLost { name: "m".into(), row_lo: 0, row_hi: 10 },
                "shard band lost",
            ),
            (ShardError::JobTimeout { budget_s: 5 }, "shard job timeout"),
            (
                ShardError::VersionMismatch { addr: "h:1".into(), got: "a".into(), want: "b".into() },
                "shard worker version mismatch",
            ),
        ];
        for (err, tag) in cases {
            let text = anyhow::Error::new(err).to_string();
            assert!(text.contains(tag), "'{text}' missing '{tag}'");
        }
    }
}
