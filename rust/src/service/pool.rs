//! Persistent worker pool: long-lived threads fed by a job channel.
//!
//! Extracted from the per-call `thread::scope` workers the scheduler used
//! to spawn (paper §IV-C leader/worker structure): thread startup is now
//! amortized across requests, which matters once the pipeline runs as a
//! long-lived service handling many small co-clustering jobs instead of
//! one batch call.
//!
//! Two layers of API:
//!
//! * [`WorkerPool::submit`] — fire-and-forget `'static` tasks (the job
//!   channel proper).
//! * [`WorkerPool::run_jobs`] — a scoped fork/join: call a borrowed
//!   closure once per job index from up to `concurrency` claim loops.
//!   The **calling thread always participates** as one of the loops, so
//!   progress is guaranteed even when every pool thread is busy (and
//!   nested `run_jobs` calls cannot deadlock). The call blocks until all
//!   job indices have been processed, which is what makes lending
//!   non-`'static` borrows to pool threads sound.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Shared injector queue the workers pull from.
struct Injector {
    queue: Mutex<InjectorState>,
    ready: Condvar,
}

struct InjectorState {
    tasks: VecDeque<Task>,
    closed: bool,
}

impl Injector {
    fn push(&self, task: Task) {
        let mut st = self.queue.lock().unwrap();
        if st.closed {
            return; // pool shutting down: drop the task
        }
        st.tasks.push_back(task);
        drop(st);
        self.ready.notify_one();
    }

    /// Block until a task is available or the pool closes.
    fn pop(&self) -> Option<Task> {
        let mut st = self.queue.lock().unwrap();
        loop {
            if let Some(t) = st.tasks.pop_front() {
                return Some(t);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.queue.lock().unwrap();
        st.closed = true;
        st.tasks.clear();
        drop(st);
        self.ready.notify_all();
    }
}

/// A pool of long-lived worker threads.
///
/// Dropping the pool closes the job channel and joins every worker.
/// The process-wide pool behind [`WorkerPool::global`] is never dropped.
pub struct WorkerPool {
    injector: Arc<Injector>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn a pool with `workers` threads (0 = available parallelism).
    pub fn new(workers: usize) -> Self {
        let workers = if workers > 0 {
            workers
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        };
        let injector = Arc::new(Injector {
            queue: Mutex::new(InjectorState { tasks: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let injector = Arc::clone(&injector);
            let handle = std::thread::Builder::new()
                .name(format!("lamc-worker-{i}"))
                .spawn(move || {
                    while let Some(task) = injector.pop() {
                        // A panicking task must not take the worker down:
                        // the pool outlives any single request.
                        let _ = catch_unwind(AssertUnwindSafe(task));
                    }
                })
                .expect("spawn pool worker");
            handles.push(handle);
        }
        Self { injector, handles: Mutex::new(handles), workers }
    }

    /// The process-wide pool (sized to available parallelism), created on
    /// first use and alive for the rest of the process. This is what
    /// `coordinator::run_rounds` executes on.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| WorkerPool::new(0))
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue a fire-and-forget task.
    pub fn submit(&self, task: Task) {
        self.injector.push(task);
    }

    /// Run `f(idx)` for every `idx in 0..jobs`, spread over up to
    /// `concurrency` claim loops (the calling thread plus up to
    /// `concurrency - 1` pool threads). Blocks until every index has been
    /// processed. Panics from `f` are re-raised on the calling thread
    /// after all jobs finish.
    pub fn run_jobs<F>(&self, concurrency: usize, jobs: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if jobs == 0 {
            return;
        }
        // Lifetime erasure: the closure is lent to pool threads as a raw
        // pointer. Soundness argument at the dereference in `claim_loop`:
        // a successful claim implies this function is still blocked in
        // the wait loop below, so the pointee is alive. Helper tasks that
        // start after all jobs are done observe an exhausted counter and
        // exit without ever dereferencing.
        let f_ref: &(dyn Fn(usize) + Send + Sync) = &f;
        let ctx = Arc::new(ScopeCtx {
            f: f_ref as *const (dyn Fn(usize) + Send + Sync),
            next: AtomicUsize::new(0),
            jobs,
            state: Mutex::new(ScopeState { done: 0, panicked: false }),
            finished: Condvar::new(),
        });

        let helpers = concurrency.saturating_sub(1).min(jobs.saturating_sub(1));
        for _ in 0..helpers {
            let ctx = Arc::clone(&ctx);
            self.submit(Box::new(move || claim_loop(&ctx)));
        }
        // The caller is always one of the claim loops.
        claim_loop(&ctx);

        let mut st = ctx.state.lock().unwrap();
        while st.done < jobs {
            st = ctx.finished.wait(st).unwrap();
        }
        let panicked = st.panicked;
        drop(st);
        if panicked {
            panic!("worker pool job panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.injector.close();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

struct ScopeCtx {
    f: *const (dyn Fn(usize) + Send + Sync),
    next: AtomicUsize,
    jobs: usize,
    state: Mutex<ScopeState>,
    finished: Condvar,
}

// SAFETY: the raw closure pointer is only dereferenced under the claim
// protocol documented in `claim_loop`; the pointee is `Sync`, so shared
// calls from several threads are fine.
unsafe impl Send for ScopeCtx {}
unsafe impl Sync for ScopeCtx {}

struct ScopeState {
    done: usize,
    panicked: bool,
}

/// Claim job indices until the counter is exhausted. Every claimed index
/// is marked done even if `f` panics, so the scope's completion latch
/// always releases.
fn claim_loop(ctx: &ScopeCtx) {
    loop {
        let idx = ctx.next.fetch_add(1, Ordering::Relaxed);
        if idx >= ctx.jobs {
            return;
        }
        // SAFETY: `idx < jobs` means this index has not been marked done,
        // so `run_jobs` is still blocked in its wait loop and the borrowed
        // closure behind `ctx.f` is alive.
        let f = unsafe { &*ctx.f };
        let outcome = catch_unwind(AssertUnwindSafe(|| f(idx)));
        let mut st = ctx.state.lock().unwrap();
        st.done += 1;
        if outcome.is_err() {
            st.panicked = true;
        }
        if st.done == ctx.jobs {
            drop(st);
            ctx.finished.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_job_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.run_jobs(4, 100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn caller_participates_with_zero_concurrency() {
        let pool = WorkerPool::new(1);
        let count = AtomicU64::new(0);
        // concurrency 0/1 still completes: the caller is a claim loop.
        pool.run_jobs(0, 10, |_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn nested_run_jobs_does_not_deadlock() {
        let pool = WorkerPool::new(2);
        let count = Arc::new(AtomicU64::new(0));
        let inner_count = Arc::clone(&count);
        pool.run_jobs(2, 4, move |_| {
            // Nested scope on the same (possibly saturated) pool.
            WorkerPool::global().run_jobs(2, 3, |_| {
                inner_count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn borrowed_state_is_visible_after_return() {
        let pool = WorkerPool::new(3);
        let out = Mutex::new(vec![0usize; 50]);
        pool.run_jobs(3, 50, |i| {
            out.lock().unwrap()[i] = i * i;
        });
        let out = out.into_inner().unwrap();
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * i));
    }

    #[test]
    fn panicking_job_propagates_after_completion() {
        let pool = WorkerPool::new(2);
        let count = Arc::new(AtomicU64::new(0));
        let seen = Arc::clone(&count);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_jobs(2, 8, |i| {
                if i == 3 {
                    panic!("boom");
                }
                seen.fetch_add(1, Ordering::SeqCst);
            });
        }));
        assert!(result.is_err());
        // All non-panicking jobs still ran: the latch waits for all 8.
        assert_eq!(count.load(Ordering::SeqCst), 7);
        // The pool survives the panic and keeps serving.
        let ok = AtomicU64::new(0);
        pool.run_jobs(2, 5, |_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = WorkerPool::global();
        let b = WorkerPool::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.workers() >= 1);
    }
}
