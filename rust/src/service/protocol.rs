//! Dependency-free TCP line protocol for the co-clustering service.
//!
//! Framing: every request is one `\n`-terminated line — a verb followed
//! by space-separated `key=value` pairs. Every response starts with a
//! line beginning `OK` or `ERR <message>`; the `RESULT` verb's success
//! response additionally carries the two label vectors and a terminator:
//!
//! ```text
//! → SUBMIT matrix=planted k=3 seed=7 method=lamc-scc
//! ← OK id=1
//! → STATUS id=1
//! ← OK id=1 state=done cached=false
//! → RESULT id=1
//! ← OK id=1 k=3 rows=96 cols=80 cached=false
//! ← ROWS 0,1,2,0,…
//! ← COLS 1,0,2,1,…
//! ← END
//! → STATS
//! ← OK jobs_done=1 cache_hits=0 cache_misses=1 …
//! → SHUTDOWN
//! ← OK shutting-down
//! ```
//!
//! Values must not contain spaces or newlines (names are identifiers,
//! numbers are numbers); `LOAD` paths are the one field where this
//! bites, and the parser rejects offending requests rather than
//! truncating them. See `docs/SERVICE.md` for the full contract.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::manager::JobSpec;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Submit(JobSpec),
    Status { id: u64 },
    Result { id: u64 },
    /// Binary result framing (`RESULTB`): the success response is one
    /// `OK` header line followed by a length-prefixed binary block (see
    /// [`encode_labels_binary`]) instead of `ROWS`/`COLS` text lines —
    /// RCV1-scale label vectors ship in 4 bytes per label with no line
    /// length ceiling. Clients auto-negotiate: an old server answers
    /// `ERR unknown verb…` and the client falls back to `RESULT`.
    ResultBinary { id: u64 },
    Stats,
    /// Load a matrix into the registry: from a named dataset spec, a
    /// matrix file path, or a LAMC2/LAMC3 store (kept disk-resident). Exactly
    /// one of `dataset`/`path`/`store` must be given.
    Load {
        name: String,
        dataset: Option<String>,
        path: Option<String>,
        store: Option<String>,
        rows: Option<usize>,
        seed: u64,
    },
    Shutdown,
}

/// Split `k=v` tokens into a map, rejecting malformed tokens.
pub fn kv_pairs(tokens: &[&str]) -> Result<BTreeMap<String, String>> {
    let mut map = BTreeMap::new();
    for t in tokens {
        let (k, v) = t
            .split_once('=')
            .with_context(|| format!("expected key=value, got '{t}'"))?;
        if k.is_empty() || v.is_empty() {
            bail!("empty key or value in '{t}'");
        }
        map.insert(k.to_string(), v.to_string());
    }
    Ok(map)
}

fn get_u64(map: &BTreeMap<String, String>, key: &str) -> Result<Option<u64>> {
    map.get(key)
        .map(|v| v.parse::<u64>().with_context(|| format!("{key}={v} is not an integer")))
        .transpose()
}

fn get_usize(map: &BTreeMap<String, String>, key: &str) -> Result<Option<usize>> {
    map.get(key)
        .map(|v| v.parse::<usize>().with_context(|| format!("{key}={v} is not an integer")))
        .transpose()
}

fn get_f64(map: &BTreeMap<String, String>, key: &str) -> Result<Option<f64>> {
    map.get(key)
        .map(|v| v.parse::<f64>().with_context(|| format!("{key}={v} is not a float")))
        .transpose()
}

fn require_id(map: &BTreeMap<String, String>) -> Result<u64> {
    get_u64(map, "id")?.context("missing id=")
}

fn check_known(map: &BTreeMap<String, String>, known: &[&str]) -> Result<()> {
    for k in map.keys() {
        if !known.contains(&k.as_str()) {
            bail!("unknown field '{k}' (known: {})", known.join(", "));
        }
    }
    Ok(())
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let line = line.trim();
    let mut tokens = line.split_whitespace();
    let verb = tokens.next().context("empty request")?;
    let rest: Vec<&str> = tokens.collect();
    match verb {
        "SUBMIT" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["matrix", "method", "k", "seed", "p-thresh", "tau", "workers"])?;
            let defaults = JobSpec::default();
            let spec = JobSpec {
                matrix: map.get("matrix").context("missing matrix=")?.clone(),
                method: map.get("method").cloned().unwrap_or(defaults.method),
                k: get_usize(&map, "k")?.unwrap_or(defaults.k),
                seed: get_u64(&map, "seed")?.unwrap_or(defaults.seed),
                p_thresh: get_f64(&map, "p-thresh")?.unwrap_or(defaults.p_thresh),
                tau: get_f64(&map, "tau")?.unwrap_or(defaults.tau),
                workers: get_usize(&map, "workers")?.unwrap_or(defaults.workers),
            };
            Ok(Request::Submit(spec))
        }
        "STATUS" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::Status { id: require_id(&map)? })
        }
        "RESULT" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::Result { id: require_id(&map)? })
        }
        "RESULTB" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["id"])?;
            Ok(Request::ResultBinary { id: require_id(&map)? })
        }
        "STATS" => {
            if !rest.is_empty() {
                bail!("STATS takes no fields");
            }
            Ok(Request::Stats)
        }
        "LOAD" => {
            let map = kv_pairs(&rest)?;
            check_known(&map, &["name", "dataset", "path", "store", "rows", "seed"])?;
            let name = map.get("name").context("missing name=")?.clone();
            let dataset = map.get("dataset").cloned();
            let path = map.get("path").cloned();
            let store = map.get("store").cloned();
            let sources = [dataset.is_some(), path.is_some(), store.is_some()];
            if sources.iter().filter(|&&s| s).count() != 1 {
                bail!("LOAD needs exactly one of dataset=, path= or store=");
            }
            Ok(Request::Load {
                name,
                dataset,
                path,
                store,
                rows: get_usize(&map, "rows")?,
                seed: get_u64(&map, "seed")?.unwrap_or(42),
            })
        }
        "SHUTDOWN" => {
            if !rest.is_empty() {
                bail!("SHUTDOWN takes no fields");
            }
            Ok(Request::Shutdown)
        }
        other => bail!("unknown verb '{other}' (want SUBMIT|STATUS|RESULT|RESULTB|STATS|LOAD|SHUTDOWN)"),
    }
}

/// Validate a string destined for a `key=value` field: whitespace would
/// split the token and a newline would split the *frame* (injecting a
/// second request — e.g. a smuggled `SHUTDOWN` — and desyncing every
/// later reply on the connection), so both are rejected at encode time.
pub fn ensure_token(field: &str, value: &str) -> Result<()> {
    if value.is_empty() {
        bail!("{field} must not be empty");
    }
    if value.chars().any(|c| c.is_whitespace() || c.is_control()) {
        bail!("{field} must not contain whitespace or control characters: {value:?}");
    }
    Ok(())
}

/// Encode a SUBMIT line for a spec (the client side of `parse_request`).
/// Errors if a field would break the line framing.
pub fn encode_submit(spec: &JobSpec) -> Result<String> {
    ensure_token("matrix", &spec.matrix)?;
    ensure_token("method", &spec.method)?;
    Ok(format!(
        "SUBMIT matrix={} method={} k={} seed={} p-thresh={} tau={} workers={}",
        spec.matrix, spec.method, spec.k, spec.seed, spec.p_thresh, spec.tau, spec.workers
    ))
}

/// Encode a label vector as the payload of a `ROWS`/`COLS` line.
pub fn encode_labels(labels: &[usize]) -> String {
    let mut out = String::with_capacity(labels.len() * 2);
    for (i, l) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&l.to_string());
    }
    out
}

/// Encode both label vectors as the binary `RESULTB` payload:
/// `u32` LE per label (row labels then column labels), then a trailing
/// `u64` LE checksum over the label bytes. The header line's `rows=` /
/// `cols=` counts are the length prefix, so there is no terminator and
/// no line-length ceiling — a 10M-row labelling is 40 MB of payload
/// instead of an unbounded comma-separated text line.
pub fn encode_labels_binary(row_labels: &[usize], col_labels: &[usize]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity((row_labels.len() + col_labels.len()) * 4 + 8);
    for &l in row_labels.iter().chain(col_labels) {
        let l32 = u32::try_from(l).map_err(|_| anyhow::anyhow!("label {l} exceeds u32 range"))?;
        out.extend_from_slice(&l32.to_le_bytes());
    }
    let ck = crate::store::checksum_bytes(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    Ok(out)
}

/// Decode a `RESULTB` payload (`rows`/`cols` from the header line).
pub fn decode_labels_binary(bytes: &[u8], rows: usize, cols: usize) -> Result<(Vec<usize>, Vec<usize>)> {
    let want = (rows + cols) * 4 + 8;
    if bytes.len() != want {
        bail!("binary result payload has {} bytes, want {want}", bytes.len());
    }
    let (labels, ck) = bytes.split_at(bytes.len() - 8);
    if crate::store::checksum_bytes(labels) != u64::from_le_bytes(ck.try_into().unwrap()) {
        bail!("binary result payload failed its checksum");
    }
    let decode = |range: std::ops::Range<usize>| -> Vec<usize> {
        labels[range.start * 4..range.end * 4]
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize)
            .collect()
    };
    Ok((decode(0..rows), decode(rows..rows + cols)))
}

/// Decode a `ROWS`/`COLS` payload back into labels.
pub fn decode_labels(s: &str) -> Result<Vec<usize>> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|t| t.parse::<usize>().with_context(|| format!("bad label '{t}'")))
        .collect()
}

/// First line of an error response.
pub fn err_line(msg: &str) -> String {
    // Newlines would break framing; flatten them.
    format!("ERR {}", msg.replace('\n', "; "))
}

/// Split a response line into (ok, rest). `Err` if it is an ERR line.
pub fn check_ok(line: &str) -> Result<&str> {
    let line = line.trim_end();
    if let Some(rest) = line.strip_prefix("OK") {
        return Ok(rest.trim_start());
    }
    if let Some(msg) = line.strip_prefix("ERR") {
        bail!("server error: {}", msg.trim_start());
    }
    bail!("malformed response line: '{line}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trip() {
        let spec = JobSpec {
            matrix: "planted".into(),
            method: "lamc-pnmtf".into(),
            k: 5,
            seed: 99,
            p_thresh: 0.9,
            tau: 0.4,
            workers: 3,
        };
        let line = encode_submit(&spec).unwrap();
        match parse_request(&line).unwrap() {
            Request::Submit(parsed) => assert_eq!(parsed, spec),
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn submit_defaults_apply() {
        match parse_request("SUBMIT matrix=m").unwrap() {
            Request::Submit(s) => {
                assert_eq!(s.method, "lamc-scc");
                assert_eq!(s.k, 4);
                assert_eq!(s.seed, 42);
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn simple_verbs() {
        assert_eq!(parse_request("STATUS id=7").unwrap(), Request::Status { id: 7 });
        assert_eq!(parse_request("RESULT id=1").unwrap(), Request::Result { id: 1 });
        assert_eq!(parse_request("RESULTB id=2").unwrap(), Request::ResultBinary { id: 2 });
        assert_eq!(parse_request("STATS").unwrap(), Request::Stats);
        assert_eq!(parse_request("SHUTDOWN\n").unwrap(), Request::Shutdown);
    }

    #[test]
    fn load_requires_exactly_one_source() {
        assert!(parse_request("LOAD name=x dataset=amazon1000").is_ok());
        assert!(parse_request("LOAD name=x path=/tmp/m.lamc rows=100").is_ok());
        assert!(parse_request("LOAD name=x store=/tmp/m.lamc2").is_ok());
        assert!(parse_request("LOAD name=x").is_err());
        assert!(parse_request("LOAD name=x dataset=a path=b").is_err());
        assert!(parse_request("LOAD name=x dataset=a store=b").is_err());
        assert!(parse_request("LOAD name=x path=a store=b").is_err());
    }

    #[test]
    fn malformed_requests_error() {
        assert!(parse_request("").is_err());
        assert!(parse_request("FROBNICATE").is_err());
        assert!(parse_request("SUBMIT").is_err(), "matrix is required");
        assert!(parse_request("SUBMIT matrix=m k=abc").is_err());
        assert!(parse_request("SUBMIT matrix=m bogus=1").is_err(), "unknown field");
        assert!(parse_request("STATUS").is_err(), "id required");
        assert!(parse_request("STATS extra=1").is_err());
    }

    #[test]
    fn encode_rejects_frame_breaking_fields() {
        let inject = JobSpec { matrix: "x\nSHUTDOWN".into(), ..JobSpec::default() };
        assert!(encode_submit(&inject).is_err(), "newline would smuggle a second request");
        let spaced = JobSpec { matrix: "a b".into(), ..JobSpec::default() };
        assert!(encode_submit(&spaced).is_err(), "space would split the token");
        assert!(ensure_token("name", "ok-name_1.2").is_ok());
        assert!(ensure_token("name", "").is_err());
    }

    #[test]
    fn label_codec_round_trip() {
        let labels = vec![0usize, 3, 1, 1, 2, 0];
        assert_eq!(decode_labels(&encode_labels(&labels)).unwrap(), labels);
        assert_eq!(decode_labels("").unwrap(), Vec::<usize>::new());
        assert!(decode_labels("1,x,2").is_err());
    }

    #[test]
    fn binary_label_codec_round_trip() {
        let rows = vec![0usize, 3, 1, 1, 2, 0, 7];
        let cols = vec![2usize, 2, 0];
        let bytes = encode_labels_binary(&rows, &cols).unwrap();
        assert_eq!(bytes.len(), (rows.len() + cols.len()) * 4 + 8);
        let (r2, c2) = decode_labels_binary(&bytes, rows.len(), cols.len()).unwrap();
        assert_eq!(r2, rows);
        assert_eq!(c2, cols);
        // Empty labellings frame fine too.
        let empty = encode_labels_binary(&[], &[]).unwrap();
        assert_eq!(decode_labels_binary(&empty, 0, 0).unwrap(), (vec![], vec![]));
    }

    #[test]
    fn binary_label_codec_rejects_damage() {
        let bytes = encode_labels_binary(&[1, 2, 3], &[0]).unwrap();
        // Length mismatch against the header counts.
        assert!(decode_labels_binary(&bytes, 3, 2).is_err());
        // Bit flip fails the checksum.
        let mut bad = bytes.clone();
        bad[0] ^= 0x01;
        assert!(decode_labels_binary(&bad, 3, 1).is_err());
    }

    #[test]
    fn response_line_helpers() {
        assert_eq!(check_ok("OK id=3\n").unwrap(), "id=3");
        assert_eq!(check_ok("OK").unwrap(), "");
        assert!(check_ok("ERR boom").is_err());
        assert!(check_ok("??").is_err());
        assert!(!err_line("a\nb").contains('\n'));
    }
}
